"""Shared harness for the paper-reproduction benchmarks.

Protocol = the paper's (§4), at laptop scale on the synthetic task ladder:
100 clients (60 in quick mode), 10% participation, E=1 local epoch
(K = n_i/b steps), FedAvg server unless stated. Step sizes for the
baseline optimizers are grid-searched on ONE task (medium, α=0.1) and then
*reused everywhere* — exactly the transfer protocol whose failure mode
Δ-SGD is designed to avoid. Δ-SGD always runs with the paper defaults
γ=2, η0=0.2, θ0=1, δ=0.1 — no tuning, ever.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_tasks import CNN_PAPER, MLP_SMALL, MLP_WIDE
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import FederatedDataset
from repro.data.synthetic import get_task
from repro.models.small import accuracy, make_small_model, softmax_ce

# paper grids (§4 Hyperparameters)
GRIDS = {
    "sgd": [0.01, 0.05, 0.1, 0.5],
    "sgd_decay": [0.01, 0.05, 0.1, 0.5],
    "sgdm": [0.01, 0.05, 0.1, 0.5],
    "sgdm_decay": [0.01, 0.05, 0.1, 0.5],
    "adam": [0.001, 0.01, 0.1],
    "adagrad": [0.001, 0.01, 0.1],
    "sps": [None],          # official defaults (c=0.5, f*=0)
    "delta_sgd": [None],    # paper defaults, never tuned
}

MODELS = {"mlp": MLP_SMALL, "mlp-wide": MLP_WIDE, "cnn": CNN_PAPER}


@functools.lru_cache(maxsize=32)
def _fed(task_id: str, alpha: float, num_clients: int, seed: int,
         variable_sizes: bool = False):
    task = get_task(task_id, seed=seed)
    vs = None
    if variable_sizes:
        vs = np.random.default_rng(seed + 5).integers(100, 501, num_clients)
    return FederatedDataset.build(task, num_clients=num_clients, alpha=alpha,
                                  samples_per_client=500, seed=seed,
                                  variable_sizes=vs)


def run_fl(opt_name: str, task_id: str, *, alpha: Optional[float] = None,
           rounds: int = 60, lr: Optional[float] = None,
           model: str = "mlp", server: str = "fedavg",
           fedprox_mu: float = 0.0, delta: float = 0.1,
           local_epochs: int = 1, batch: int = 64, num_clients: int = 60,
           participation: float = 0.1, weighted: bool = False,
           variable_sizes: bool = False, seed: int = 0,
           engine: str = "vmap", scenario: Optional[str] = None,
           compression: Optional[str] = None,
           error_feedback: bool = False,
           robust_agg: Optional[str] = None,
           quorum: Optional[int] = None,
           telemetry: bool = False) -> Dict:
    """One FL training run; returns final test accuracy + timing.

    ``engine="flat"`` switches Δ-SGD runs onto the packed flat-parameter
    round engine (core/fed_round flat path). ``scenario`` names a
    federation preset (repro.federation.scenarios) — participation
    scheduling, heterogeneous K_c, async buffering; its Dirichlet-α hint
    is used when ``alpha`` is not given, and async scenarios force the
    flat engine. Scenario runs also return cohort/staleness/K_eff
    telemetry (see launch/report.scenario_summary).

    ``compression`` names a delta-compression kind (repro.compression:
    "none"/"int8"/"topk"; ``error_feedback`` adds EF21); active
    compression forces the flat engine too, and the run returns
    wire-bytes / compression-ratio telemetry under ``"compression"``.

    ``robust_agg`` / ``quorum`` override the scenario's robust server
    aggregation and quorum threshold (repro.federation.faults; None =
    keep the preset's choice — an explicit "mean" DOWNGRADES a robust
    preset to plain averaging, which the faults suite uses to show the
    undefended byzantine divergence). They promote a scenario-less run
    to ``sync_iid``; faulty/robust scenarios force the flat engine.

    ``telemetry=True`` turns on the in-scan distribution plane
    (repro.telemetry) — non-perturbing by contract, so the telemetry
    bench suite times its overhead against this same run with it off."""
    scn = None
    scn_overrides = {}
    if robust_agg is not None:
        scn_overrides["robust_agg"] = robust_agg
    if quorum is not None:
        scn_overrides["quorum"] = quorum
    if scenario is not None or scn_overrides:
        from repro.federation import get_scenario
        # run seed threaded into the scenario: multi-seed sweeps must
        # vary the cohort / K_c / staleness draws too
        scn = get_scenario(scenario or "sync_iid", seed=seed,
                           **scn_overrides)
        if alpha is None:
            alpha = scn.alpha
    comp = None
    if (compression is not None or error_feedback
            or (scn is not None and scn.bandwidth_heterogeneous)):
        # a bandwidth-heterogeneous scenario activates even a kind="none"
        # spec (per-client level draws) — same resolution as the launch
        # drivers, so the preset behaves identically from either entry
        from repro.compression import get_compression
        comp = get_compression(compression, error_feedback=error_feedback)
    comp_active = comp is not None and comp.active(scn)
    alpha = 0.1 if alpha is None else alpha
    fed = _fed(task_id, alpha, num_clients, seed, variable_sizes)
    fed.scenario = scn        # _fed is lru_cached: (re)pin per run
    fed._round = 0
    init_fn, logits_fn = make_small_model(MODELS[model])
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}),
        fedprox_mu=fedprox_mu)
    kw = {}
    if lr is not None:
        kw["lr"] = lr
    if opt_name == "delta_sgd":
        kw["delta"] = delta
    copt = get_client_opt(opt_name, **kw)
    sopt = get_server_opt(server)
    flat = False
    if (engine == "flat"
            or (scn is not None and (scn.is_async or scn.faulty
                                     or scn.robust or scn.quorum > 0))
            or comp_active) and opt_name == "delta_sgd":
        # pallas kernels on TPU; identical fused math via XLA elsewhere
        # (interpret-mode pallas in the round loop would distort timing)
        flat = "pallas" if jax.default_backend() == "tpu" else "xla"
    rnd = jax.jit(make_fl_round(
        loss_fn, copt, sopt, num_rounds=rounds, weighted=weighted,
        flat=flat, scenario=scn, num_clients=num_clients,
        client_sizes=fed.client_sizes() if scn is not None else None,
        compression=comp, telemetry=telemetry))
    from repro.federation.schedulers import cohort_size
    state = init_fl_state(init_fn(jax.random.key(seed)), sopt, scn,
                          compression=comp,
                          cohort=cohort_size(participation, num_clients))
    K = fed.epoch_steps(batch) * local_epochs
    ids_rounds, mrows, crows = [], [], []
    t0 = time.time()
    metrics = {}
    for t in range(rounds):
        batches, w, ids = fed.sample_round(participation, K, batch,
                                           round_idx=t)
        state, metrics, _ = rnd(
            state, {"x": jnp.asarray(batches["x"]),
                    "y": jnp.asarray(batches["y"])},
            client_weights=jnp.asarray(w) if weighted else None)
        if scn is not None:
            ids_rounds.append(np.asarray(ids))
            mrows.append({k: float(metrics[k]) for k in
                          ("stale_mean", "stale_max", "k_eff_mean",
                           "k_eff_min", "k_eff_max", "flushed",
                           # round-health telemetry
                           # (repro.federation.faults)
                           "eta_clip_rate", "nan_guard_rate",
                           "valid_count", "round_skipped", "drop_frac",
                           "byz_frac", "overstale_frac", "agg_clip_rate")
                          if k in metrics})
        if comp_active:
            crows.append({k: float(metrics[k]) for k in
                          ("wire_bytes", "comp_ratio", "comp_level_mean")
                          if k in metrics})
    wall = time.time() - t0
    xt, yt = fed.test_batch(2000)
    acc = float(accuracy(logits_fn(state.params, jnp.asarray(xt)),
                         jnp.asarray(yt)))
    out = {"acc": acc, "wall_s": wall, "us_per_round": wall / rounds * 1e6,
           "eta": float(metrics.get("eta_mean", np.nan)),
           "loss": float(metrics.get("loss", np.nan))}
    if scn is not None:
        from repro.launch.report import scenario_summary
        out["scenario"] = scenario_summary(scn.name, ids_rounds,
                                           num_clients, mrows)
    if crows:
        out["compression"] = {
            "wire_bytes_round": float(np.mean([r["wire_bytes"]
                                               for r in crows])),
            "comp_ratio": float(np.mean([r["comp_ratio"] for r in crows]))}
        if any("comp_level_mean" in r for r in crows):
            out["compression"]["level_mean"] = float(np.mean(
                [r["comp_level_mean"] for r in crows
                 if "comp_level_mean" in r]))
    return out


_TUNED: Dict[str, Optional[float]] = {}


def tuned_lrs(rounds: int = 40, seed: int = 0) -> Dict[str, Optional[float]]:
    """Grid-search every baseline on the tuning task (medium, α=0.1, MLP —
    the task where baselines actually converge, mirroring the paper's
    choice of CIFAR-10/ResNet-18 as the tuning anchor)."""
    if _TUNED:
        return _TUNED
    for opt, grid in GRIDS.items():
        best_lr, best_acc = None, -1.0
        for lr in grid:
            acc = run_fl(opt, "medium", alpha=0.1, rounds=rounds, lr=lr,
                         seed=seed)["acc"]
            if acc > best_acc:
                best_acc, best_lr = acc, lr
        _TUNED[opt] = best_lr
    return _TUNED


OPTS = list(GRIDS)
