"""Benchmark harness — one function per paper table/figure, each emitting
``name,us_per_call,derived`` CSV rows (us_per_call = wall-µs per FL round;
derived = final test accuracy unless stated).

  table1   : optimizer × task × α grid (paper Table 1)
  table2b  : FedProx loss, α=0.01 (paper Table 2b)
  table3   : variable local dataset sizes + weighted FedAvg (paper Table 3)
  table4   : FedAdam server (paper Table 4)
  fig4     : Δ-SGD δ-sensitivity (paper Fig. 4)
  fig5     : local epochs E ∈ {1,2,3} (paper Fig. 5)
  convex   : Thm 5 numeric check (derived = final distance² / initial)
  kernels  : per-kernel µs/call in interpret mode (derived = max |err| vs
             the ref oracle — correctness, not TPU wall time)
  sharded  : flat Δ-SGD round on a host (data, model) mesh, sharded vs
             replicated (derived = max |param diff| between engines)
  scenarios: federation scenario presets (repro.federation) on the quick
             FL harness — sync_iid / dirichlet_stragglers / zipf_async
             (derived = final accuracy) plus cohort-skew, staleness and
             effective-K diagnostic rows
  compression: the flat_fed_compressed variant (repro.compression) on
             the quick FL harness — none/int8/topk delta compression
             with EF21 error feedback (derived = final accuracy) plus
             wire-bytes and compression-ratio rows, the
             bandwidth_tiered per-client-level scenario, and
             interpret-mode µs/call + max-err rows for the
             quantize/dequantize/top-k kernels
  faults   : chaos presets (repro.federation.faults) — dropouts + NaN
             gradients (dirichlet_dropouts) and byzantine + over-stale
             deltas (byzantine_async) under {mean, clip, trimmed}
             aggregation, plus a clean sync_iid anchor (derived = final
             accuracy; byzantine-under-mean rows document the
             undefended divergence) and round-health telemetry rows
  rounds_fused: the round-fused training loop (repro.core.fed_loop) vs
             the host loop at C=128 — us/round both ways (bit-exact,
             fused-row derived = max |param diff| must be 0) plus the
             host/fused speedup row (acceptance: >= 1.5x)
  fleet    : fleet regime (repro.core.fed_loop.make_fleet_loop +
             repro.federation.arena) — us/round at C_registered in
             {10^2, 10^3} (--quick; full adds {10^4, 10^5}) with a
             fixed 16-client cohort, each size compile-checked against
             the cohort-only memory ceiling
             (hlo.assert_cohort_only_materialization), plus one fused
             Gumbel-top-k cohort draw over 10^5 zipf candidates

Full protocol details: benchmarks/fl_common.py. Run everything:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# 8 virtual CPU devices so the `sharded` suite exercises a real mesh;
# must be set before jax initializes (all jax imports here are lazy).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

ROWS = []


def _timeit(fn, *a, n=3):
    """Interpret-mode µs/call: one warmup call, then the mean of n
    blocked calls. Returns (us, last_output)."""
    import jax
    fn(*a)
    t0 = time.time()
    for _ in range(n):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6, out


def emit(name, us, derived):
    # %.6g keeps small kernel parity errors exact (a fixed .4f would
    # round 1.4e-4 down past the bench guard's max_err thresholds)
    row = f"{name},{us:.1f},{derived:.6g}"
    ROWS.append(row)
    print(row, flush=True)


def table1(rounds):
    from benchmarks.fl_common import OPTS, run_fl, tuned_lrs
    lrs = tuned_lrs(rounds=min(rounds, 40))
    for task in ("easy", "medium", "hard"):
        for alpha in (1.0, 0.1, 0.01):
            for opt in OPTS:
                r = run_fl(opt, task, alpha=alpha, rounds=rounds,
                           lr=lrs[opt])
                emit(f"table1/{task}/alpha{alpha}/{opt}",
                     r["us_per_round"], r["acc"])


def table2b(rounds):
    from benchmarks.fl_common import OPTS, run_fl, tuned_lrs
    lrs = tuned_lrs(rounds=min(rounds, 40))
    for opt in OPTS:
        r = run_fl(opt, "medium", alpha=0.01, rounds=rounds, lr=lrs[opt],
                   fedprox_mu=0.1)
        emit(f"table2b/fedprox/medium/alpha0.01/{opt}", r["us_per_round"],
             r["acc"])


def table3(rounds):
    from benchmarks.fl_common import run_fl, tuned_lrs
    lrs = tuned_lrs(rounds=min(rounds, 40))
    for opt in ("sgd", "sgdm", "adam", "adagrad", "sps", "delta_sgd"):
        r = run_fl(opt, "medium", alpha=0.1, rounds=rounds, lr=lrs[opt],
                   variable_sizes=True, weighted=True)
        emit(f"table3/varsizes/medium/{opt}", r["us_per_round"], r["acc"])


def table4(rounds):
    from benchmarks.fl_common import OPTS, run_fl, tuned_lrs
    lrs = tuned_lrs(rounds=min(rounds, 40))
    for opt in OPTS:
        r = run_fl(opt, "medium", alpha=0.1, rounds=rounds, lr=lrs[opt],
                   server="fedadam")
        emit(f"table4/fedadam/medium/{opt}", r["us_per_round"], r["acc"])


def fig4(rounds):
    from benchmarks.fl_common import run_fl
    for delta in (0.01, 0.1, 1.0):
        for task in ("easy", "medium"):
            r = run_fl("delta_sgd", task, alpha=0.1, rounds=rounds,
                       delta=delta)
            emit(f"fig4/delta{delta}/{task}", r["us_per_round"], r["acc"])


def fig5(rounds):
    from benchmarks.fl_common import run_fl
    for E in (1, 2, 3):
        r = run_fl("delta_sgd", "medium", alpha=0.1, rounds=rounds,
                   local_epochs=E)
        emit(f"fig5/epochs{E}/medium", r["us_per_round"], r["acc"])


def convex(rounds=40):
    """Thm 5 numeric check on interpolation least squares."""
    sys.path.insert(0, "tests")
    from test_theory import _make_problem, _gi
    m, d = 4, 6
    As, bs, x_star = _make_problem(m, d)
    x = np.zeros(d, np.float32)
    xs_i = [x.copy() for _ in range(m)]
    xs_prev = [x.copy() for _ in range(m)]
    etas, thetas = [0.05] * m, [0.0] * m
    gs_prev = [_gi(As[i], bs[i], x) for i in range(m)]
    t0 = time.time()
    v0 = float(np.sum(x_star ** 2))
    v = v0
    for t in range(rounds):
        nxt, ne, nt = [], [], []
        for i in range(m):
            g = _gi(As[i], bs[i], xs_i[i])
            dg = np.linalg.norm(g - gs_prev[i])
            dx = np.linalg.norm(xs_i[i] - xs_prev[i])
            eta = min(dx / (2 * dg) if dg > 0 else np.inf,
                      np.sqrt(1 + thetas[i]) * etas[i])
            nxt.append(xs_i[i] - eta * g)
            nt.append(eta / etas[i])
            ne.append(eta)
            gs_prev[i] = g
        xs_prev, xs_i, etas, thetas = xs_i, nxt, ne, nt
        xm = np.mean(xs_i, axis=0)
        v = float(np.sum((xm - x_star) ** 2))
    emit("convex/dist_ratio_T40", (time.time() - t0) / rounds * 1e6, v / v0)


def kernels(rounds=None):
    del rounds
    import jax
    import jax.numpy as jnp
    from repro.kernels.delta_sgd import delta_sgd as dk, ref as dref
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.mamba2_scan.ops import ssd_scan
    from repro.kernels.mamba2_scan.ref import ssd_ref
    rng = np.random.default_rng(0)
    timeit = _timeit

    g = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    gp = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    us, out = timeit(lambda a, b: dk.norms(a, b, interpret=True), g, gp)
    err = abs(float(out[0]) - float(dref.norms_ref(g, gp)[0]))
    emit("kernels/delta_sgd_norms_64k", us, err)

    # ---- flat fused Δ-SGD step: packed (C, N) engine vs per-leaf path ----
    # 16-leaf tree, 64k elements total; one full local step (norms+apply).
    from repro.core import flat as fp
    from repro.core.delta_sgd import (delta_sgd_init, delta_sgd_update,
                                      flat_delta_sgd_init,
                                      flat_delta_sgd_step)
    GAMMA, DELTA, ETA0, THETA0 = 2.0, 0.1, 0.2, 1.0
    tree = {f"w{i}": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)
            for i in range(16)}
    grads = {k_: v * 0.1 for k_, v in tree.items()}
    gprev = {k_: v * -0.05 for k_, v in tree.items()}
    layout = fp.layout_of(tree)

    def perleaf_step(p, g, gp_):
        """Legacy schedule: norms + apply kernel per leaf (2×leaves
        launches per local step, per client)."""
        dg2 = gg2 = jnp.zeros((), jnp.float32)
        for a, b in zip(jax.tree_util.tree_leaves(g),
                        jax.tree_util.tree_leaves(gp_)):
            x, y = dk.norms(a, b, interpret=True)
            dg2, gg2 = dg2 + x, gg2 + y
        eta = ETA0  # first-step branch: η fixed, apply still runs
        return {k2: dk.apply_update(p[k2], g[k2], eta, interpret=True)
                for k2 in p}, dg2, gg2

    def packed_step(P, G, S):
        return flat_delta_sgd_step(P, G, S, gamma=GAMMA, delta=DELTA,
                                   eta0=ETA0, interpret=True)

    P1 = fp.pack(tree, layout)[None]
    G1 = fp.pack(grads, layout)[None]
    S1 = flat_delta_sgd_init(1, layout, eta0=ETA0, theta0=THETA0)
    S1 = S1._replace(prev_grads=fp.pack(gprev, layout)[None])

    # launch accounting (trace-time): the packed step must cost exactly
    # 2 pallas launches independent of leaf count and client count
    for C in (1, 4):
        Pc = jnp.broadcast_to(P1[0], (C, layout.padded_size))
        Gc = jnp.broadcast_to(G1[0], (C, layout.padded_size))
        Sc = flat_delta_sgd_init(C, layout, eta0=ETA0, theta0=THETA0)
        dk.reset_launch_count()
        jax.block_until_ready(packed_step(Pc, Gc, Sc)[0])
        assert dk.launch_count() == 2, (C, dict(dk.LAUNCHES))
    dk.reset_launch_count()
    jax.block_until_ready(perleaf_step(tree, grads, gprev)[0]["w0"])
    perleaf_launches = dk.launch_count()  # 2 × leaves, per client
    print(f"# launches/local-step: per-leaf={perleaf_launches} "
          f"(x num_clients under vmap), flat_fused=2 (total)", flush=True)

    # parity vs the pytree oracle over a full first step
    s_ref = delta_sgd_init(tree, eta0=ETA0, theta0=THETA0)
    s_ref = s_ref._replace(prev_grads=gprev)
    ref_p, ref_s = delta_sgd_update(tree, grads, s_ref, gamma=GAMMA,
                                    delta=DELTA, eta0=ETA0)
    newP, newS = packed_step(P1, G1, S1)
    got_p = fp.unpack(newP[0], layout)
    err = max(float(jnp.max(jnp.abs(got_p[k2] - ref_p[k2])))
              for k2 in ref_p)
    err = max(err, abs(float(newS.eta[0]) - float(ref_s.eta)))

    us_packed, _ = timeit(lambda a, b: packed_step(a, b, S1), P1, G1)
    us_perleaf, _ = timeit(lambda a, b: perleaf_step(a, b, gprev),
                           tree, grads)
    emit("kernels/delta_sgd_perleaf_64k", us_perleaf, 0.0)
    emit("kernels/delta_sgd_flat_fused", us_packed, err)
    assert us_packed <= us_perleaf, (us_packed, us_perleaf)

    # end-to-end round time, flat vs vmap engine (derived = accuracy)
    from benchmarks import fl_common
    for eng in ("vmap", "flat"):
        # fresh dataset per engine: round sampling is stateful, so a
        # shared cached dataset would feed the engines different batches
        fl_common._fed.cache_clear()
        r = fl_common.run_fl("delta_sgd", "easy", rounds=10,
                             num_clients=30, engine=eng)
        emit(f"kernels/fl_round_{eng}", r["us_per_round"], r["acc"])

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us, out = timeit(lambda a, b, c: flash_attention(
        a, b, c, block_q=64, block_k=64, interpret=True), q, k, v)
    err = float(jnp.max(jnp.abs(out - attention_ref(q, k, v))))
    emit("kernels/flash_attention_256", us, err)

    x = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 128, 4)), jnp.float32)
    A = jnp.asarray(np.log(rng.uniform(1, 16, 4)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(1, 128, 1, 16)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 128, 1, 16)), jnp.float32)
    us, out = timeit(lambda *a: ssd_scan(*a), x, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(out[0] - ssd_ref(x, dt, A, Bm, Cm)[0])))
    emit("kernels/mamba2_ssd_128", us, err)


def sharded(rounds=None):
    """Flat Δ-SGD rounds with the (C, N) buffer mesh-sharded per
    FederationSpec.flat_spec vs the replicated flat engine. Timing is
    host-mesh wall time (virtual CPU devices — layout/collective
    correctness, not TPU speed); derived of the sharded row = max
    |param diff| vs the replicated engine after 3 rounds.

    The flat_block_* rows time the round-fused loop both ways: the
    replicated fused loop vs the block-level shard_map
    (make_fl_loop(block_sharded=True) — ONE shard_map around the whole
    R-round lax.scan, so per-round dispatch overhead is paid once per
    block instead of once per round). Their us ratio is the dispatch-
    overhead figure baseline.json soft-guards (measured ~2.5-3x vs the
    replicated per-round engine; the limit adds headroom for shared-CPU
    timing noise)."""
    del rounds
    import jax
    import jax.numpy as jnp
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.sharding.spec import cross_device

    rng = np.random.default_rng(0)
    shape = (4, 2) if jax.device_count() >= 8 else (1, 1)
    mesh = jax.make_mesh(shape, ("data", "model"))
    spec = cross_device(mesh)
    D, C, K = 4096, 8, 4

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    finals = {}
    for name, kw in (("replicated", {}),
                     ("sharded", dict(mesh=mesh, federation=spec))):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat="xla", **kw))
        st = init_fl_state(params, sopt)
        st, _, _ = rnd(st, batches)          # compile + warm
        jax.block_until_ready(st.params["x"])
        st = init_fl_state(params, sopt)
        t0 = time.time()
        for _ in range(3):
            st, _, _ = rnd(st, batches)
        jax.block_until_ready(st.params["x"])
        us = (time.time() - t0) / 3 * 1e6
        finals[name] = np.asarray(st.params["x"])
        err = (0.0 if name == "replicated" else
               float(np.max(np.abs(finals["sharded"]
                                   - finals["replicated"]))))
        emit(f"sharded/flat_round_{name}_{shape[0]}x{shape[1]}", us, err)

    # ---- block-level shard_map: the fused R-round loop replicated vs
    # wrapped in ONE shard_map over the client axes (core.fed_loop
    # block_sharded=True). N stays replicated (flat_shards == 1); the
    # only client-crossing collective is the aggregate psum, so the
    # sharded block's per-round cost tracks the replicated loop's
    # instead of paying per-round SPMD dispatch ----
    from repro.core import flatten_fl_state, make_fl_loop
    from repro.sharding.spec import FederationSpec
    fedc = FederationSpec(client_axes=("data",), fsdp_axes=(), tp_axes=())
    R = 8
    data = {"A": jnp.asarray(rng.normal(size=(R, C, K, 8, D)),
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(R, C, K, 8)), jnp.float32)}
    kwb = dict(params_like=params, num_rounds=4 * R, rounds_per_call=R,
               flat="xla")
    finals_b = {}
    data1 = jax.tree.map(lambda x: x[:1], data)
    for name, kw in (("block_replicated", {}),
                     ("block_sharded", dict(mesh=mesh, federation=fedc,
                                            block_sharded=True))):
        loop = make_fl_loop(loss, copt, sopt, **kwb, **kw)
        jloop = jax.jit(loop)
        f0 = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        fst, _ = jloop(f0, data)             # compile + warm
        jax.block_until_ready(fst.P)
        t0 = time.time()
        for _ in range(3):
            fst, _ = jloop(f0, data)
        jax.block_until_ready(fst.P)
        us = (time.time() - t0) / (3 * R) * 1e6
        # parity over ONE round: psum reassociation is ~1e-6/round, but
        # Δ-SGD's η min-branch can discretely amplify it over a long
        # block — the controlled-tolerance multi-round parity lives in
        # tests/test_fleet.py
        f1, _ = jloop(f0, data1)
        finals_b[name] = np.asarray(f1.P)
        err = (0.0 if name == "block_replicated" else
               float(np.max(np.abs(finals_b["block_sharded"]
                                   - finals_b["block_replicated"]))))
        emit(f"sharded/flat_{name}_{shape[0]}x{shape[1]}", us, err)


def scenarios(rounds=None):
    """Federation scenario presets on the quick FL harness. The accuracy
    rows (derived = acc) time the full scenario round incl. scheduler
    draw, lane masking, and (zipf_async) the buffered server path; the
    diagnostic rows surface the per-round cohort composition / staleness
    / effective-K telemetry in the benchmark CSV (satellite: report
    scenario stats in the CSV)."""
    del rounds
    from benchmarks import fl_common
    for name in ("sync_iid", "dirichlet_stragglers", "zipf_async"):
        # fresh dataset per scenario: round sampling and the scenario
        # pin are stateful on the cached FederatedDataset
        fl_common._fed.cache_clear()
        r = fl_common.run_fl("delta_sgd", "easy", rounds=10,
                             num_clients=30, scenario=name)
        emit(f"scenarios/{name}", r["us_per_round"], r["acc"])
        s = r["scenario"]
        emit(f"scenarios/{name}/cohort_top5_share", r["us_per_round"],
             s.get("cohort_top5_share", 0.0))
        if "k_eff_mean" in s:
            emit(f"scenarios/{name}/k_eff_mean", r["us_per_round"],
                 s["k_eff_mean"])
        if "stale_mean" in s:
            emit(f"scenarios/{name}/stale_mean", r["us_per_round"],
                 s["stale_mean"])


def compression(rounds=None):
    """Delta compression (repro.compression) on the quick FL harness:
    the `flat_fed_compressed` variant at each compression kind (with
    EF21 error feedback), its wire-bytes / compression-ratio columns,
    the bandwidth_tiered per-client-level scenario, and interpret-mode
    kernel rows (derived = max |err| vs the pure-jnp oracle)."""
    del rounds
    import jax.numpy as jnp
    from benchmarks import fl_common
    from repro.kernels.compress import compress as ck, ref as cr

    for kind in ("none", "int8", "topk"):
        # fresh dataset per run: round sampling is stateful on the
        # cached FederatedDataset
        fl_common._fed.cache_clear()
        r = fl_common.run_fl("delta_sgd", "easy", rounds=10,
                             num_clients=30, engine="flat",
                             compression=kind,
                             error_feedback=(kind != "none"))
        emit(f"compression/flat_fed_compressed/{kind}",
             r["us_per_round"], r["acc"])
        if kind != "none":
            c = r["compression"]
            emit(f"compression/flat_fed_compressed/{kind}/wire_bytes",
                 r["us_per_round"], c["wire_bytes_round"])
            emit(f"compression/flat_fed_compressed/{kind}/comp_ratio",
                 r["us_per_round"], c["comp_ratio"])

    # bandwidth axis: per-client levels drawn each round (tiered mix)
    fl_common._fed.cache_clear()
    r = fl_common.run_fl("delta_sgd", "easy", rounds=10, num_clients=30,
                         compression="int8", error_feedback=True,
                         scenario="bandwidth_tiered")
    emit("compression/bandwidth_tiered", r["us_per_round"], r["acc"])
    emit("compression/bandwidth_tiered/comp_ratio", r["us_per_round"],
         r["compression"]["comp_ratio"])
    emit("compression/bandwidth_tiered/level_mean", r["us_per_round"],
         r["compression"].get("level_mean", 0.0))

    # kernel rows: interpret-mode µs/call, derived = max err vs oracle
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 1 << 14)), jnp.float32)

    us, (q, s) = _timeit(lambda a: ck.quantize_int8(a, interpret=True), x)
    qr, sr = cr.quantize_int8_ref(x)
    err = max(float(jnp.max(jnp.abs(q.astype(jnp.int32)
                                    - qr.astype(jnp.int32)))),
              float(jnp.max(jnp.abs(s - sr))))
    emit("compression/quantize_int8_64k", us, err)
    us, dq = _timeit(lambda a, b: ck.dequantize_int8(a, b, interpret=True),
                    q, s)
    err = float(jnp.max(jnp.abs(dq - cr.dequantize_int8_ref(qr, sr))))
    emit("compression/dequantize_int8_64k", us, err)
    us, tk = _timeit(lambda a: ck.topk_mask(a, 32, interpret=True), x)
    err = float(jnp.max(jnp.abs(tk - cr.topk_mask_ref(x, 32))))
    emit("compression/topk_mask_64k", us, err)


def faults(rounds=None):
    """Chaos suite (repro.federation.faults): the two chaos scenario
    presets — dirichlet_dropouts (mid-round dropouts + NaN gradients,
    sync) and byzantine_async (−10x scaled deltas + over-stale updates,
    FedBuff async) — under the RobustAgg ladder {mean, clip, trimmed},
    next to the clean sync_iid anchor (derived = final accuracy; the
    mean rows under byzantine corruption are EXPECTED to crater — that
    contrast is what the suite documents, so baseline.json keeps every
    faults row soft). The telemetry rows surface the round-health
    counters: mean surviving clients, quorum skips, NaN-guard and
    η-clamp trigger rates."""
    del rounds
    from benchmarks import fl_common
    # cohort of 10 (participation 0.25 of 40): big enough that trimmed
    # aggregation has a real window (t=2) and the 10% byzantine rate
    # corrupts ~1 client per round
    kw = dict(rounds=10, num_clients=40, participation=0.25)
    fl_common._fed.cache_clear()
    clean = fl_common.run_fl("delta_sgd", "easy", engine="flat",
                             scenario="sync_iid", **kw)
    emit("faults/clean/sync_iid/mean", clean["us_per_round"],
         clean["acc"])
    for scen in ("dirichlet_dropouts", "byzantine_async"):
        for agg in ("mean", "clip", "trimmed"):
            fl_common._fed.cache_clear()
            r = fl_common.run_fl("delta_sgd", "easy", scenario=scen,
                                 robust_agg=agg, **kw)
            emit(f"faults/{scen}/{agg}", r["us_per_round"], r["acc"])
            if agg == "clip":     # one telemetry set per preset
                s = r["scenario"]
                for key in ("valid_mean", "skipped_rounds",
                            "nan_guard_rate", "eta_clip_rate"):
                    if key in s:
                        emit(f"faults/{scen}/{key}", r["us_per_round"],
                             s[key])


def rounds_fused(rounds=None):
    """Round-fused loop (repro.core.fed_loop) vs the host loop at a
    fleet-scale cohort (C=128, full participation) on the synthetic
    task, wide-MLP params (~45k): the host loop re-stages (C, K, b, ...)
    batches, re-dispatches the jitted round, and pays the per-round
    pack/unpack traffic — broadcast re-pack of the params at round
    start, the params-tree + (C, ...) new-locals unpack at round end —
    all scaling with C*N; the fused loop carries the state in persistent
    flat form across an 8-round lax.scan, stages the example arena on
    device once, and ships only (R, C, K, b) int32 gather indices per
    block. Rows: us/round for each loop (derived of the fused row = max
    |param diff| vs the host loop — must be 0.0, the loops are
    bit-exact) and the speedup row (derived = host/fused, the >= 1.5x
    acceptance figure)."""
    del rounds
    import jax
    import jax.numpy as jnp
    from repro.core import (arena_gather, flatten_fl_state, get_client_opt,
                            get_server_opt, init_fl_state, make_fl_loop,
                            make_fl_round, make_loss, unflatten_fl_state)
    from repro.data.pipeline import FederatedDataset
    from repro.data.synthetic import get_task
    from repro.models.small import MLPConfig, make_small_model, softmax_ce

    # C >= 64 at small per-client batches and the default K=2: the
    # regime the paper's fleet-scale heterogeneity experiments live in,
    # where per-round overhead (not the grad evals) dominates wall-clock
    T, R, B, K, part, m = 16, 8, 4, 2, 1.0, 128
    task = get_task("easy", seed=0)

    def build():
        return FederatedDataset.build(task, num_clients=m, alpha=1.0,
                                      seed=0)

    init_fn, logits_fn = make_small_model(
        MLPConfig("mlp-wide-fused", input_dim=32, hidden_dims=(1024,),
                  num_classes=10))
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}))
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    params = init_fn(jax.random.key(0))

    def run_host(fed, rounds_n, rnd):
        # the launch/train.py host round: stage batches, dispatch the
        # jitted round, materialize the round's metrics row (telemetry)
        st = init_fl_state(params, sopt)
        for t in range(rounds_n):
            bat, _, _ = fed.sample_round(part, K, B, round_idx=t)
            st, met, _ = rnd(st, {"x": jnp.asarray(bat["x"]),
                                  "y": jnp.asarray(bat["y"])})
            jax.tree.map(np.asarray, met)
        jax.block_until_ready(st.params["l0"]["w"])
        return st

    rnd = jax.jit(make_fl_round(loss_fn, copt, sopt, num_rounds=T,
                                flat="xla"))
    run_host(build(), 1, rnd)               # compile warmup
    fed = build()
    t0 = time.time()
    st = run_host(fed, T, rnd)
    us_host = (time.time() - t0) / T * 1e6

    loop = make_fl_loop(loss_fn, copt, sopt, params_like=params,
                        num_rounds=T, rounds_per_call=R, flat="xla",
                        gather=arena_gather)
    jloop = jax.jit(loop, donate_argnums=0)

    def run_fused(fed, rounds_n):
        arena = jax.tree.map(jnp.asarray, fed.arena())
        fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        for t in range(0, rounds_n, R):
            idx, _, _ = fed.sample_block(part, K, B, round0=t,
                                         rounds=min(R, rounds_n - t))
            fst, met = jloop(fst, jnp.asarray(idx), arena=arena)
            jax.tree.map(np.asarray, met)   # R stacked rows, one fetch
        jax.block_until_ready(fst.P)
        return unflatten_fl_state(fst, loop.layout)

    run_fused(build(), R)                   # compile warmup
    fed = build()
    t0 = time.time()
    st2 = run_fused(fed, T)
    us_fused = (time.time() - t0) / T * 1e6

    import numpy as _np
    err = max(float(_np.max(_np.abs(_np.asarray(a, _np.float32)
                                    - _np.asarray(b, _np.float32))))
              for a, b in zip(jax.tree_util.tree_leaves(st.params),
                              jax.tree_util.tree_leaves(st2.params)))
    emit("rounds_fused/host_loop", us_host, 0.0)
    emit(f"rounds_fused/fused_r{R}", us_fused, err)
    emit("rounds_fused/speedup", us_fused, us_host / us_fused)


def fleet(rounds=None):
    """Fleet-scale suite (repro.core.fed_loop.make_fleet_loop +
    repro.federation.arena): the fleet loop at C_registered in {100,
    1000} (quick; the full run adds {10^4, 10^5}) with a FIXED cohort of
    C=16 — us/round must stay flat in C_registered because only the
    sampled cohort is ever materialized. Each size is compiled first and
    checked against the memory ceiling
    (repro.sharding.hlo.assert_cohort_only_materialization: no tensor
    wider than O(C_registered) scalars along the registered dim), so a
    row appearing at all means the ceiling held (derived = 0). The
    scheduler row times ONE fused Gumbel-top-k cohort draw over 10^5
    zipf candidates (derived = 0 when the draw is C distinct in-range
    ids)."""
    quick = rounds is not None and rounds <= 25
    import jax
    import jax.numpy as jnp
    from repro.core import (flatten_fl_state, get_client_opt,
                            get_server_opt, init_fl_state, make_fleet_loop,
                            make_loss)
    from repro.federation import arena_init
    from repro.federation.schedulers import make_scheduler
    from repro.sharding.hlo import assert_cohort_only_materialization

    rng = np.random.default_rng(0)
    D, C, K, B, R = 512, 16, 2, 4, 4

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    loss = make_loss(quad)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    data = {"A": jnp.asarray(rng.normal(size=(R, C, K, B, D)),
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(R, C, K, B)), jnp.float32)}
    for M in (100, 1000) if quick else (100, 1000, 10_000, 100_000):
        loop = make_fleet_loop(loss, copt, sopt, params_like=params,
                               num_rounds=4 * R, num_registered=M,
                               rounds_per_call=R, seed=7)
        car = arena_init(M, eta0=loop.eta0)
        fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        jloop = jax.jit(loop)
        compiled = jloop.lower((fst, car), data).compile()
        assert_cohort_only_materialization(compiled, M)
        out = jloop((fst, car), data)        # warm from the same exec
        jax.block_until_ready(out[0][0].P)
        t0 = time.time()
        for _ in range(3):
            out = jloop((fst, car), data)
        jax.block_until_ready(out[0][0].P)
        emit(f"fleet/loop_c{M}", (time.time() - t0) / (3 * R) * 1e6, 0.0)

    # scheduler scaling: one fused Gumbel-top-k draw over 10^5 heavy-
    # tailed candidates — no O(C_registered * N) host materialization
    M = 100_000
    sch = make_scheduler("zipf", num_clients=M, cohort=C)
    key = jax.random.key(0)
    samp = jax.jit(lambda t: sch.sample(key, t))
    us, ids = _timeit(samp, jnp.int32(0))
    ids = np.asarray(ids)
    ok = (len(np.unique(ids)) == C and ids.min() >= 0 and ids.max() < M)
    emit("fleet/sched_zipf_topk_100k", us, 0.0 if ok else 1.0)


def telemetry(rounds=None):
    """Telemetry plane suite (repro.telemetry + kernels/telemetry):
    kernel-vs-jnp-reference parity for the distribution kernels
    (derived = max |Δ|, exact 0 for integer histogram counts) and the
    non-perturbing cost contract — the same flat round timed with the
    telemetry plane off vs on. baseline.json normalizes
    telemetry/round_on by round_off with a soft ceiling, so a
    distribution reduction sneaking onto the step path (rather than
    riding the round-end values) shows up as an overhead regression."""
    del rounds
    import jax
    import jax.numpy as jnp
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.kernels.telemetry import (lane_histogram, lane_histogram_ref,
                                         lane_quantiles, lane_quantiles_ref)
    from repro.telemetry import TelemetrySpec

    rng = np.random.default_rng(0)
    spec = TelemetrySpec(enabled=True)
    edges = jnp.asarray(spec.eta_edges())
    x = jnp.asarray(10.0 ** rng.uniform(-5.0, 2.0, size=256), jnp.float32)
    us, h = _timeit(jax.jit(lambda v: lane_histogram(v, edges)), x)
    emit("telemetry/lane_histogram_256", us,
         float(jnp.abs(h - lane_histogram_ref(x, edges)).max()))
    us, q = _timeit(jax.jit(lambda v: lane_quantiles(v)), x)
    emit("telemetry/lane_quantiles_256", us,
         float(jnp.abs(q - lane_quantiles_ref(x)).max()))

    # overhead contract: one jitted flat round, off vs on. D is large
    # enough that the grad evals dominate — the telemetry reductions
    # run over (C,) round-end values, so their cost must NOT scale
    # with the model and the ratio row stays near 1.0
    D, C, K, B, T = 8192, 64, 2, 8, 8

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    loss = make_loss(quad)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    data = {"A": jnp.asarray(rng.normal(size=(C, K, B, D)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(C, K, B)), jnp.float32)}
    times = {}
    for tag, tele in (("round_off", False), ("round_on", True)):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=T,
                                    flat="xla", telemetry=tele))
        st = init_fl_state(params, sopt)
        st, met, _ = rnd(st, data)              # compile warmup
        jax.block_until_ready(st.params["x"])
        t0 = time.time()
        for _ in range(T):
            st, met, _ = rnd(st, data)
        jax.block_until_ready(st.params["x"])
        times[tag] = (time.time() - t0) / T * 1e6
        # derived: distribution keys present exactly when enabled
        want = {"eta_hist", "loss_deciles"} <= set(met)
        emit(f"telemetry/{tag}", times[tag],
             0.0 if want == tele else 1.0)
    emit("telemetry/overhead_ratio", times["round_on"],
         times["round_on"] / times["round_off"])


def serving(rounds=None):
    """Serving-plane suite (repro.serving): the fused scan decode vs
    the legacy per-token host loop (derived on the fused row = token
    mismatches vs the host loop — must be 0), the load generator's
    throughput / latency percentiles / occupancy under a closed loop,
    and the checkpoint hot-swap stall (save two rounds into a tempdir,
    start serving round 1, publish round 2 mid-run: derived = swaps
    observed, must be 1; us = notice-to-serving stall)."""
    import shutil
    import tempfile

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import (DecodeEngine, ModelRegistry, Workload,
                               greedy_decode, run_load)

    quick = rounds is not None and rounds <= 25
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S, G = 4, 32, 16 if quick else 32
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (B, S)), jnp.int32)}
    cache_len = S + G
    prefill = jax.jit(lambda p, b: model.prefill(p, b,
                                                 cache_len=cache_len))
    logits, cache0 = prefill(params, batch)
    tok0 = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

    # legacy host loop: one dispatch + one implicit sync per token
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))

    def host_loop():
        c, t, out = cache0, tok0, [tok0]
        for _ in range(G - 1):
            lg, c = step(params, c, t)
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            out.append(t)
        return jnp.concatenate(out, 1)

    us_host, ref = _timeit(host_loop, n=3)
    us_host /= G                          # per decoded token
    emit("serving/decode_host_loop", us_host, 0.0)

    fused = jax.jit(lambda p, c, t: greedy_decode(model, p, c, t, G - 1))
    us_fused, (toks, _, _) = _timeit(fused, params, cache0, tok0, n=3)
    us_fused /= G
    got = np.concatenate([np.asarray(tok0), np.asarray(toks)], axis=1)
    mismatch = int((np.asarray(ref) != got).sum())
    emit("serving/decode_fused", us_fused, float(mismatch))
    emit("serving/decode_fused_speedup", us_fused,
         us_host / max(us_fused, 1e-9))

    # load generator: closed loop at the pool's concurrency
    eng = DecodeEngine(model, params, slots=B, cache_len=cache_len,
                       flush_tokens=8)
    wl = Workload(num_requests=8 if quick else 16, arrival="closed",
                  concurrency=B, prompt_lens=(S // 2, S),
                  gen_lens=(G // 2, G), seed=0)
    rep = run_load(eng, wl, cfg.vocab_size)
    emit("serving/loadgen_tok_per_s", rep["wall_s"] * 1e6,
         rep["tok_per_s"])
    emit("serving/latency_p50", rep["p50_s"] * 1e6, 0.0)
    emit("serving/latency_p99", rep["p99_s"] * 1e6, 0.0)
    emit("serving/occupancy", rep["wall_s"] * 1e6, rep["occupancy"])

    # hot-swap stall: publish a newer round under live traffic
    tmp = tempfile.mkdtemp(prefix="bench_serving_ckpt_")
    try:
        from repro.checkpoint import save
        save(tmp, model.init(jax.random.key(1)), step=1)
        reg = ModelRegistry(tmp, params)
        eng = DecodeEngine(model, params, slots=B, cache_len=cache_len,
                           flush_tokens=4, registry=reg)
        for i in range(B):
            eng.submit(rng.integers(0, cfg.vocab_size, (S,))
                       .astype(np.int32), G)
        eng.step()
        save(tmp, model.init(jax.random.key(2)), step=2)
        eng.run_until_idle()
        m = eng.metrics()
        # swaps counts only the MID-RUN publish (round 1 was the
        # engine's initial version, staged before traffic)
        emit("serving/swap_stall", m["serve_swap_stall_max"] * 1e6,
             float(m["serve_swaps_total"]))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


ALL = {"table1": table1, "table2b": table2b, "table3": table3,
       "table4": table4, "fig4": fig4, "fig5": fig5,
       # convex keeps its own T=40 protocol; kernels/sharded/scenarios/
       # compression ignore rounds
       "convex": lambda rounds: convex(),
       "kernels": kernels,
       "sharded": sharded,
       "scenarios": scenarios,
       "compression": compression,
       "faults": faults,
       "rounds_fused": rounds_fused,
       "fleet": fleet,
       "telemetry": telemetry,
       "serving": serving}


def _write_csv(path: str = "bench_results.csv") -> None:
    """Atomic write: never leave a truncated csv behind."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write("name,us_per_call,derived\n")
        if ROWS:
            f.write("\n".join(ROWS) + "\n")
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--only", default=None,
                    help="comma-separated exact suite names: "
                         + ",".join(ALL))
    args = ap.parse_args()
    rounds = args.rounds or (25 if args.quick else 60)
    only = args.only.split(",") if args.only else None
    if only:
        unknown = [n for n in only if n not in ALL]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; choose from "
                     f"{list(ALL)}")
    print("name,us_per_call,derived")
    for name, fn in ALL.items():
        if only is not None and name not in only:
            continue
        fn(rounds)
    _write_csv()


if __name__ == "__main__":
    main()
