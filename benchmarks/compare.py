"""CI benchmark-regression guard.

Compares ``bench_results.csv`` rows against a committed baseline JSON
(``benchmarks/baseline.json``). For every baseline entry present in the
csv the row must

  * keep its ``derived`` column (kernel max |err| vs the oracle) at or
    below ``max_err``,
  * not regress its cost by more than ``max_regression`` (e.g. 1.25 =
    +25%). When the entry names a ``normalize_by`` row, cost is the
    RATIO us(row) / us(normalize_by) from the SAME run — runner speed
    cancels out, so the guard is meaningful across CI machines; the raw
    us_per_call is only reported.

A baseline row whose key is MISSING from the results csv is an advisory
warning, not a failure: newly added baseline rows must not brick result
files produced by older benchmark runs (or by ``--only`` subsets).
Entries may carry ``"level": "soft"`` — their breaches are also
advisory-only, even in hard mode (used for fresh scenario rows whose
baselines haven't stabilized across runners yet).

Modes: ``hard`` exits 1 on any (non-advisory) violation (pinned-jax CI
leg), ``soft`` prints violations but exits 0 (latest-jax leg), ``off``
skips entirely.

  python -m benchmarks.compare bench_results.csv benchmarks/baseline.json \
      --mode hard
"""
from __future__ import annotations

import argparse
import json
import sys


def read_results(path: str):
    rows = {}
    with open(path) as f:
        header = f.readline()
        if not header.startswith("name,"):
            raise SystemExit(f"{path}: not a bench_results csv")
        for line in f:
            line = line.strip()
            if not line:
                continue
            name, us, derived = line.split(",")
            rows[name] = (float(us), float(derived))
    return rows


def check(results: dict, baseline: dict):
    """-> (violations, advisories, report_lines).

    Missing rows are always advisory; entries with ``level: soft`` route
    ALL their breaches to advisories."""
    violations, advisories, report = [], [], []
    for name, spec in baseline.items():
        soft = spec.get("level") == "soft"
        sink = advisories if soft else violations
        if name not in results:
            advisories.append(f"{name}: row missing from results "
                              f"(skipped)")
            continue
        us, derived = results[name]
        max_err = spec.get("max_err")
        if max_err is not None and derived > max_err:
            sink.append(f"{name}: derived {derived:g} > "
                        f"max_err {max_err:g}")
        norm = spec.get("normalize_by")
        if norm is not None:
            if norm not in results:
                advisories.append(f"{name}: normalize_by row {norm!r} "
                                  f"missing from results (skipped)")
                continue
            cost, base = us / results[norm][0], spec["ratio"]
            kind = f"ratio vs {norm}"
        else:
            cost, base = us, spec["us_per_call"]
            kind = "us_per_call"
        limit = base * spec.get("max_regression", 1.25)
        line = (f"{name}: {kind} {cost:.4g} (baseline {base:.4g}, "
                f"limit {limit:.4g}, raw {us:.0f}us"
                + (", soft" if soft else "") + ")")
        report.append(line)
        if cost > limit:
            sink.append(f"{name}: {kind} {cost:.4g} regressed past "
                        f"{limit:.4g} (baseline {base:.4g})")
    return violations, advisories, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results")
    ap.add_argument("baseline")
    ap.add_argument("--mode", choices=["hard", "soft", "off"],
                    default="hard")
    args = ap.parse_args()
    if args.mode == "off":
        print("bench guard: off")
        return
    with open(args.baseline) as f:
        baseline = json.load(f)
    violations, advisories, report = check(read_results(args.results),
                                           baseline)
    for line in report:
        print("bench guard:", line)
    for a in advisories:
        print("bench guard ADVISORY:", a)
    for v in violations:
        print("bench guard VIOLATION:", v)
    if violations and args.mode == "hard":
        sys.exit(1)
    print(f"bench guard: {'soft-' if violations else ''}ok "
          f"({len(report)} rows checked, {len(advisories)} advisories, "
          f"mode={args.mode})")


if __name__ == "__main__":
    main()
