"""CI benchmark-regression guard.

Compares ``bench_results.csv`` rows against a committed baseline JSON
(``benchmarks/baseline.json``). For every baseline entry present in the
csv the row must

  * keep its ``derived`` column (kernel max |err| vs the oracle) at or
    below ``max_err``,
  * not regress its cost by more than ``max_regression`` (e.g. 1.25 =
    +25%). When the entry names a ``normalize_by`` row, cost is the
    RATIO us(row) / us(normalize_by) from the SAME run — runner speed
    cancels out, so the guard is meaningful across CI machines; the raw
    us_per_call is only reported.

Breaches are bucketed three ways and the run ends with ONE
machine-readable summary line (``bench guard summary: {...json...}``
with hard/soft/advisory counts — CI and humans parse the same line):

  * hard     — breaches of normal entries; the only bucket that can
               fail the run (exit 1, mode=hard only)
  * soft     — breaches of entries marked ``"level": "soft"`` (fresh
               scenario/faults rows whose baselines haven't stabilized
               across runners yet); always advisory-only
  * advisory — rows missing from the csv (newly added baseline rows
               must not brick older result files or ``--only``
               subsets), malformed csv lines, and baseline entries that
               error while being checked (each entry is evaluated in
               its own try/except, so one bad row cannot take down the
               whole guard)

Modes: ``hard`` exits 1 on any hard breach (pinned-jax CI leg),
``soft`` prints breaches but exits 0 (latest-jax leg), ``off`` skips
entirely.

  python -m benchmarks.compare bench_results.csv benchmarks/baseline.json \
      --mode hard
"""
from __future__ import annotations

import argparse
import json
import sys


def read_results(path: str):
    """-> (rows, parse_advisories). Malformed lines are reported, not
    fatal: a partially written csv should degrade to advisories."""
    rows, bad = {}, []
    with open(path) as f:
        header = f.readline()
        if not header.startswith("name,"):
            raise SystemExit(f"{path}: not a bench_results csv")
        for ln, line in enumerate(f, start=2):
            line = line.strip()
            if not line:
                continue
            try:
                name, us, derived = line.split(",")
                rows[name] = (float(us), float(derived))
            except ValueError:
                bad.append(f"{path}:{ln}: malformed row {line!r} "
                           f"(skipped)")
    return rows, bad


def _check_entry(name, spec, results):
    """-> (breach_msgs, advisory_msgs, report_line_or_None) for ONE
    baseline entry."""
    breaches, advisories = [], []
    if name not in results:
        return [], [f"{name}: row missing from results (skipped)"], None
    us, derived = results[name]
    max_err = spec.get("max_err")
    if max_err is not None and derived > max_err:
        breaches.append(f"{name}: derived {derived:g} > "
                        f"max_err {max_err:g}")
    norm = spec.get("normalize_by")
    if norm is not None:
        if norm not in results:
            advisories.append(f"{name}: normalize_by row {norm!r} "
                              f"missing from results (skipped)")
            return breaches, advisories, None
        cost, base = us / results[norm][0], spec["ratio"]
        kind = f"ratio vs {norm}"
    else:
        cost, base = us, spec["us_per_call"]
        kind = "us_per_call"
    limit = base * spec.get("max_regression", 1.25)
    line = (f"{name}: {kind} {cost:.4g} (baseline {base:.4g}, "
            f"limit {limit:.4g}, raw {us:.0f}us"
            + (", soft" if spec.get("level") == "soft" else "") + ")")
    if cost > limit:
        breaches.append(f"{name}: {kind} {cost:.4g} regressed past "
                        f"{limit:.4g} (baseline {base:.4g})")
    return breaches, advisories, line


def check(results: dict, baseline: dict):
    """-> (hard, soft, advisories, report_lines).

    Entries with ``level: soft`` route ALL their breaches to the soft
    bucket; missing rows and per-entry evaluation errors are advisory.
    Only the hard bucket can fail the run."""
    hard, soft, advisories, report = [], [], [], []
    for name, spec in baseline.items():
        try:
            breaches, advs, line = _check_entry(name, spec, results)
        except Exception as e:  # one bad entry must not kill the guard
            advisories.append(f"{name}: entry check errored "
                              f"({e.__class__.__name__}: {e}) — "
                              f"advisory only")
            continue
        advisories.extend(advs)
        (soft if spec.get("level") == "soft" else hard).extend(breaches)
        if line is not None:
            report.append(line)
    return hard, soft, advisories, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results")
    ap.add_argument("baseline")
    ap.add_argument("--mode", choices=["hard", "soft", "off"],
                    default="hard")
    ap.add_argument("--summary-out", default=None,
                    help="also write the JSON guard summary to this "
                         "path")
    args = ap.parse_args()
    if args.mode == "off":
        print("bench guard: off")
        return
    with open(args.baseline) as f:
        baseline = json.load(f)
    results, parse_advs = read_results(args.results)
    hard, soft, advisories, report = check(results, baseline)
    advisories = parse_advs + advisories
    for line in report:
        print("bench guard:", line)
    for a in advisories:
        print("bench guard ADVISORY:", a)
    for s in soft:
        print("bench guard SOFT:", s)
    for v in hard:
        print("bench guard VIOLATION:", v)
    summary = {"mode": args.mode, "rows_checked": len(report),
               "hard": len(hard), "soft": len(soft),
               "advisory": len(advisories),
               "ok": not (hard and args.mode == "hard")}
    print("bench guard summary:", json.dumps(summary, sort_keys=True))
    if args.summary_out:
        with open(args.summary_out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
    if hard and args.mode == "hard":
        sys.exit(1)


if __name__ == "__main__":
    main()
