"""Federation scenario engine: device-side client sampling, compute
heterogeneity, and async buffered aggregation (see ROADMAP §Scenarios).

  schedulers    — who participates (uniform / size-weighted / zipf /
                  cyclic), drawn with JAX PRNG so cohort selection can
                  live inside the jitted round.
  heterogeneity — how many local steps each client manages (K_c ≤ K_max),
                  lowered as per-step lane masks on the flat engine.
  buffer        — FedBuff-style server-side delta buffer with staleness-
                  weighted merges into any ServerOpt.
  faults        — deterministic fault injection (drops, NaN grads,
                  byzantine deltas, over-staleness) + the RobustAgg
                  server-aggregation ladder (mean/clip/trimmed/median).
  scenarios     — named presets bundling all axes, threaded through
                  FLConfig / fed_round / launch / benchmarks.
  arena         — fleet-scale per-REGISTERED-client state (EF21, Δ-SGD η
                  carry, participation history) in (C_registered, ...)
                  device storage; rounds gather only the sampled
                  cohort's rows and scatter them back (see
                  docs/ARCHITECTURE.md §Fleet arena).
"""
from repro.federation.arena import (ClientArena, arena_init,
                                    arena_shardings, arena_take,
                                    arena_update)
from repro.federation.buffer import (AsyncBufferState, buffer_init,
                                     buffer_merge, buffer_step,
                                     staleness_weights)
from repro.federation.faults import (ROBUST_AGG_KINDS, FaultLanes,
                                     FaultModel, RobustAgg,
                                     robust_aggregate,
                                     robust_aggregate_sharded)
from repro.federation.heterogeneity import (SPEED_MODELS, SpeedModel,
                                            active_mask, step_active)
from repro.federation.schedulers import (SCHEDULERS, CyclicScheduler,
                                         Scheduler, SizeWeightedScheduler,
                                         UniformScheduler, ZipfScheduler,
                                         cohort_size, make_scheduler)
from repro.federation.scenarios import SCENARIOS, Scenario, get_scenario

__all__ = [
    "AsyncBufferState", "buffer_init", "buffer_merge", "buffer_step",
    "staleness_weights", "SPEED_MODELS", "SpeedModel", "active_mask",
    "step_active", "SCHEDULERS", "Scheduler", "UniformScheduler",
    "SizeWeightedScheduler", "ZipfScheduler", "CyclicScheduler",
    "cohort_size", "make_scheduler", "SCENARIOS", "Scenario",
    "get_scenario", "ROBUST_AGG_KINDS", "FaultLanes", "FaultModel",
    "RobustAgg", "robust_aggregate", "robust_aggregate_sharded",
    "ClientArena", "arena_init", "arena_take", "arena_update",
    "arena_shardings",
]
