"""Fleet-scale client-state arena: persistent per-REGISTERED-client
state, keyed by client id.

The round engine only ever materializes the sampled cohort — a (C, ...)
slab for C = |S_t| clients. At fleet scale (C_registered >> C) the
per-client state that must SURVIVE the rounds a client sits out — its
EF21 error-feedback reconstruction, its last Δ-SGD step size, its
participation history — cannot live in those cohort slots: slot c
belongs to a different client every round. The arena keys that state by
registered client id instead:

  * storage is (C_registered, ...) device arrays — optionally sharded
    over the mesh's client axes (``arena_shardings``), so fleet state
    scales across devices, never through the host;
  * the gather/scatter contract: each round the loop draws the cohort
    ids (the SAME Gumbel-top-k draw the data pipeline uses), gathers
    ONLY those C rows on device (``arena_take``), runs the round body on
    the cohort slab, and scatters the updated rows back
    (``arena_update``). Rows of clients not in the cohort are never
    read or written — a never-sampled client's state stays bit-identical
    (property-tested in tests/test_fleet.py);
  * memory ceiling: with error feedback OFF the arena holds only O(C_registered)
    scalars per client — no (C_registered, N) buffer ever exists
    (machine-checked by ``repro.sharding.hlo
    .assert_cohort_only_materialization`` on the compiled fleet loop).
    EF21 adds the one (C_registered, N) f32 buffer the algorithm itself
    requires (Richtárik et al.: g_c persists per client).

Fields:
  eta         (C_reg,) f32   — last round-end Δ-SGD η (init η₀). The
                               "Δ-SGD carry": with ``eta_carry=True``
                               the fleet loop warm-starts a returning
                               client's η₀ from it (a locally-adaptive
                               extension in the spirit of Mukherjee et
                               al.; default OFF keeps Alg. 1's per-round
                               reset bit-exact).
  rounds_seen (C_reg,) int32 — participation count (0 = never sampled).
  last_round  (C_reg,) int32 — round of last participation (−1 before
                               the first). ``round − last_round`` is the
                               client's REALIZED staleness — the
                               async-buffer slot the FedBuff telemetry
                               reads, as opposed to the drawn staleness
                               of the scenario.
  ef          (C_reg, N) f32 — EF21 reconstruction per registered
                               client (only allocated under
                               error-feedback compression).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class ClientArena(NamedTuple):
    eta: jax.Array                      # (C_reg,) f32
    rounds_seen: jax.Array              # (C_reg,) int32
    last_round: jax.Array               # (C_reg,) int32, -1 = never
    ef: Optional[jax.Array] = None      # (C_reg, N) f32 or None


def arena_init(num_registered: int, *, eta0: float,
               ef_width: Optional[int] = None) -> ClientArena:
    """Fresh arena for ``num_registered`` clients. ``ef_width`` (the
    flat layout's padded_size) allocates the (C_reg, N) EF21 buffer —
    leave None unless the run uses error-feedback compression: it is
    the ONLY field whose memory scales with C_registered × N."""
    ef = (jnp.zeros((num_registered, ef_width), jnp.float32)
          if ef_width is not None else None)
    return ClientArena(
        jnp.full((num_registered,), eta0, jnp.float32),
        jnp.zeros((num_registered,), jnp.int32),
        jnp.full((num_registered,), -1, jnp.int32),
        ef)


def arena_take(arena: ClientArena, ids: jax.Array) -> ClientArena:
    """Gather the sampled cohort's rows: (C,) ids -> a cohort-sized
    ClientArena view. O(C) output — the (C_reg, ...) storage is indexed,
    never copied wholesale."""
    return jax.tree.map(lambda a: a[ids], arena)


def arena_update(arena: ClientArena, ids: jax.Array,
                 rows: ClientArena) -> ClientArena:
    """Scatter updated cohort rows back. Only the ``ids`` rows change;
    every other registered client's state is bit-identical (``.at[].set``
    leaves unindexed rows untouched). With duplicate ids (never produced
    by the without-replacement schedulers) the last write wins."""
    return jax.tree.map(lambda a, r: a.at[ids].set(r), arena, rows)


def arena_shardings(arena: ClientArena, mesh, federation):
    """NamedShardings placing arena rows over the mesh's CLIENT axes —
    the device-sharded storage layout (``jax.device_put(arena, these)``).
    Vectors shard their only axis; the EF buffer shards rows and keeps N
    replicated (the fleet loop gathers cohort rows across shards, which
    XLA lowers to an O(C·N) gather — EF + meshes beyond that is the
    per-round sharded engine's job)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as PS
    ca, _ = federation.flat_axes(mesh)
    entry = ca if ca else None
    return jax.tree.map(
        lambda a: NamedSharding(
            mesh, PS(entry) if a.ndim == 1 else PS(entry, None)),
        arena)
