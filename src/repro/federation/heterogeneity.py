"""Compute heterogeneity: per-client local step counts K_c ≤ K_max.

Real cohorts do not run in lockstep — "the computing power of each client
can greatly vary" is half of the paper's motivation for a tuning-free
client optimizer. The scenario engine models it as a per-round draw of
step counts ``K_c ∈ [K_min, K_max]`` per client, lowered onto the round
engines as **per-step lane masks**: the (C, N) flat buffer keeps its
fixed shape through the K_max-step ``lax.scan``, and a client that has
finished its K_c steps simply rides along with η forced to 0 — its lanes
are dead but cost no extra kernel launches (the fused apply already takes
a per-client η vector, so masking is free).

Speed models:
  fixed      — K_c = K_max for everyone (the synchronous baseline; this
               model produces NO masks, so the engines take the exact
               seed code path).
  uniform    — K_c ~ U{K_min, …, K_max} iid per client per round.
  stragglers — a Bernoulli(straggler_frac) subset runs only K_min steps,
               the rest run K_max (the classic fast/slow device split).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

SPEED_MODELS = ("fixed", "uniform", "stragglers")


@dataclass(frozen=True)
class SpeedModel:
    kind: str = "fixed"
    k_min_frac: float = 0.25     # K_min = max(1, round(k_min_frac·K_max))
    straggler_frac: float = 0.3  # P(slow) under ``stragglers``

    def __post_init__(self):
        if self.kind not in SPEED_MODELS:
            raise KeyError(f"unknown speed model {self.kind!r}")

    @property
    def heterogeneous(self) -> bool:
        return self.kind != "fixed"

    def k_min(self, k_max: int) -> int:
        return max(1, min(k_max, int(round(self.k_min_frac * k_max))))

    def draw(self, key, num_clients: int, k_max: int) -> jax.Array:
        """(C,) int32 step counts in [K_min, K_max] (all K_max if fixed)."""
        if self.kind == "fixed":
            return jnp.full((num_clients,), k_max, jnp.int32)
        k_min = self.k_min(k_max)
        if self.kind == "uniform":
            return jax.random.randint(key, (num_clients,), k_min,
                                      k_max + 1, jnp.int32)
        slow = jax.random.bernoulli(key, self.straggler_frac,
                                    (num_clients,))
        return jnp.where(slow, jnp.int32(k_min), jnp.int32(k_max))


def step_active(step_idx, step_counts: jax.Array) -> jax.Array:
    """(C,) bool: is each client still running at local step ``step_idx``?

    Step counts are PREFIX masks — client c runs steps 0..K_c−1 and then
    stays frozen, so inactivity is terminal within a round. The engines
    rely on this: a frozen client's stale Δ-SGD norm state can never leak
    back into an applied update, because its η is forced to 0 at every
    later step.
    """
    return jnp.asarray(step_idx, jnp.int32) < step_counts


def active_mask(step_counts: jax.Array, k_max: int) -> jax.Array:
    """(C, K_max) f32 mask, mask[c, k] = 1.0 iff k < K_c. Used to weight
    per-step losses so metrics only average over steps that really ran."""
    k = jnp.arange(k_max, dtype=jnp.int32)
    return (k[None, :] < step_counts[:, None]).astype(jnp.float32)
