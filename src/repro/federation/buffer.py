"""Async buffered aggregation (FedBuff-style, Nguyen et al. 2022).

Synchronous FedAvg stalls every round on its slowest client. FedBuff
instead lets clients report whenever they finish: the server accumulates
STALENESS-WEIGHTED deltas in a buffer and only takes a server step once
``M`` client updates have arrived. This module is the server half of that
protocol, simulated fully on device with fixed shapes:

  * every round, the C cohort clients contribute ``Δ_c = x_c^K − x_t``
    with a per-client staleness ``s_c`` (rounds their result has been in
    flight, drawn by the scenario) and weight ``w(s_c) = (1+s_c)^{−a}``
    — FedBuff's polynomial staleness discount;
  * the buffer carries the weighted delta SUM as a pytree shaped like the
    params (layout-independent: it survives mesh/shard changes and
    checkpoints like any other server state) plus scalar weight/count/
    staleness accumulators;
  * once ``count ≥ M`` the buffered pseudo-average is handed to ANY
    ``ServerOpt`` as the round's "client mean" (FedAvg applies it
    directly; FedAdam/FedYogi treat it as the pseudo-gradient), and the
    buffer resets. Both branches run under ``lax.cond`` so the round
    stays one fixed jitted program.

With staleness ≡ 0 and M = C the flush happens every round with unit
weights, and the pseudo-average IS the plain client mean — the async
path then reproduces synchronous FedAvg (parity-tested).

Delta compression (repro.compression): under a compressed round the
engine hands ``buffer_merge`` the staleness-weighted sum of the
DEQUANTIZED reconstructions Δ̂_c — compression happens on the client
side of the wire, so the buffer always accumulates dense f32 deltas and
the staleness weights (and every flush rule below) are unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AsyncBufferState(NamedTuple):
    delta: Any              # pytree like params, f32: Σ_c w(s_c)·Δ_c
    weight: jax.Array       # scalar f32: Σ_c w(s_c)
    count: jax.Array        # int32: client updates buffered since flush
    stale_sum: jax.Array    # f32: Σ s_c since flush (metrics)
    stale_max: jax.Array    # f32: max s_c since flush (metrics)


def buffer_init(params) -> AsyncBufferState:
    delta = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    z = jnp.asarray(0.0, jnp.float32)
    return AsyncBufferState(delta, z, jnp.asarray(0, jnp.int32), z, z)


def staleness_weights(staleness: jax.Array, exponent: float) -> jax.Array:
    """FedBuff polynomial discount w(s) = (1+s)^(−a), (C,) f32."""
    s = staleness.astype(jnp.float32)
    return jnp.power(1.0 + s, -float(exponent))


def buffer_merge(buf: AsyncBufferState, delta_sum, weight_sum,
                 num_updates, staleness) -> AsyncBufferState:
    """Fold one cohort's pre-weighted delta SUM into the buffer.

    ``delta_sum`` is Σ_c w(s_c)·Δ_c (pytree like params, f32) — the round
    engine computes it as one reduction over the packed client axis, so
    the merge itself is a param-sized axpy.
    """
    delta = jax.tree.map(lambda a, b: a + b, buf.delta, delta_sum)
    s = staleness.astype(jnp.float32)
    return AsyncBufferState(
        delta, buf.weight + weight_sum,
        buf.count + jnp.asarray(num_updates, jnp.int32),
        buf.stale_sum + jnp.sum(s),
        jnp.maximum(buf.stale_max, jnp.max(s)))


def buffer_step(params, server_state, buf: AsyncBufferState, server_opt,
                buffer_size: int):
    """Flush if ``count ≥ M``, else hold. Returns
    ``(params, server_state, buffer, flushed)`` with fixed structure.

    The flush hands the server optimizer ``x_t + Σ w·Δ / Σ w`` — exactly
    the "client mean" a synchronous round would supply, so every ServerOpt
    (FedAvg/FedAvgM/FedAdam/FedYogi) works unmodified.
    """
    def flush(_):
        mean = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32)
                          + d / jnp.maximum(buf.weight, 1e-12)
                          ).astype(p.dtype), params, buf.delta)
        new_p, new_s = server_opt.update(params, mean, server_state)
        return new_p, new_s, buffer_init(params), jnp.float32(1.0)

    def hold(_):
        return params, server_state, buf, jnp.float32(0.0)

    return jax.lax.cond(buf.count >= buffer_size, flush, hold, None)
