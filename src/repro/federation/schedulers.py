"""Participation schedulers: who is in the cohort S_t, drawn on device.

The paper motivates Δ-SGD by heterogeneity FL must absorb — "the
distribution of local data, participation rate, and computing power of
each client can greatly vary". The seed repo sampled cohorts with a
host-side ``np.random`` draw, which (a) hard-codes uniform participation
and (b) keeps cohort selection outside the jitted round. Every scheduler
here is a pure JAX function of ``(key, round_idx)`` with a fixed cohort
size, so the draw can run inside ``jax.jit`` (and later inside a
multi-round ``lax.scan``); ``data/pipeline.py sample_round`` calls the
same function on host and gathers the selected clients' data, so the ids
the jitted round reports and the data it consumes always agree.

Sampling is without replacement via the Gumbel-top-k trick: adding iid
Gumbel noise to log-weights and taking the top C indices draws C distinct
clients with probability proportional to their weights (Vieira 2014) —
one fused ``top_k``, no sequential rejection loop, jit/vmap/scan safe.

Schedulers:
  uniform       — every client equally likely (the paper's protocol).
  size_weighted — P(i) ∝ n_i local samples (cross-device deployments
                  where bigger shards check in more often).
  zipf          — P(i) ∝ (i+1)^(−s): a heavy-tailed availability skew,
                  the classic "popular devices dominate" regime.
  cyclic        — only a rotating window of clients is available each
                  round (diurnal availability); uniform inside the
                  window.

Fleet scale: every scheduler is O(num_clients) in ONE device vector —
the (m,) log-weights plus the Gumbel noise — with no O(m) host-side
materialization (size weights are stored as device/numpy arrays, zipf
and cyclic weights are computed by ``arange`` on device), so
``num_clients`` here is C_REGISTERED and 10^5+ candidates draw in a
single fused ``top_k``. The fleet loop (core.fed_loop.make_fleet_loop)
calls ``sample`` inside its scanned round; tests/test_fleet.py bounds
the draw's jaxpr buffers at O(m).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def cohort_size(participation: float, num_clients: int) -> int:
    """|S_t| = round(p·m), floored at 1 — the ONE place this is computed.

    (The seed repo truncated in ``FLConfig.clients_per_round`` but rounded
    in ``data/pipeline.py``; p=0.15, m=10 gave cohorts of 1 or 2 depending
    on the caller.)
    """
    return max(1, int(round(participation * num_clients)))


def _gumbel_top_k(key, log_w: jax.Array, k: int) -> jax.Array:
    """k distinct indices ~ P(i) ∝ exp(log_w[i]), via Gumbel-top-k."""
    g = jax.random.gumbel(key, log_w.shape, jnp.float32)
    _, ids = jax.lax.top_k(log_w + g, k)
    return ids.astype(jnp.int32)


@dataclass(frozen=True)
class Scheduler:
    """Protocol + base: subclasses define ``log_weights(round_idx)``.

    ``sample(key, round_idx)`` folds the round index into the key, so one
    base key yields an independent, reproducible draw per round — the
    host pipeline and the jitted round call it with the same (key, t) and
    get the same cohort.
    """
    num_clients: int
    cohort: int
    name: str = "uniform"

    def __post_init__(self):
        if not (1 <= self.cohort <= self.num_clients):
            raise ValueError(f"cohort {self.cohort} must be in "
                             f"[1, {self.num_clients}]")

    def log_weights(self, round_idx) -> jax.Array:
        del round_idx
        return jnp.zeros((self.num_clients,), jnp.float32)

    def sample(self, key, round_idx) -> jax.Array:
        """(cohort,) distinct int32 client ids for round ``round_idx``."""
        key = jax.random.fold_in(key, round_idx)
        return _gumbel_top_k(key, self.log_weights(round_idx), self.cohort)


@dataclass(frozen=True)
class UniformScheduler(Scheduler):
    name: str = "uniform"


@dataclass(frozen=True)
class SizeWeightedScheduler(Scheduler):
    """P(i) ∝ n_i. ``sizes`` is the (m,) per-client sample-count vector,
    kept as a device (or numpy) array so a 10^5-client fleet never
    round-trips through a Python tuple — it is excluded from eq/hash
    (``compare=False``): schedulers are constructed at trace time by the
    engines, never used as static jit arguments."""
    sizes: object = field(default=(), compare=False)
    name: str = "size_weighted"

    def __post_init__(self):
        super().__post_init__()
        if len(self.sizes) != self.num_clients:
            raise ValueError(f"sizes has {len(self.sizes)} entries for "
                             f"{self.num_clients} clients")

    def log_weights(self, round_idx) -> jax.Array:
        del round_idx
        s = jnp.asarray(self.sizes, jnp.float32)
        return jnp.log(jnp.maximum(s, 1e-6))


@dataclass(frozen=True)
class ZipfScheduler(Scheduler):
    """P(i) ∝ (i+1)^(−s): client 0 is the most available, the tail barely
    participates. s≈1.2 matches common device-availability fits."""
    s: float = 1.2
    name: str = "zipf"

    def log_weights(self, round_idx) -> jax.Array:
        del round_idx
        ranks = jnp.arange(1, self.num_clients + 1, dtype=jnp.float32)
        return -self.s * jnp.log(ranks)


@dataclass(frozen=True)
class CyclicScheduler(Scheduler):
    """Rotating availability window: at round t only clients with
    ``(i − t·stride) mod m < window`` are up; the cohort is drawn
    uniformly among them. ``window ≥ cohort`` is enforced so the draw
    never has to pick an unavailable (−inf weight) client."""
    window_frac: float = 0.25
    name: str = "cyclic"

    @property
    def window(self) -> int:
        return max(self.cohort,
                   int(round(self.window_frac * self.num_clients)))

    @property
    def stride(self) -> int:
        return max(1, self.window // 2)

    def log_weights(self, round_idx) -> jax.Array:
        i = jnp.arange(self.num_clients, dtype=jnp.int32)
        start = (jnp.asarray(round_idx, jnp.int32) * self.stride) \
            % self.num_clients
        avail = ((i - start) % self.num_clients) < self.window
        return jnp.where(avail, 0.0, -jnp.inf)


def make_scheduler(kind: str, *, num_clients: int, cohort: int,
                   sizes: Optional[np.ndarray] = None,
                   zipf_s: float = 1.2, window_frac: float = 0.25):
    """Scheduler factory shared by the data pipeline and the round engine."""
    if kind == "uniform":
        return UniformScheduler(num_clients, cohort)
    if kind == "size_weighted":
        if sizes is None:
            # no size information (synthetic / in-round reporting): the
            # draw degrades to uniform, which is exactly P(i) ∝ equal n_i
            return UniformScheduler(num_clients, cohort,
                                    name="size_weighted")
        # keep device arrays on device; anything host-side becomes ONE
        # numpy array (no per-element Python loop at fleet scale)
        if not isinstance(sizes, (jax.Array, np.ndarray)):
            sizes = np.asarray(sizes, np.float32)
        return SizeWeightedScheduler(num_clients, cohort, sizes=sizes)
    if kind == "zipf":
        return ZipfScheduler(num_clients, cohort, s=zipf_s)
    if kind == "cyclic":
        return CyclicScheduler(num_clients, cohort, window_frac=window_frac)
    raise KeyError(f"unknown scheduler kind {kind!r}")


SCHEDULERS = ("uniform", "size_weighted", "zipf", "cyclic")
