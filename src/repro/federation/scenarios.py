"""Scenario registry: named (participation × compute × aggregation ×
bandwidth) regimes.

A ``Scenario`` bundles the heterogeneity axes the paper names — data
distribution (a Dirichlet-α hint for the data pipeline), participation
(a scheduler kind), computing power (a speed model) — plus the
aggregation discipline (synchronous FedAvg vs FedBuff-style async
buffering) and the BANDWIDTH axis: per-client delta-compression levels
drawn per round exactly like K_c on the compute axis
(repro.compression.LEVELS ladder: 0=none, 1=int8, 2=topk). It is a
frozen, hashable config object: the round engine closes over it, and
all of its randomness flows from ``fold_in(key(seed), round)`` so host
pipeline and jitted round agree.

The full preset table lives in docs/SCENARIOS.md — GENERATED from the
``SCENARIOS`` registry below by scripts/gen_docs.py (CI regenerates it
and fails on drift), so this docstring does not duplicate it.

``dirichlet_dropouts`` / ``byzantine_async`` are the CHAOS presets,
adding the FAULT axis
(repro.federation.faults): ``dirichlet_dropouts`` loses 30% of each
cohort mid-round and corrupts 5% with NaN gradients (quorum Q=2);
``byzantine_async`` flips/scales 10% of deltas by −10× and over-stales
10% of async updates, defended by clip aggregation (quorum Q=2).

Fleet presets (``fleet_uniform`` / ``fleet_zipf``): the cross-device
regime the fleet arena targets — C_registered >> C_cohort with
``participation_hint`` suggesting a sub-percent sampling rate (drivers
apply it when FLConfig doesn't pin one), uniform vs heavy-tailed zipf
availability over the registered fleet, and compute heterogeneity on.
They carry no fault axis: the fleet loop runs every un-meshed engine
feature, but fleet-scale robustness stays the per-round engines' job.

``sync_iid`` is the exact seed configuration: fixed speed emits no masks
and sync aggregation takes the unmodified round tail, so it reproduces
the pre-scenario engines bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.compression.spec import LEVELS
from repro.federation.faults import FaultLanes, FaultModel, RobustAgg
from repro.federation.heterogeneity import SpeedModel
from repro.federation.schedulers import make_scheduler

# size of the compression-level ladder (none < int8 < topk); tier_probs
# must match it and every bandwidth draw stays inside it
_NUM_LEVELS = len(LEVELS)


@dataclass(frozen=True)
class Scenario:
    name: str
    # participation
    scheduler: str = "uniform"       # uniform|size_weighted|zipf|cyclic
    zipf_s: float = 1.2
    window_frac: float = 0.25        # cyclic availability window
    # compute heterogeneity
    speed: str = "fixed"             # fixed|uniform|stragglers
    k_min_frac: float = 0.25
    straggler_frac: float = 0.3
    # aggregation
    aggregation: str = "sync"        # sync|async
    buffer_size: int = 8             # M (async)
    staleness_max: int = 4           # s_c ~ U{0..staleness_max} (async)
    staleness_exp: float = 0.5       # w(s) = (1+s)^-a (async)
    # bandwidth heterogeneity: per-client delta-compression level over
    # the repro.compression.LEVELS ladder (0=none, 1=int8, 2=topk),
    # drawn per round like K_c. "fixed" = everyone at the run's
    # CompressionSpec.kind (no draw); "uniform" = level ~ U{0..2};
    # "tiered" = categorical over tier_probs (the fleet mix: a few
    # well-connected clients, mostly int8, a top-k tail).
    bandwidth: str = "fixed"         # fixed|uniform|tiered
    tier_probs: tuple = (0.2, 0.5, 0.3)
    # fault axis (repro.federation.faults): per-round, per-client fault
    # draws. All rates default to 0 — the fault-free configuration lowers
    # to the exact legacy round tail.
    drop_rate: float = 0.0           # P(client drops mid-round)
    nan_rate: float = 0.0            # P(client grads go NaN/Inf)
    byzantine_rate: float = 0.0      # P(delta corrupted by scale below)
    byzantine_scale: float = -10.0
    overstale_rate: float = 0.0      # P(async update over-stale)
    overstale: int = 16              # staleness forced on those updates
    # robust server aggregation + graceful degradation
    robust_agg: str = "mean"         # mean|clip|trimmed|median
    clip_norm: float = 10.0          # robust_agg="clip": max ‖Δ_c‖₂
    trim_frac: float = 0.2           # robust_agg="trimmed": cut per end
    quorum: int = 0                  # skip round when < Q valid clients
    # data hint consumed by drivers/benchmarks (not by the round engine)
    alpha: Optional[float] = None
    # fleet hints consumed by drivers/benchmarks (not the round engine):
    # a suggested C_registered and participation rate for the fleet
    # regime (FLConfig.num_registered_clients overrides the first; an
    # explicit --participation overrides the second)
    registered_hint: Optional[int] = None
    participation_hint: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if self.aggregation not in ("sync", "async"):
            raise KeyError(f"unknown aggregation {self.aggregation!r}")
        if self.bandwidth not in ("fixed", "uniform", "tiered"):
            raise KeyError(f"unknown bandwidth model {self.bandwidth!r}")
        if len(self.tier_probs) != _NUM_LEVELS:
            raise ValueError(
                f"tier_probs must have one entry per compression level "
                f"(repro.compression.LEVELS, {_NUM_LEVELS}), got "
                f"{len(self.tier_probs)}")
        if self.quorum < 0:
            raise ValueError(f"quorum must be >= 0, got {self.quorum}")
        SpeedModel(self.speed)  # validates the kind
        self.fault_model        # validates rates
        self.robust_model       # validates kind/clip_norm/trim_frac

    # ---- derived models -------------------------------------------------
    @property
    def speed_model(self) -> SpeedModel:
        return SpeedModel(self.speed, k_min_frac=self.k_min_frac,
                          straggler_frac=self.straggler_frac)

    @property
    def heterogeneous(self) -> bool:
        return self.speed_model.heterogeneous

    @property
    def is_async(self) -> bool:
        return self.aggregation == "async"

    @property
    def bandwidth_heterogeneous(self) -> bool:
        return self.bandwidth != "fixed"

    @property
    def fault_model(self) -> FaultModel:
        return FaultModel(drop_rate=self.drop_rate,
                          nan_rate=self.nan_rate,
                          byzantine_rate=self.byzantine_rate,
                          byzantine_scale=self.byzantine_scale,
                          overstale_rate=self.overstale_rate,
                          overstale=self.overstale)

    @property
    def faulty(self) -> bool:
        return self.fault_model.active

    @property
    def robust_model(self) -> RobustAgg:
        return RobustAgg(kind=self.robust_agg, clip_norm=self.clip_norm,
                         trim_frac=self.trim_frac)

    @property
    def robust(self) -> bool:
        return self.robust_model.robust

    def make_scheduler(self, num_clients: int, cohort: int, sizes=None):
        return make_scheduler(self.scheduler, num_clients=num_clients,
                              cohort=cohort, sizes=sizes,
                              zipf_s=self.zipf_s,
                              window_frac=self.window_frac)

    # ---- per-round draws (jit-safe; round may be traced) ----------------
    def round_key(self, round_idx):
        return jax.random.fold_in(jax.random.key(self.seed), round_idx)

    def draw_step_counts(self, round_idx, num_clients: int,
                         k_max: int) -> jax.Array:
        key = jax.random.fold_in(self.round_key(round_idx), 1)
        return self.speed_model.draw(key, num_clients, k_max)

    def draw_staleness(self, round_idx, num_clients: int) -> jax.Array:
        """(C,) int32 in [0, staleness_max]: rounds each update has been
        in flight when it reaches the server buffer."""
        key = jax.random.fold_in(self.round_key(round_idx), 2)
        if self.staleness_max <= 0:
            return jnp.zeros((num_clients,), jnp.int32)
        return jax.random.randint(key, (num_clients,), 0,
                                  self.staleness_max + 1, jnp.int32)

    def draw_compression_levels(self, round_idx,
                                num_clients: int) -> jax.Array:
        """(C,) int32 bandwidth levels over the repro.compression.LEVELS
        ladder — which compressor each client's uplink can afford this
        round. Only meaningful when ``bandwidth_heterogeneous``; the
        engine passes None (= the run's CompressionSpec.kind) for
        ``bandwidth="fixed"``."""
        key = jax.random.fold_in(self.round_key(round_idx), 3)
        if self.bandwidth == "uniform":
            return jax.random.randint(key, (num_clients,), 0,
                                      _NUM_LEVELS, jnp.int32)
        logits = jnp.log(jnp.asarray(self.tier_probs, jnp.float32))
        return jax.random.categorical(
            key, logits, shape=(num_clients,)).astype(jnp.int32)

    def draw_faults(self, round_idx, num_clients: int,
                    k_max: int) -> FaultLanes:
        """Per-client fault lanes for the round (axis 4 of the round
        key, next to step counts=1 / staleness=2 / bandwidth=3)."""
        key = jax.random.fold_in(self.round_key(round_idx), 4)
        return self.fault_model.draw(key, num_clients, k_max)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    Scenario("sync_iid", alpha=1.0),
    Scenario("sync_dirichlet", alpha=0.1),
    Scenario("size_weighted", scheduler="size_weighted"),
    Scenario("dirichlet_stragglers", speed="stragglers", alpha=0.1),
    Scenario("cyclic_hetero", scheduler="cyclic", speed="uniform"),
    Scenario("zipf_async", scheduler="zipf", speed="uniform",
             aggregation="async", buffer_size=8),
    Scenario("bandwidth_tiered", bandwidth="tiered"),
    Scenario("dirichlet_dropouts", speed="stragglers", alpha=0.1,
             drop_rate=0.3, nan_rate=0.05, quorum=2),
    Scenario("byzantine_async", scheduler="zipf", speed="uniform",
             aggregation="async", buffer_size=8, byzantine_rate=0.1,
             overstale_rate=0.1, robust_agg="clip", quorum=2),
    Scenario("fleet_uniform", speed="uniform", alpha=0.1,
             registered_hint=100_000, participation_hint=0.0005),
    Scenario("fleet_zipf", scheduler="zipf", speed="uniform", alpha=0.1,
             registered_hint=100_000, participation_hint=0.0005),
)}


def get_scenario(name_or_scenario, **overrides) -> Scenario:
    """Resolve a preset by name (or pass a Scenario through), with
    optional field overrides, e.g. ``get_scenario("zipf_async",
    buffer_size=16)``."""
    if isinstance(name_or_scenario, Scenario):
        scn = name_or_scenario
    else:
        try:
            scn = SCENARIOS[name_or_scenario]
        except KeyError:
            raise KeyError(f"unknown scenario {name_or_scenario!r}; "
                           f"presets: {sorted(SCENARIOS)}") from None
    return replace(scn, **overrides) if overrides else scn
