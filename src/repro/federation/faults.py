"""Fault injection + robust server aggregation (the chaos axis).

No fleet of real clients returns only finite, timely, honest updates.
This module gives the scenario engine a deterministic FAULT axis and the
round tail a ROBUST-AGGREGATION ladder so the packed flat engine keeps
training through the failure modes the FL literature catalogues:

  * ``FaultModel`` — per-round, per-client fault draws, all flowing from
    ``fold_in(round_key, 4)`` exactly like the compute/staleness/
    bandwidth axes (repro.federation.scenarios), so host pipeline and
    jitted round agree and every fault is reproducible from (seed,
    round). Four failure modes, each lowered as per-client LANE state
    (η=0 lanes / lane-wise delta scaling) so the flat engine's
    2-launches-per-local-step invariant survives:
      - drop-mid-round: the client dies after ``drop_step < K`` local
        steps and never reports (lane goes inactive, client excluded);
      - NaN/Inf gradient corruption: from a drawn local step on, the
        client's packed gradient lanes are non-finite — caught by the
        in-step numerical guards (repro.core.delta_sgd), which zero the
        lane's η, sanitize its gradient, and latch its ``valid`` flag;
      - byzantine delta corruption: the client's reported round delta is
        scaled/sign-flipped by ``byzantine_scale`` (e.g. −10×) — NOT
        detectable client-side; the robust aggregators defend;
      - async over-staleness: the update arrives staler than the
        scenario's accepted bound and the server rejects it.

  * ``RobustAgg`` — the server-side aggregation ladder over packed
    (C, N) client deltas: ``mean`` (valid-masked mean), ``clip``
    (per-client l2 delta-norm clipping, then mean), ``trimmed``
    (coordinate-wise trimmed mean) and ``median`` (coordinate-wise
    median). Invalid clients (guard-tripped, dropped, rejected) are
    excluded: they carry zero weight under mean/clip and contribute a
    zero delta to the order-statistic aggregators. Under meshes the
    ladder runs inside ``shard_map`` strictly before/with the
    client-mean psum: clip norms finish with a tiny (C_loc,) psum over
    the N-shard axes, and trimmed/median aggregate SHARD-LOCALLY over
    each device's C_loc clients before a (N_loc,) mean across client
    shards (bucketed robust aggregation, Karimireddy et al. style) — so
    the only client-crossing payloads stay (N_loc,)-sized and PR 4's
    no-full-precision-delta wire guarantee keeps holding
    (repro.sharding.hlo.assert_no_fullprec_delta_collective, now with a
    tightenable payload bound).

With no faults drawn and ``kind="mean"`` the round engine never routes
through this module — the fault-free mean path stays bit-exact against
the golden trajectories by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

_RATE_FIELDS = ("drop_rate", "nan_rate", "byzantine_rate",
                "overstale_rate")


class FaultLanes(NamedTuple):
    """One round's per-client fault draws (all (C,))."""
    drop_step: jax.Array    # int32: local step the client dies at;
                            # k_max = runs to completion
    nan_step: jax.Array     # int32: first local step with non-finite
                            # grads; k_max = clean
    byzantine: jax.Array    # bool: delta scaled by byzantine_scale
    overstale: jax.Array    # bool: async update arrives over-stale


@dataclass(frozen=True)
class FaultModel:
    """Deterministic per-round fault injection rates (scenario axis)."""
    drop_rate: float = 0.0          # P(client drops mid-round)
    nan_rate: float = 0.0           # P(client's grads go non-finite)
    byzantine_rate: float = 0.0     # P(client's delta is corrupted)
    byzantine_scale: float = -10.0  # multiplier on corrupted deltas
    overstale_rate: float = 0.0     # P(async update arrives over-stale)
    overstale: int = 16             # staleness assigned to those updates

    def __post_init__(self):
        for f in _RATE_FIELDS:
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")

    @property
    def active(self) -> bool:
        return any(getattr(self, f) > 0.0 for f in _RATE_FIELDS)

    def draw(self, key, num_clients: int, k_max: int) -> FaultLanes:
        """Per-client lanes for one round (jit-safe). Sub-keys are
        folded per fault mode so adding a mode never perturbs the
        others' draws."""
        C = num_clients
        ks = [jax.random.fold_in(key, i) for i in range(4)]
        full = jnp.full((C,), k_max, jnp.int32)

        if self.drop_rate > 0.0:
            dropped = jax.random.bernoulli(
                jax.random.fold_in(ks[0], 0), self.drop_rate, (C,))
            # die strictly mid-round: after >= 1 step when K allows it
            # (K == 1 drops before the only step — nothing to report)
            step = jax.random.randint(
                jax.random.fold_in(ks[0], 1), (C,), 1, max(k_max, 2),
                jnp.int32)
            step = jnp.minimum(step, k_max - 1)
            drop_step = jnp.where(dropped, step, full)
        else:
            drop_step = full

        if self.nan_rate > 0.0:
            corrupt = jax.random.bernoulli(
                jax.random.fold_in(ks[1], 0), self.nan_rate, (C,))
            step = jax.random.randint(
                jax.random.fold_in(ks[1], 1), (C,), 0, k_max, jnp.int32)
            nan_step = jnp.where(corrupt, step, full)
        else:
            nan_step = full

        byz = (jax.random.bernoulli(ks[2], self.byzantine_rate, (C,))
               if self.byzantine_rate > 0.0
               else jnp.zeros((C,), bool))
        over = (jax.random.bernoulli(ks[3], self.overstale_rate, (C,))
                if self.overstale_rate > 0.0
                else jnp.zeros((C,), bool))
        return FaultLanes(drop_step, nan_step, byz, over)


# ---------------------------------------------------------------------------
# robust server aggregation over packed (C, N) client deltas
# ---------------------------------------------------------------------------

ROBUST_AGG_KINDS = ("mean", "clip", "trimmed", "median")


@dataclass(frozen=True)
class RobustAgg:
    """Server aggregation rung over per-client round deltas."""
    kind: str = "mean"          # mean|clip|trimmed|median
    clip_norm: float = 10.0     # clip: max per-client l2 delta norm
    trim_frac: float = 0.2      # trimmed: fraction cut at EACH end

    def __post_init__(self):
        if self.kind not in ROBUST_AGG_KINDS:
            raise KeyError(f"unknown robust aggregation {self.kind!r}; "
                           f"kinds: {ROBUST_AGG_KINDS}")
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(
                f"trim_frac must be in [0, 0.5), got {self.trim_frac}")
        if self.clip_norm <= 0.0:
            raise ValueError(f"clip_norm must be > 0, got {self.clip_norm}")

    @property
    def robust(self) -> bool:
        return self.kind != "mean"

    def trim_count(self, num_clients: int) -> int:
        """Static per-end trim count: floor(trim_frac·C), clamped so at
        least one row survives. ``median`` trims to the middle 1 (odd C)
        or 2 (even C) rows — the coordinate-wise median."""
        C = num_clients
        if self.kind == "median":
            return (C - 1) // 2
        return min(int(self.trim_frac * C), (C - 1) // 2)


def _masked_mean(delta, vw):
    """Σ_c vw_c·Δ_c / Σ_c vw_c with a zero-safe denominator."""
    den = jnp.maximum(jnp.sum(vw), 1e-12)
    return jnp.tensordot(vw, delta, axes=(0, 0)) / den


def _clip_factors(norms, clip_norm):
    """min(1, clip/‖Δ_c‖) per client — zero-delta rows pass through."""
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))


def _sorted_window_mean(zeroed, t: int):
    """Coordinate-wise mean of the sorted rows [t, C−t) — the trimmed
    mean (and, via RobustAgg.trim_count, the median). Invalid rows were
    zeroed by the caller: a zero delta is the 'no contribution' element
    and keeps the sort total over a static C."""
    C = zeroed.shape[0]
    s = jnp.sort(zeroed, axis=0)
    return jnp.mean(s[t:C - t], axis=0)


def robust_aggregate(delta, spec: RobustAgg, valid=None, *,
                     weights=None, backend: str = "xla",
                     interpret: Optional[bool] = None):
    """Aggregate packed (C, N) client deltas -> ((N,) delta, info dict).

    ``valid`` is the per-client (C,) bool survivor mask (guards + drops
    + staleness rejection): invalid clients are excluded — zero weight
    under mean/clip, a zeroed row under trimmed/median. ``weights`` are
    optional client weights (size-weighted FedAvg); order-statistic
    rungs ignore them (a weighted trimmed mean is not a sum — the
    bucketed sharded variant documents the same restriction).
    ``backend="pallas"`` routes trimmed/median through the fused
    bitonic-sort kernel (repro.kernels.robust_agg)."""
    C = delta.shape[0]
    v = (valid.astype(jnp.float32) if valid is not None
         else jnp.ones((C,), jnp.float32))
    zeroed = delta * v[:, None]
    info = {}
    if spec.kind in ("trimmed", "median"):
        t = spec.trim_count(C)
        if backend == "pallas":
            from repro.kernels.robust_agg import robust_agg as k
            if interpret is None:
                interpret = jax.default_backend() != "tpu"
            agg = k.batched_trimmed_mean(zeroed, t, interpret=interpret)
        else:
            agg = _sorted_window_mean(zeroed, t)
        return agg, info
    vw = v if weights is None else v * weights.astype(jnp.float32)
    if spec.kind == "clip":
        norms = jnp.sqrt(jnp.sum(zeroed * zeroed, axis=1))
        factors = _clip_factors(norms, spec.clip_norm)
        info["agg_clip_rate"] = (jnp.sum((factors < 1.0) * v)
                                 / jnp.maximum(jnp.sum(v), 1.0))
        zeroed = zeroed * factors[:, None]
    return _masked_mean(zeroed, vw), info


def robust_aggregate_sharded(delta, spec: RobustAgg, valid, *, mesh,
                             pspec, weights=None):
    """Mesh-native robust aggregation: the (C, N) delta buffer stays
    sharded per ``pspec`` (= FederationSpec.flat_spec(mesh)) and the
    ladder runs inside ``shard_map``. clip's per-client norms finish
    with ONE (C_loc,) psum over the N-shard axes; trimmed/median run
    shard-locally over each device's C_loc clients and the (N_loc,)
    shard aggregates are averaged across client shards (bucketed robust
    aggregation — with one client per shard this degenerates to the
    mean, so production specs should stack >= 2 clients per shard, the
    same requirement the wire-boundary HLO check has). No per-client
    data ever crosses the client shard boundary. Returns
    ((N,) delta, info dict)."""
    from jax.sharding import PartitionSpec as PS
    from repro.core.delta_sgd import _axis_names, _shard_map
    ca = pspec[0] if len(pspec) > 0 else None
    na = pspec[1] if len(pspec) > 1 else None
    c_names, na_names = _axis_names(ca), _axis_names(na)

    def psum_c(x):
        return jax.lax.psum(x, c_names) if c_names else x

    with_w = weights is not None

    def local(d_l, v_l, *rest):
        w_l = rest[0] if with_w else None
        vf = v_l.astype(jnp.float32)
        zeroed = d_l * vf[:, None]
        if spec.kind in ("trimmed", "median"):
            t = spec.trim_count(zeroed.shape[0])
            shard_agg = _sorted_window_mean(zeroed, t)
            n_shards = psum_c(jnp.float32(1.0))
            return psum_c(shard_agg) / n_shards, jnp.float32(0.0)
        vw = vf if w_l is None else vf * w_l.astype(jnp.float32)
        clip_rate = jnp.float32(0.0)
        if spec.kind == "clip":
            n2 = jnp.sum(zeroed * zeroed, axis=1)
            if na_names:
                n2 = jax.lax.psum(n2, na_names)
            factors = _clip_factors(jnp.sqrt(n2), spec.clip_norm)
            nv = jnp.maximum(psum_c(jnp.sum(vf)), 1.0)
            clip_rate = psum_c(jnp.sum((factors < 1.0) * vf)) / nv
            zeroed = zeroed * factors[:, None]
        part = jnp.tensordot(vw, zeroed, axes=(0, 0))
        den = jnp.maximum(psum_c(jnp.sum(vw)), 1e-12)
        return psum_c(part) / den, clip_rate

    ins = [delta, valid] + ([weights] if with_w else [])
    specs = [PS(ca, na), PS(ca)] + ([PS(ca)] if with_w else [])
    fn = _shard_map(local, mesh, tuple(specs), (PS(na), PS()))
    agg, clip_rate = fn(*ins)
    info = {}
    if spec.kind == "clip":
        info["agg_clip_rate"] = clip_rate
    return agg, info
