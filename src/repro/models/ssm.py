"""Recurrent mixers: Mamba2 (SSD, chunked) and xLSTM (mLSTM / sLSTM).

All three expose:
  *_full(params, x, cfg, build_cache=...) -> (y, cache|None)   train/prefill
  *_step(params, x, cfg, cache)           -> (y, cache)        decode (O(1))

Mamba2 follows the SSD chunked algorithm (intra-chunk parallel matmul +
inter-chunk state scan) — the same structure the Pallas kernel
(repro/kernels/mamba2_scan) accelerates. mLSTM uses the stabilized
chunk-summarised form; sLSTM is inherently sequential (lax.scan over time),
which is faithful to the architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, rmsnorm, split_keys


def _chunk_len(S: int, target: int = 64) -> int:
    for c in range(min(target, S), 0, -1):
        if S % c == 0:
            return c
    return 1


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def mamba2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    return d_in, H, P, G, N


def init_mamba2(key, cfg, dtype):
    D = cfg.d_model
    d_in, H, P, G, N = mamba2_dims(cfg)
    conv_ch = d_in + 2 * G * N
    ks = split_keys(key, 6)
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[4], (H,), jnp.float32,
                           np.log(1e-3), np.log(1e-1))
    dt = jnp.exp(u)
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "w_zx": dense_init(ks[0], (D, d_in + conv_ch), dtype, fan_in=D),
        "w_dt": dense_init(ks[1], (D, H), dtype, fan_in=D),
        "dt_bias": dt_bias.astype(dtype),
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, conv_ch), dtype,
                             fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[5], (H,), jnp.float32,
                                            1.0, 16.0)).astype(dtype),
        "D_skip": jnp.ones((H,), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ks[3], (d_in, D), dtype, fan_in=d_in),
    }


def _causal_conv_full(x, w, b):
    """x: (B,S,C) depthwise causal conv, kernel (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssd_chunked(xh, dt, A_log, Bm, Cm, h0=None, chunk=64):
    """SSD scan (Mamba2 Alg. via chunking). xh:(B,S,H,P) dt:(B,S,H)
    A_log:(H,) Bm/Cm:(B,S,G,N).

    h_t = exp(dA_t)·h_{t-1} + dt_t·x_t⊗B_t ;   y_t = C_t·h_t
    intra-chunk term is a masked (L,L) matmul; inter-chunk states scan.

    Returns (y:(B,S,H,P), h_final:(B,H,P,N) fp32).
    """
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = _chunk_len(S, chunk)
    nc = S // L
    rep = H // G
    f32 = jnp.float32
    dA = dt.astype(f32) * (-jnp.exp(A_log.astype(f32)))       # (B,S,H) <= 0

    def rs(t):  # (B,S,...) -> (nc,B,L,...)
        return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    xc = rs(xh.astype(f32))                                   # (nc,B,L,H,P)
    dtc = rs(dt.astype(f32))                                  # (nc,B,L,H)
    Bh = rs(jnp.repeat(Bm.astype(f32), rep, axis=2))          # (nc,B,L,H,N)
    Ch = rs(jnp.repeat(Cm.astype(f32), rep, axis=2))
    cs = jnp.cumsum(rs(dA), axis=2)                           # (nc,B,L,H)

    # intra-chunk: M[q,k] = (C_q·B_k)·exp(cs_q - cs_k)·dt_k for k<=q
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]        # (nc,B,q,k,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("cbqhn,cbkhn->cbqkh", Ch, Bh)
    M = CB * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("cbqkh,cbkhp->cbqhp", M, xc)

    # per-chunk summary state: S_c = sum_k exp(cs_L - cs_k)·dt_k·B_k⊗x_k
    w_end = jnp.exp(cs[:, :, -1:, :] - cs) * dtc              # (nc,B,L,H)
    S_c = jnp.einsum("cbkh,cbkhn,cbkhp->cbhpn", w_end, Bh, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                    # (nc,B,H)

    h0 = (jnp.zeros((B, H, P, N), f32) if h0 is None else h0.astype(f32))

    # State propagation scan carries only elementwise decay+add (cheap —
    # XLA's cost model counts while bodies once, so keep FLOPs outside).
    def body(h, inp):
        s_c, cd = inp
        h_new = cd[:, :, None, None] * h + s_c
        return h_new, h  # emit the PRE-update state seen by this chunk

    h_fin, h_prev = jax.lax.scan(body, h0, (S_c, chunk_decay))
    # Inter-chunk output contribution, vectorised over all chunks at once.
    y_inter = jnp.einsum("cbqhn,cbhpn,cbqh->cbqhp", Ch, h_prev, jnp.exp(cs))
    y = y_intra + y_inter
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, H, P)
    return y.astype(xh.dtype), h_fin


def mamba2_full(params, x, cfg, *, build_cache=False, use_pallas=False):
    B, S, D = x.shape
    d_in, H, P, G, N = mamba2_dims(cfg)
    zx = jnp.einsum("bsd,de->bse", x, params["w_zx"])
    z, xc = zx[..., :d_in], zx[..., d_in:]
    xc = jax.nn.silu(_causal_conv_full(xc, params["conv_w"],
                                       params["conv_b"]))
    xs = xc[..., :d_in].reshape(B, S, H, P)
    Bm = xc[..., d_in:d_in + G * N].reshape(B, S, G, N)
    Cm = xc[..., d_in + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, params["w_dt"])
                         .astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    if use_pallas:
        from repro.kernels.mamba2_scan import ops as m2_ops
        y, h_fin = m2_ops.ssd_scan(xs, dt, params["A_log"], Bm, Cm)
    else:
        y, h_fin = _ssd_chunked(xs, dt, params["A_log"], Bm, Cm)
    y = y + xs * params["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    cache = None
    if build_cache:
        K = cfg.ssm_conv
        conv_ch = d_in + 2 * G * N
        tail = zx[..., d_in:][:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            zx[..., d_in:], ((0, 0), (K - 1 - S, 0), (0, 0)))
        cache = {"ssm": h_fin.astype(x.dtype), "conv": tail}
    return out, cache


def mamba2_step(params, x, cfg, cache):
    """x: (B,1,D). cache: ssm (B,H,P,N) fp-any, conv (B,K-1,conv_ch)."""
    B = x.shape[0]
    d_in, H, P, G, N = mamba2_dims(cfg)
    K = cfg.ssm_conv
    zx = jnp.einsum("bsd,de->bse", x, params["w_zx"])[:, 0]   # (B, ...)
    z, xc_new = zx[..., :d_in], zx[..., d_in:]
    conv_in = jnp.concatenate([cache["conv"], xc_new[:, None, :]], axis=1)
    w = params["conv_w"]
    xc = jnp.einsum("bkc,kc->bc", conv_in, w) + params["conv_b"]
    xc = jax.nn.silu(xc)
    xs = xc[..., :d_in].reshape(B, H, P)
    Bm = xc[..., d_in:d_in + G * N].reshape(B, G, N)
    Cm = xc[..., d_in + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(jnp.einsum("bd,dh->bh", x[:, 0], params["w_dt"])
                         .astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    dA = jnp.exp(dt * (-jnp.exp(params["A_log"].astype(jnp.float32))))
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)      # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    h = cache["ssm"].astype(jnp.float32)
    h = dA[:, :, None, None] * h + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32), Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch).astype(x.dtype)
    y = y + xs * params["D_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("be,ed->bd", y, params["w_out"])[:, None, :]
    return out, {"ssm": h.astype(cache["ssm"].dtype), "conv": conv_in[:, 1:]}


def init_mamba2_cache(cfg, B, dtype):
    d_in, H, P, G, N = mamba2_dims(cfg)
    conv_ch = d_in + 2 * G * N
    return {"ssm": jnp.zeros((B, H, P, N), jnp.float32),
            "conv": jnp.zeros((B, cfg.ssm_conv - 1, conv_ch), dtype)}


# ===========================================================================
# xLSTM — mLSTM (matrix memory)
# ===========================================================================
def mlstm_dims(cfg):
    d_in = 2 * cfg.d_model          # proj factor 2
    H = cfg.num_heads
    d_qk = d_in // 2                # qk_dim_factor 0.5
    return d_in, H, d_qk, d_in // H, d_qk // H


def init_mlstm(key, cfg, dtype):
    D = cfg.d_model
    d_in, H, d_qk, hd_v, hd_k = mlstm_dims(cfg)
    ks = split_keys(key, 7)
    return {
        "w_up": dense_init(ks[0], (D, 2 * d_in), dtype, fan_in=D),
        "wq": dense_init(ks[1], (d_in, d_qk), dtype, fan_in=d_in),
        "wk": dense_init(ks[2], (d_in, d_qk), dtype, fan_in=d_in),
        "wv": dense_init(ks[3], (d_in, d_in), dtype, fan_in=d_in),
        "w_if": dense_init(ks[4], (d_in, 2 * H), dtype, fan_in=d_in),
        "b_if": jnp.concatenate([jnp.zeros((H,)),
                                 jnp.linspace(3.0, 6.0, H)]).astype(dtype),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ks[5], (d_in, D), dtype, fan_in=d_in),
    }


def _mlstm_chunked(q, k, v, i_pre, f_pre, chunk=256):
    """Chunkwise stabilized mLSTM — exactly the recurrent semantics
    (mlstm_step), evaluated L tokens at a time. q,k:(B,S,H,hk) v:(B,S,H,hv),
    i_pre/f_pre:(B,S,H) gate pre-activations.

    Intra-chunk work and the inter-chunk readout are vectorised over chunks;
    the lax.scan carries only the elementwise (C, n, m) state combine.
    Returns (y:(B,S,H,hv), final (C, n, m)) for decode continuation.
    """
    B, S, H, hk = q.shape
    hv = v.shape[-1]
    L = _chunk_len(S, chunk)
    nc = S // L
    f32 = jnp.float32
    q = q.astype(f32)
    k = k.astype(f32) / np.sqrt(hk)
    v = v.astype(f32)
    lf = jax.nn.log_sigmoid(f_pre.astype(f32))
    li = i_pre.astype(f32)

    def rs(t):  # (B,S,...) -> (nc,B,L,...)
        return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    qc, kc, vc, lfc, lic = map(rs, (q, k, v, lf, li))
    g = jnp.cumsum(lfc, axis=2)                       # (nc,B,L,H) inclusive
    G = g[:, :, -1, :]                                # (nc,B,H) chunk decay

    # chunk-local state summaries (local stabilizer mloc)
    w = G[:, :, None, :] - g + lic                    # (nc,B,L,H)
    mloc = jnp.max(w, axis=2)                         # (nc,B,H)
    wexp = jnp.exp(w - mloc[:, :, None, :])
    C_c = jnp.einsum("cblh,cblhk,cblhv->cbhkv", wexp, kc, vc)
    n_c = jnp.einsum("cblh,cblhk->cbhk", wexp, kc)

    # running-state combine: elementwise only (cheap scan body)
    def body(carry, xs):
        C, n, m = carry
        Cc_, nc_, ml_, G_ = xs
        m_new = jnp.maximum(G_ + m, ml_)
        a = jnp.exp(G_ + m - m_new)
        b = jnp.exp(ml_ - m_new)
        return ((a[..., None, None] * C + b[..., None, None] * Cc_,
                 a[..., None] * n + b[..., None] * nc_,
                 m_new),
                (C, n, m))  # emit PRE-chunk state

    C0 = jnp.zeros((B, H, hk, hv), f32)
    n0 = jnp.zeros((B, H, hk), f32)
    m0 = jnp.zeros((B, H), f32)
    (Cf, nf, mf), (Cp, np_, mp) = jax.lax.scan(body, (C0, n0, m0),
                                               (C_c, n_c, mloc, G))

    # intra-chunk decay matrix + combined row stabilizer
    D = (g[:, :, :, None, :] - g[:, :, None, :, :]
         + lic[:, :, None, :, :])                     # (nc,B,q,t,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask[None, None, :, :, None], D, -jnp.inf)
    m_inter = g + mp[:, :, None, :]                   # (nc,B,L,H)
    M = jnp.maximum(jnp.max(D, axis=3), m_inter)      # (nc,B,L,H)
    Dexp = jnp.exp(D - M[:, :, :, None, :])
    scores = jnp.einsum("cbqhe,cbthe->cbqth", qc, kc)
    Sm = scores * Dexp
    iw = jnp.exp(m_inter - M)                         # (nc,B,L,H)
    num = (jnp.einsum("cbqth,cbthv->cbqhv", Sm, vc)
           + iw[..., None] * jnp.einsum("cbqhk,cbhkv->cbqhv", qc, Cp))
    qn = jnp.einsum("cbqhk,cbhk->cbqh", qc, np_)
    den = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=3) + iw * qn), jnp.exp(-M))
    y = num / den[..., None]
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, H, hv)
    return y, (Cf, nf, mf)


def mlstm_full(params, x, cfg, *, build_cache=False):
    B, S, D = x.shape
    d_in, H, d_qk, hd_v, hd_k = mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["w_up"])
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ params["wq"]).reshape(B, S, H, hd_k)
    k = (xi @ params["wk"]).reshape(B, S, H, hd_k)
    v = (xi @ params["wv"]).reshape(B, S, H, hd_v)
    gif = xi @ params["w_if"] + params["b_if"]
    i_pre, f_pre = gif[..., :H], gif[..., H:]
    y, (C, n, m) = _mlstm_chunked(q, k, v, i_pre, f_pre)
    y = y.astype(x.dtype).reshape(B, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    cache = {"C": C, "n": n, "m": m} if build_cache else None
    return out, cache


def mlstm_step(params, x, cfg, cache):
    B = x.shape[0]
    d_in, H, d_qk, hd_v, hd_k = mlstm_dims(cfg)
    f32 = jnp.float32
    up = jnp.einsum("bsd,de->bse", x, params["w_up"])[:, 0]
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ params["wq"]).reshape(B, H, hd_k).astype(f32)
    k = (xi @ params["wk"]).reshape(B, H, hd_k).astype(f32) / np.sqrt(hd_k)
    v = (xi @ params["wv"]).reshape(B, H, hd_v).astype(f32)
    gif = (xi @ params["w_if"] + params["b_if"]).astype(f32)
    logi, logf = gif[..., :H], jax.nn.log_sigmoid(gif[..., H:])
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, logi)                       # (B,H)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(logi - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :])                    # (B,H,hk,hv)
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhkd,bhk->bhd", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype).reshape(B, d_in)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("be,ed->bd", y, params["w_out"])[:, None, :]
    return out, {"C": C, "n": n, "m": m_new}


def init_mlstm_cache(cfg, B, dtype):
    del dtype  # state kept in f32 for stability
    d_in, H, d_qk, hd_v, hd_k = mlstm_dims(cfg)
    return {"C": jnp.zeros((B, H, hd_k, hd_v), jnp.float32),
            "n": jnp.zeros((B, H, hd_k), jnp.float32),
            "m": jnp.zeros((B, H), jnp.float32)}


# ===========================================================================
# xLSTM — sLSTM (scalar memory, sequential by construction)
# ===========================================================================
def init_slstm(key, cfg, dtype):
    D = cfg.d_model
    H = cfg.num_heads
    hd = D // H
    ks = split_keys(key, 4)
    d_ff = int(D * 4 / 3)
    return {
        "w_x": dense_init(ks[0], (D, 4 * D), dtype, fan_in=D),
        "r": dense_init(ks[1], (H, hd, 4 * hd), dtype, fan_in=hd),
        "b": jnp.concatenate([jnp.zeros((D,)), jnp.linspace(3.0, 6.0, D),
                              jnp.zeros((2 * D,))]).astype(dtype),
        "ff_gate": dense_init(ks[2], (D, d_ff), dtype, fan_in=D),
        "ff_out": dense_init(ks[3], (d_ff, D), dtype, fan_in=d_ff),
        "ff_norm": jnp.ones((D,), dtype),
    }


def _slstm_cell(params, pre_x, state, cfg):
    """pre_x: (B,4D) = x_t @ W_x, precomputed outside the time scan (the
    input projection is the FLOP-heavy part; hoisting it keeps the scan body
    cheap and the dry-run cost analysis honest).
    state: dict h,c,n,m each (B,D) f32."""
    D = cfg.d_model
    H = cfg.num_heads
    hd = D // H
    B = pre_x.shape[0]
    f32 = jnp.float32
    h = state["h"]
    rec = jnp.einsum("bhk,hkg->bhg",
                     h.reshape(B, H, hd).astype(params["r"].dtype),
                     params["r"]).reshape(B, 4 * D)
    pre = (pre_x + rec + params["b"]).astype(f32)
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fp = jnp.exp(logf + state["m"] - m_new)
    ip = jnp.exp(i_pre - m_new)
    c = fp * state["c"] + ip * jnp.tanh(z_pre)
    n = fp * state["n"] + ip
    hy = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"h": hy, "c": c, "n": n, "m": m_new}


def slstm_full(params, x, cfg, *, build_cache=False):
    B, S, D = x.shape
    state0 = init_slstm_cache(cfg, B, x.dtype)
    pre_x = jnp.einsum("bsd,dg->bsg", x, params["w_x"])   # hoisted

    def body(state, pre_t):
        state = _slstm_cell(params, pre_t, state, cfg)
        return state, state["h"]

    state, hs = jax.lax.scan(body, state0, jnp.moveaxis(pre_x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                # (B,S,D)
    y = rmsnorm(y, params["ff_norm"])
    ff = jax.nn.gelu((y @ params["ff_gate"]).astype(jnp.float32))
    out = ff.astype(x.dtype) @ params["ff_out"]
    return out, (state if build_cache else None)


def slstm_step(params, x, cfg, cache):
    pre_x = x[:, 0] @ params["w_x"]
    state = _slstm_cell(params, pre_x, cache, cfg)
    y = state["h"].astype(x.dtype)
    y = rmsnorm(y, params["ff_norm"])
    ff = jax.nn.gelu((y @ params["ff_gate"]).astype(jnp.float32))
    out = (ff.astype(x.dtype) @ params["ff_out"])[:, None, :]
    return out, state


def init_slstm_cache(cfg, B, dtype):
    del dtype
    D = cfg.d_model
    z = jnp.zeros((B, D), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}
