"""Model facade: build any assigned architecture from its ModelConfig.

Exposes a uniform interface consumed by the FL runtime, the serving driver
and the dry-run:

    model = build_model(cfg, dtype)
    params = model.init(key)
    logits, aux = model.apply(params, batch)                  # train fwd
    loss, metrics = model.loss(params, batch)                 # CE (+aux)
    logits, cache = model.prefill(params, batch, cache_len)   # inference
    logits, cache = model.decode_step(params, cache, tokens)  # 1 token

Batch dict keys: tokens (B,S) int32, labels (B,S) int32, and for the stub
frontends: frames (B,encoder_seq,D) [audio] or image_embeds (B,N_img,D)
[vlm] — precomputed embeddings per the assignment carve-out.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.common import (embed_init, dense_init, init_norm,
                                 apply_norm, shard_logical,
                                 sinusoidal_positions, split_keys, tree_size)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    dtype: Any = jnp.float32

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        cfg, dtype = self.cfg, self.dtype
        ks = split_keys(key, 8)
        params = {
            "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                                dtype),
            "final_norm": init_norm(ks[1], cfg, dtype),
            "stack": tfm.init_stack(ks[2], cfg, dtype,
                                    decoder=cfg.cross_attention),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[3], (cfg.d_model,
                                                   cfg.padded_vocab), dtype)
        if cfg.encoder_layers:
            params["encoder"] = {
                "stack": tfm.init_stack(
                    ks[4], cfg, dtype,
                    layer_types=("attn",) * cfg.encoder_layers),
                "norm": init_norm(ks[5], cfg, dtype),
            }
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": dense_init(ks[6], (2 * cfg.d_model, cfg.d_model),
                                   dtype),
                "block": tfm.init_block(ks[7], cfg, cfg.layer_types[-1],
                                        dtype),
                "norm": init_norm(ks[5], cfg, dtype),
            }
        return params

    # ------------------------------------------------------------- embedding
    def _embed(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        if cfg.num_image_tokens and "image_embeds" in batch:
            img = batch["image_embeds"].astype(x.dtype)
            x = jnp.concatenate([img, x], axis=1)
        x = shard_logical(x, ("batch", "seq", "embed"))
        if not cfg.rope_theta:  # absolute sinusoidal positions (whisper)
            S = x.shape[1]
            pos = jnp.asarray(sinusoidal_positions(S, cfg.d_model),
                              x.dtype)
            x = x + pos[None]
        return x

    def _encode(self, params, batch):
        """Whisper encoder over stub frame embeddings."""
        cfg = self.cfg
        frames = batch["frames"].astype(self.dtype)
        S = frames.shape[1]
        x = frames + jnp.asarray(sinusoidal_positions(S, cfg.d_model),
                                 frames.dtype)[None]
        positions = jnp.arange(S)[None]
        x, _, _ = tfm.stack_full(
            params["encoder"]["stack"], x, cfg,
            layer_types=("attn",) * cfg.encoder_layers,
            positions=positions, causal=False)
        return apply_norm(params["encoder"]["norm"], x, cfg)

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V, stacked (num_layers, ...)."""
        from repro.models.attention import cross_kv
        cfg = self.cfg
        runs = tfm.segment_runs(cfg.layer_types)
        assert len(runs) == 1, "enc-dec assumes a uniform decoder stack"
        p = params["stack"]["run0"]
        if runs[0][1] == 1:  # single-layer run: params are unstacked
            kv = cross_kv(p["xattn"], enc_out, cfg)
            return jax.tree.map(lambda e: e[None], kv)
        return jax.vmap(lambda pl: cross_kv(pl["xattn"], enc_out, cfg))(p)

    def _project_vocab(self, params, x):
        """Vocab projection over the PADDED table; padding logits -inf."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        if cfg.padded_vocab != cfg.vocab_size:
            vid = jax.lax.broadcasted_iota(jnp.int32, (cfg.padded_vocab,), 0)
            logits = jnp.where(vid < cfg.vocab_size, logits, -1e30)
        return logits

    def _head(self, params, x):
        x = apply_norm(params["final_norm"], x, self.cfg)
        return self._project_vocab(params, x)

    # ----------------------------------------------------------------- train
    def apply(self, params, batch, *, use_pallas=False):
        """Full causal forward. Returns (logits over token positions, aux)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None]
        enc_kv = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch)
            enc_kv = self._cross_kv(params, enc_out)
        x, _, aux = tfm.stack_full(params["stack"], x, cfg,
                                   positions=positions, enc_kv=enc_kv,
                                   use_pallas=use_pallas)
        if cfg.num_image_tokens and "image_embeds" in batch:
            x = x[:, cfg.num_image_tokens:]  # logits for text positions only
        logits = self._head(params, x)
        logits = shard_logical(logits, ("batch", "seq", "vocab"))
        if cfg.mtp_depth and "labels" in batch:
            aux = aux + self._mtp_loss(params, x, batch)
        return logits, aux

    def _mtp_loss(self, params, h, batch, weight: float = 0.3):
        """DeepSeek-V3 style multi-token prediction: predict token t+2 from
        [h_t ; emb(token_{t+1})] through one extra block."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        nxt = params["embed"][tokens[:, 1:]]
        hcat = jnp.concatenate([h[:, :-1], nxt], axis=-1)
        x = jnp.einsum("bsd,dk->bsk", hcat, params["mtp"]["proj"])
        positions = jnp.arange(x.shape[1])[None]
        x, _, aux = tfm.block_full(params["mtp"]["block"], x, cfg,
                                   cfg.layer_types[-1], positions=positions)
        x = apply_norm(params["mtp"]["norm"], x, cfg)
        logits = self._project_vocab(params, x)
        # targets: token t+2 == labels shifted by one
        tgt = labels[:, 1:]
        ll = _ce(logits, tgt)
        return weight * ll + aux

    def loss(self, params, batch, *, use_pallas=False):
        logits, aux = self.apply(params, batch, use_pallas=use_pallas)
        ce = _ce(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- inference
    def cache_len_for(self, seq_len: int, window: Optional[int]) -> int:
        return min(seq_len, window) if window else seq_len

    def prefill(self, params, batch, *, cache_len=None, window=None,
                use_pallas=False):
        """Forward + build decode cache. Returns (last-position logits,
        cache)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None]
        enc_kv = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch)
            enc_kv = self._cross_kv(params, enc_out)
        x, caches, _ = tfm.stack_full(params["stack"], x, cfg,
                                      positions=positions, window=window,
                                      build_cache=True, enc_kv=enc_kv,
                                      use_pallas=use_pallas)
        logits = self._head(params, x[:, -1:])
        cache_len = cache_len or self.cache_len_for(S, window)
        cache = self._assemble_cache(caches, B, S, cache_len, window)
        if enc_kv is not None:
            cache["enc_kv"] = enc_kv
        return logits, cache

    def _assemble_cache(self, built, B, S, cache_len, window):
        """Pad/crop per-layer prefill caches to the decode cache length and
        attach position bookkeeping. When cropping (ring buffer), entries are
        rolled so absolute position p sits at slot p % W — decode_step then
        always overwrites the oldest entry."""
        cfg = self.cfg
        runs_spec = tfm.segment_runs(cfg.layer_types)

        def fit(leaf):  # kv-like leaves: (n, B, S, ...)
            if S >= cache_len:
                out = leaf[:, :, S - cache_len:]
                return jnp.roll(out, shift=S % cache_len, axis=2)
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, cache_len - S)
            return jnp.pad(leaf, pad)

        runs = {}
        for i, (btype, n) in enumerate(runs_spec):
            c = built[f"run{i}"]
            if btype in ("attn", "moe", "shared_attn"):
                runs[f"run{i}"] = jax.tree.map(fit, c)
            else:  # recurrent states are already O(1)
                runs[f"run{i}"] = c
        if S >= cache_len:
            pos = jnp.roll(jnp.arange(S - cache_len, S, dtype=jnp.int32),
                           S % cache_len)
        else:
            pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                   jnp.full((cache_len - S,), -1, jnp.int32)])
        return {"runs": runs, "t": jnp.asarray(S, jnp.int32),
                "positions": pos}

    def init_cache(self, B, cache_len, *, window=None, quant_kv=False):
        """Empty decode cache (serving from scratch). quant_kv=True stores
        int8 KV entries (beyond-paper decode-bandwidth optimization)."""
        cfg, dtype = self.cfg, self.dtype
        from repro.models import attention as attn
        from repro.models import ssm
        runs_spec = tfm.segment_runs(cfg.layer_types)
        runs = {}
        for i, (btype, n) in enumerate(runs_spec):
            if btype in ("attn", "moe", "shared_attn"):
                one = (attn.init_mla_cache(cfg, B, cache_len, dtype)
                       if cfg.use_mla else
                       attn.init_gqa_cache(cfg, B, cache_len, dtype,
                                           quant=quant_kv))
            elif btype == "mamba2":
                one = ssm.init_mamba2_cache(cfg, B, dtype)
            elif btype == "mlstm":
                one = ssm.init_mlstm_cache(cfg, B, dtype)
            else:
                one = ssm.init_slstm_cache(cfg, B, dtype)
            runs[f"run{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)
        return {"runs": runs, "t": jnp.asarray(0, jnp.int32),
                "positions": jnp.full((cache_len,), -1, jnp.int32)}

    def decode_step(self, params, cache, tokens, *, window=None):
        """tokens: (B,1) -> (logits (B,1,V), cache). ``window`` must match
        the value used at prefill/init_cache (a static config, not state).

        Two cache forms, selected by ``cache["t"]``'s rank:
          * scalar ``t`` + (W,) ``positions`` — the legacy LOCKSTEP
            cache (every batch row at the same position; serve's fixed
            batch, the decode-consistency tests);
          * (B,) ``t`` + (B, W) ``positions`` — the PER-SLOT pool cache
            (repro.serving.engine): each row decodes at its own
            position/ring slot, so a continuous-batching pool can admit
            and retire sequences independently per row.
        """
        cfg = self.cfg
        t = cache["t"]
        vec = t.ndim > 0
        W = cache["positions"].shape[-1]
        slot = (t % W).astype(jnp.int32)
        if vec:
            rows = jnp.arange(t.shape[0])
            positions_buf = cache["positions"].at[rows, slot].set(t)
        else:
            positions_buf = cache["positions"].at[slot].set(t)
        x = params["embed"][tokens]
        if not cfg.rope_theta:  # absolute sinusoidal positions (whisper)
            from repro.models.common import sinusoidal_position_at
            if vec:
                pe = jax.vmap(
                    lambda ti: sinusoidal_position_at(ti, cfg.d_model))(t)
                x = x + pe[:, None, :].astype(x.dtype)
            else:
                x = x + sinusoidal_position_at(t, cfg.d_model).astype(x.dtype)
        enc_kv = cache.get("enc_kv")
        x, runs = tfm.stack_step(params["stack"], x, cfg,
                                 cache["runs"], t=t, slot=slot,
                                 positions_buf=positions_buf, window=window,
                                 enc_kv=enc_kv)
        logits = self._head(params, x)
        new_cache = {"runs": runs, "t": t + 1, "positions": positions_buf}
        if enc_kv is not None:
            new_cache["enc_kv"] = enc_kv
        return logits, new_cache


def build_model(cfg: ModelConfig, dtype=jnp.float32) -> Model:
    return Model(cfg, dtype)


def _ce(logits, labels):
    """Cross-entropy that stays sharded over the vocab dim: the label
    log-prob is a one-hot contraction (partial-sum + tiny all-reduce under
    SPMD) instead of take_along_axis (which would all-gather the logits)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - ll)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact param count via abstract init (no allocation)."""
    model = build_model(cfg, jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    total = tree_size(shapes)
    if active_only and cfg.num_experts:
        # replace dense-expert count with routed-active + shared experts
        E, K = cfg.num_experts, cfg.num_experts_per_tok
        per_expert = 3 * cfg.d_model * cfg.expert_d_ff
        n_moe = sum(1 for t in cfg.layer_types if t == "moe")
        total -= n_moe * (E - K) * per_expert
    return int(total)
