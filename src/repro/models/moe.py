"""Mixture-of-Experts layer: token-choice top-k routing with capacity-based
scatter dispatch (TPU-friendly: static shapes, grouped einsum over an
expert-sharded buffer; SPMD inserts the all-to-all at the scatter/gather).

Supports shared experts (DeepSeek-V3) and an auxiliary load-balance loss,
which is accumulated into a loss-carry threaded through the layer stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, shard_logical, split_keys

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg, dtype):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), dtype, fan_in=D),
        "w_gate": dense_init(ks[1], (E, D, F), dtype, fan_in=D),
        "w_in": dense_init(ks[2], (E, D, F), dtype, fan_in=D),
        "w_out": dense_init(ks[3], (E, F, D), dtype, fan_in=F),
    }
    if cfg.num_shared_experts:
        Fs = cfg.expert_d_ff * cfg.num_shared_experts
        sk = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sk[0], (D, Fs), dtype, fan_in=D),
            "w_in": dense_init(sk[1], (D, Fs), dtype, fan_in=D),
            "w_out": dense_init(sk[2], (Fs, D), dtype, fan_in=Fs),
        }
    return p


def _capacity(T: int, E: int, k: int) -> int:
    c = int(T * k * CAPACITY_FACTOR / E)
    return max(4, ((c + 3) // 4) * 4)


def apply_moe(params, x, cfg):
    """x: (B,S,D) -> (out, aux_loss).

    Dispatch: top-k per token; position-in-expert via cumsum over the
    flattened token stream; tokens beyond expert capacity are dropped
    (their residual path still carries them, standard Switch behaviour).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    C = _capacity(T, E, K)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                  # (T,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- aux load-balance loss (Switch eq. 4 generalised to top-k) ----
    me = jnp.mean(probs, axis=0)                              # (E,)
    onehot_any = jax.nn.one_hot(idx, E, dtype=jnp.float32)    # (T,K,E)
    ce = jnp.mean(jnp.sum(onehot_any, axis=1), axis=0)        # frac routed
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce) / K

    # ---- position-in-expert via cumsum over the flattened (T*K,) stream ---
    flat_e = idx.reshape(T * K)                               # expert ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (TK,E)
    pos = jnp.cumsum(onehot, axis=0) - 1                      # pos within e
    pos = jnp.sum(pos * onehot, axis=-1)                      # (TK,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)           # drop slot

    # ---- scatter tokens into (E*C+1, D) expert buffer ----
    tok_ids = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xt[tok_ids],
                                                          mode="drop")
    buf = buf[:E * C].reshape(E, C, D)
    buf = shard_logical(buf, ("experts", None, None))

    # ---- grouped expert FFN ----
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("ecf,efd->ecd", h, params["w_out"])
    eo = shard_logical(eo, ("experts", None, None))

    # ---- gather back + combine with gate weights ----
    eo_flat = jnp.concatenate(
        [eo.reshape(E * C, D), jnp.zeros((1, D), x.dtype)], axis=0)
    per_slot = eo_flat[slot] * gate_vals.reshape(T * K)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_ids].add(per_slot)

    if "shared" in params:
        sp = params["shared"]
        h = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_in"])
        out = out + h @ sp["w_out"]
    return out.reshape(B, S, D), aux
