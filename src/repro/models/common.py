"""Shared model building blocks: inits, norms, activations, rope, logical
sharding annotations.

The module system is plain pytrees-of-dicts + pure functions: every block
exposes ``init_*(key, cfg, dtype) -> params`` and ``apply(params, x, ...)``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Logical sharding annotations.
#
# Models annotate activations with *logical* axis names; the launcher installs
# a rule-set mapping logical names -> mesh axes (repro/sharding/spec.py). With
# no rules installed (CPU smoke tests) the annotation is a no-op.
# ---------------------------------------------------------------------------
_tls = threading.local()


def set_logical_rules(rules):
    _tls.rules = rules


def get_logical_rules():
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def logical_rules(rules):
    prev = get_logical_rules()
    set_logical_rules(rules)
    try:
        yield
    finally:
        set_logical_rules(prev)


# ---------------------------------------------------------------------------
# Scan-unroll flag. XLA's cost_analysis counts a while-loop body ONCE, not
# × trip count (verified empirically). The dry-run enables full unrolling of
# the structural scans (layer stack, K local steps) so the roofline FLOP /
# byte numbers are trip-count-correct. Time-sequential scans (sLSTM over
# time, SSD inter-chunk state propagation) are restructured so essentially
# all FLOPs sit outside the loop body.
# ---------------------------------------------------------------------------
def set_unroll(flag: bool):
    _tls.unroll = flag


def scan_unroll() -> bool:
    return getattr(_tls, "unroll", False)


@contextlib.contextmanager
def unroll_scans(flag: bool = True):
    prev = scan_unroll()
    set_unroll(flag)
    try:
        yield
    finally:
        set_unroll(prev)


def set_remat(flag: bool):
    _tls.remat = flag


def remat_on() -> bool:
    return getattr(_tls, "remat", False)


@contextlib.contextmanager
def remat_blocks(flag: bool = True):
    """Per-transformer-block activation checkpointing (standard production
    policy: recompute block internals in backward, keep only the residual
    stream between layers)."""
    prev = remat_on()
    set_remat(flag)
    try:
        yield
    finally:
        set_remat(prev)


def shard_logical(x: jax.Array, names: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply with_sharding_constraint according to the installed rules."""
    rules = get_logical_rules()
    if rules is None:
        return x
    return rules.constrain(x, names)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape: Sequence[int], dtype, fan_in: Optional[int] = None):
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[0] default)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(1, fan))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def init_norm(key, cfg, dtype, d=None):
    d = d or cfg.d_model
    if cfg.norm_variant == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(params, x, cfg):
    if "bias" in params:
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                        # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_position_at(t, d: int):
    """Traced single-position sinusoidal embedding: t scalar -> (d,)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    angle = t.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    out = jnp.zeros((d,), jnp.float32)
    out = out.at[0::2].set(jnp.sin(angle))
    out = out.at[1::2].set(jnp.cos(angle))
    return out


def sinusoidal_positions(num_pos: int, d: int) -> np.ndarray:
    pos = np.arange(num_pos)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d)
    out = np.zeros((num_pos, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------
def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))
