"""Small models for the faithful paper reproduction: an MLP and the paper's
shallow CNN (two conv + two FC, ReLU; dropout omitted — deterministic repro).

Interface mirrors the big models: init(key) -> params, loss(params, batch).
Batch: {"x": (B, ...), "y": (B,) int32}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import CNNConfig, MLPConfig
from repro.models.common import dense_init, split_keys


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp_model(key, cfg: MLPConfig, dtype=jnp.float32):
    dims = (cfg.input_dim,) + cfg.hidden_dims + (cfg.num_classes,)
    ks = split_keys(key, len(dims) - 1)
    return {f"l{i}": {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype),
                      "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)}


def mlp_logits(params, x):
    n = len(params)
    for i in range(n):
        x = x @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# Shallow CNN (paper's MNIST/FMNIST model)
# ---------------------------------------------------------------------------
def init_cnn_model(key, cfg: CNNConfig, dtype=jnp.float32):
    c1, c2 = cfg.conv_channels
    ks = split_keys(key, 4)
    # after two stride-2 3x3 convs: spatial /4
    flat = (cfg.image_size // 4) ** 2 * c2
    return {
        "conv1": {"w": dense_init(ks[0], (3, 3, cfg.channels, c1), dtype,
                                  fan_in=9 * cfg.channels),
                  "b": jnp.zeros((c1,), dtype)},
        "conv2": {"w": dense_init(ks[1], (3, 3, c1, c2), dtype,
                                  fan_in=9 * c1),
                  "b": jnp.zeros((c2,), dtype)},
        "fc1": {"w": dense_init(ks[2], (flat, cfg.fc_dim), dtype),
                "b": jnp.zeros((cfg.fc_dim,), dtype)},
        "fc2": {"w": dense_init(ks[3], (cfg.fc_dim, cfg.num_classes), dtype),
                "b": jnp.zeros((cfg.num_classes,), dtype)},
    }


def cnn_logits(params, x):
    """x: (B, H, W, C)."""
    def conv(x, p):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y + p["b"])

    x = conv(x, params["conv1"])
    x = conv(x, params["conv2"])
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


# ---------------------------------------------------------------------------
# Shared loss / metrics
# ---------------------------------------------------------------------------
def softmax_ce(logits, y):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def make_small_model(cfg):
    """Returns (init_fn, logits_fn) for an MLPConfig or CNNConfig."""
    if isinstance(cfg, MLPConfig):
        return (lambda key, dtype=jnp.float32: init_mlp_model(key, cfg, dtype),
                mlp_logits)
    return (lambda key, dtype=jnp.float32: init_cnn_model(key, cfg, dtype),
            cnn_logits)
