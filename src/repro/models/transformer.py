"""Layer-stack machinery: block dispatch, segment runs, shared blocks.

Layers are grouped into *runs* of consecutive identical block types
(cfg.layer_types). Each run's params are stacked on a leading axis and
applied with ``lax.scan`` — one trace per run, so an 81-layer hybrid
compiles like a handful of blocks. ``shared_attn`` blocks (Zamba2) hold a
single global param set referenced by every occurrence.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models.common import (apply_norm, dense_init, init_norm,
                                 scan_unroll, shard_logical, split_keys,
                                 swiglu)


def segment_runs(layer_types: Tuple[str, ...]) -> List[Tuple[str, int]]:
    runs: List[Tuple[str, int]] = []
    for t in layer_types:
        if runs and runs[-1][0] == t:
            runs[-1] = (t, runs[-1][1] + 1)
        else:
            runs.append((t, 1))
    return runs


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {"w_gate": dense_init(ks[0], (D, F), dtype, fan_in=D),
                "w_in": dense_init(ks[1], (D, F), dtype, fan_in=D),
                "w_out": dense_init(ks[2], (F, D), dtype, fan_in=F)}
    return {"w_in": dense_init(ks[0], (D, F), dtype, fan_in=D),
            "b_in": jnp.zeros((F,), dtype),
            "w_out": dense_init(ks[1], (F, D), dtype, fan_in=F),
            "b_out": jnp.zeros((D,), dtype)}


def apply_mlp(params, x, cfg):
    if "w_gate" in params:
        h = swiglu(x @ params["w_gate"], x @ params["w_in"])
        h = shard_logical(h, ("batch", "seq", "ffn"))
        return h @ params["w_out"]
    h = jax.nn.gelu((x @ params["w_in"] + params["b_in"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = shard_logical(h, ("batch", "seq", "ffn"))
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------
def init_block(key, cfg, btype: str, dtype, *, decoder: bool = False):
    ks = split_keys(key, 6)
    if btype in ("attn", "shared_attn", "moe"):
        attn_init = attn.init_mla if cfg.use_mla else attn.init_attention
        p = {"ln1": init_norm(ks[0], cfg, dtype),
             "attn": attn_init(ks[1], cfg, dtype),
             "ln2": init_norm(ks[2], cfg, dtype)}
        if btype == "moe":
            from repro.models.moe import init_moe
            p["moe"] = init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[3], cfg, dtype)
        if decoder and cfg.cross_attention:
            p["ln_x"] = init_norm(ks[4], cfg, dtype)
            p["xattn"] = attn.init_cross_attention(ks[5], cfg, dtype)
        return p
    if btype == "mamba2":
        return {"ln": init_norm(ks[0], cfg, dtype),
                "mixer": ssm.init_mamba2(ks[1], cfg, dtype)}
    if btype == "mlstm":
        return {"ln": init_norm(ks[0], cfg, dtype),
                "mixer": ssm.init_mlstm(ks[1], cfg, dtype)}
    if btype == "slstm":
        return {"ln": init_norm(ks[0], cfg, dtype),
                "mixer": ssm.init_slstm(ks[1], cfg, dtype)}
    raise ValueError(btype)


# ---------------------------------------------------------------------------
# Block apply — full-sequence mode
# ---------------------------------------------------------------------------
def block_full(params, x, cfg, btype, *, positions, window=None,
               build_cache=False, enc_kv=None, causal=True,
               use_pallas=False):
    """Returns (x, cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if btype in ("attn", "shared_attn", "moe"):
        h = apply_norm(params["ln1"], x, cfg)
        if cfg.use_mla:
            a, cache = attn.mla_full(params["attn"], h, cfg,
                                     positions=positions, window=window,
                                     build_cache=build_cache,
                                     use_pallas=use_pallas)
        else:
            if not causal:
                a, cache = _bidir_attn(params["attn"], h, cfg, positions)
            else:
                a, cache = attn.gqa_full(params["attn"], h, cfg,
                                         positions=positions, window=window,
                                         build_cache=build_cache,
                                         use_pallas=use_pallas)
        x = x + a
        if enc_kv is not None:
            h = apply_norm(params["ln_x"], x, cfg)
            x = x + attn.cross_attend(params["xattn"], h, cfg, enc_kv)
        h = apply_norm(params["ln2"], x, cfg)
        if btype == "moe":
            from repro.models.moe import apply_moe
            m, aux = apply_moe(params["moe"], h, cfg)
        else:
            m = apply_mlp(params["mlp"], h, cfg)
        x = x + m
        return x, cache, aux
    h = apply_norm(params["ln"], x, cfg)
    fn = {"mamba2": ssm.mamba2_full, "mlstm": ssm.mlstm_full,
          "slstm": ssm.slstm_full}[btype]
    if btype == "mamba2":
        m, cache = fn(params["mixer"], h, cfg, build_cache=build_cache,
                      use_pallas=use_pallas)
    else:
        m, cache = fn(params["mixer"], h, cfg, build_cache=build_cache)
    return x + m, cache, aux


def _bidir_attn(params, x, cfg, positions):
    """Non-causal attention (Whisper encoder)."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.rope_theta:
        q = attn.apply_rope(q, positions, cfg.rope_theta)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(B, S, KV, H // KV, hd)
    out = attn._sdpa(qg, k, v, causal=False).reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), None


# ---------------------------------------------------------------------------
# Block apply — single decode step
# ---------------------------------------------------------------------------
def block_step(params, x, cfg, btype, cache, *, t, slot, positions_buf,
               window=None, enc_kv=None):
    if btype in ("attn", "shared_attn", "moe"):
        h = apply_norm(params["ln1"], x, cfg)
        if cfg.use_mla:
            a, cache = attn.mla_step(params["attn"], h, cfg, cache, t=t,
                                     slot=slot, positions_buf=positions_buf,
                                     window=window)
        else:
            a, cache = attn.gqa_step(params["attn"], h, cfg, cache, t=t,
                                     slot=slot, positions_buf=positions_buf,
                                     window=window)
        x = x + a
        if enc_kv is not None:
            h = apply_norm(params["ln_x"], x, cfg)
            x = x + attn.cross_attend(params["xattn"], h, cfg, enc_kv)
        h = apply_norm(params["ln2"], x, cfg)
        if btype == "moe":
            from repro.models.moe import apply_moe
            m, _ = apply_moe(params["moe"], h, cfg)
        else:
            m = apply_mlp(params["mlp"], h, cfg)
        return x + m, cache
    h = apply_norm(params["ln"], x, cfg)
    fn = {"mamba2": ssm.mamba2_step, "mlstm": ssm.mlstm_step,
          "slstm": ssm.slstm_step}[btype]
    m, cache = fn(params["mixer"], h, cfg, cache)
    return x + m, cache


# ---------------------------------------------------------------------------
# Stack: init / full / step over segment runs
# ---------------------------------------------------------------------------
def init_stack(key, cfg, dtype, *, layer_types=None, decoder=False):
    layer_types = layer_types or cfg.layer_types
    runs = segment_runs(layer_types)
    keys = split_keys(key, len(runs) + 1)
    params = {}
    shared = None
    for i, (btype, n) in enumerate(runs):
        if btype == "shared_attn":
            if shared is None:
                shared = init_block(keys[-1], cfg, btype, dtype,
                                    decoder=decoder)
                params["shared_attn"] = shared
            continue
        if n == 1:
            params[f"run{i}"] = init_block(keys[i], cfg, btype, dtype,
                                           decoder=decoder)
        else:
            ks = jnp.stack(split_keys(keys[i], n))
            params[f"run{i}"] = jax.vmap(
                lambda k: init_block(k, cfg, btype, dtype, decoder=decoder)
            )(ks)
    return params


def stack_full(params, x, cfg, *, layer_types=None, positions, window=None,
               build_cache=False, enc_kv=None, causal=True,
               use_pallas=False):
    """Returns (x, cache_dict, total_aux)."""
    from repro.models.common import remat_on
    layer_types = layer_types or cfg.layer_types
    runs = segment_runs(layer_types)
    caches = {}
    aux_total = jnp.zeros((), jnp.float32)

    def make_apply(btype):
        """Per-block apply; checkpointed when remat is on (production
        policy: keep only the residual stream between layers)."""
        def apply_one(pl, xx, ekv):
            return block_full(pl, xx, cfg, btype, positions=positions,
                              window=window, build_cache=build_cache,
                              enc_kv=ekv, causal=causal,
                              use_pallas=use_pallas)
        return jax.checkpoint(apply_one) if remat_on() else apply_one

    for i, (btype, n) in enumerate(runs):
        x = shard_logical(x, ("batch", "seq", "embed"))  # residual stream
        apply_one = make_apply(btype)
        if btype == "shared_attn":
            # params shared across occurrences; caches are NOT (each site
            # attends over its own history).
            p = params["shared_attn"]
            cs = []
            for _ in range(n):
                x, c, aux = apply_one(p, x, None)
                aux_total += aux
                cs.append(c)
            if build_cache:
                caches[f"run{i}"] = jax.tree.map(
                    lambda *ys: jnp.stack(ys), *cs)
            continue
        p = params[f"run{i}"]
        if n == 1:
            x, c, aux = apply_one(p, x, _slice_enc(enc_kv, 0))
            aux_total += aux
            if build_cache:
                caches[f"run{i}"] = jax.tree.map(lambda y: y[None], c)
        else:
            def body(carry, xs):
                xx, auxx = carry
                pl, ekv = xs
                xx, c, aux = apply_one(pl, xx, ekv)
                return (xx, auxx + aux), c

            (x, aux_total), cs = jax.lax.scan(body, (x, aux_total),
                                              (p, enc_kv),
                                              unroll=scan_unroll())
            if build_cache:
                caches[f"run{i}"] = cs
    return x, (caches if build_cache else None), aux_total


def stack_step(params, x, cfg, caches, *, layer_types=None, t, slot,
               positions_buf, window=None, enc_kv=None):
    layer_types = layer_types or cfg.layer_types
    runs = segment_runs(layer_types)
    new_caches = {}
    for i, (btype, n) in enumerate(runs):
        key = f"run{i}"
        if btype == "shared_attn":
            p = params["shared_attn"]

            def body(xx, cl):
                xx, cl = block_step(p, xx, cfg, btype, cl, t=t, slot=slot,
                                    positions_buf=positions_buf,
                                    window=window)
                return xx, cl

            x, cs = jax.lax.scan(body, x, caches[key],
                                 unroll=scan_unroll())
            new_caches[key] = cs
            continue
        p = params[key]
        if n == 1:
            c = jax.tree.map(lambda y: y[0], caches[key])
            x, c = block_step(p, x, cfg, btype, c, t=t, slot=slot,
                              positions_buf=positions_buf, window=window,
                              enc_kv=_slice_enc(enc_kv, 0))
            new_caches[key] = jax.tree.map(lambda y: y[None], c)
        else:
            def body(xx, xs):
                pl, cl, ekv = xs
                xx, cl = block_step(pl, xx, cfg, btype, cl, t=t, slot=slot,
                                    positions_buf=positions_buf,
                                    window=window, enc_kv=ekv)
                return xx, cl

            x, cs = jax.lax.scan(body, x, (p, caches[key], enc_kv),
                                 unroll=scan_unroll())
            new_caches[key] = cs
    return x, new_caches


def _shared_run_key(runs):
    return "shared"


def _slice_enc(enc_kv, layer_idx):
    """enc_kv is stacked per layer (num_layers, ...) for cross-attention."""
    if enc_kv is None:
        return None
    return jax.tree.map(lambda e: e[layer_idx], enc_kv)
