from repro.models.model import Model, build_model, count_params_analytic

__all__ = ["Model", "build_model", "count_params_analytic"]
