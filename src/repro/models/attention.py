"""Attention blocks: GQA/MQA, MLA (DeepSeek-V3), cross-attention, sliding
window, and ring-buffer KV caches for decode.

Two modes everywhere:
  * full : whole-sequence causal attention (train / prefill). Optionally
           returns a freshly-built KV cache.
  * step : one new token against an existing cache (decode).

The quadratic jnp path here is the reference; the Pallas flash kernel
(repro/kernels/flash_attention) is plugged in via ``use_pallas``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (apply_rope, dense_init, shard_logical,
                                 split_keys, zeros_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), dtype, fan_in=D),
        "wk": dense_init(ks[1], (D, KV, hd), dtype, fan_in=D),
        "wv": dense_init(ks[2], (D, KV, hd), dtype, fan_in=D),
        "wo": dense_init(ks[3], (H, hd, D), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def init_mla(key, cfg, dtype):
    D = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = split_keys(key, 6)
    return {
        "wq_a": dense_init(ks[0], (D, qr), dtype, fan_in=D),
        "q_norm": jnp.ones((qr,), dtype),
        "wq_b": dense_init(ks[1], (qr, H, dn + dr), dtype, fan_in=qr),
        "wkv_a": dense_init(ks[2], (D, kvr + dr), dtype, fan_in=D),
        "kv_norm": jnp.ones((kvr,), dtype),
        "wk_b": dense_init(ks[3], (kvr, H, dn), dtype, fan_in=kvr),
        "wv_b": dense_init(ks[4], (kvr, H, dv), dtype, fan_in=kvr),
        "wo": dense_init(ks[5], (H, dv, D), dtype, fan_in=H * dv),
    }


def init_cross_attention(key, cfg, dtype):
    return init_attention(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA grouping, query-chunked.
#
# The (S,T) score matrix is never materialised whole: queries are processed
# in NQ chunks (lax.scan), so the peak intermediate is (B,KV,G,S/NQ,T) —
# the pure-JAX analogue of the flash-attention tiling the Pallas kernel
# (repro/kernels/flash_attention) implements natively. The dry-run unrolls
# the chunk scan (models.common.unroll_scans) so cost analysis stays honest.
# ---------------------------------------------------------------------------
_NQ_TARGET = 8

# Beyond-paper perf knob (§Perf): store softmax weights in bf16 between the
# two attention matmuls — halves the dominant train/prefill HBM term; the
# max-subtracted exponent keeps values in [0,1] where bf16's 8 mantissa
# bits give ~3 decimal digits (validated vs f32 in tests).
SOFTMAX_BF16 = False


def _sdpa_block(qc, k, v, rows, *, causal, window):
    """qc: (B,L,H,hd), k/v: (B,T,H,hd), rows: (L,) absolute positions."""
    scale = 1.0 / np.sqrt(qc.shape[-1])
    T = k.shape[1]
    scores = jnp.einsum("bshd,bthd->bhst", qc.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    cols = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], T), 1)
    mask = jnp.ones((rows.shape[0], T), bool)
    if causal:
        mask &= cols <= rows[:, None]
    if window is not None:
        mask &= (rows[:, None] - cols) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    if SOFTMAX_BF16:
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m).astype(jnp.bfloat16)
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        out = jnp.einsum("bhst,bthd->bshd", p,
                         v.astype(jnp.bfloat16)).astype(jnp.float32)
        out = out / denom.swapaxes(1, 2)
        return out.astype(v.dtype)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
    return out.astype(v.dtype)


def _sdpa(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (B,S,KV,G,hd)  k,v: (B,T,KV,hd) -> (B,S,KV,G,hd).

    K/V are repeated to the full H = KV*G heads before the score einsum so
    the heads dim shards cleanly over the tensor-parallel axis (a sharded
    (KV, G) axis split confuses SPMD propagation and replicates the score
    chunks). The repeat is cheap (K/V ≪ scores); the Pallas kernel avoids
    it entirely via its index map.
    """
    from repro.models.common import scan_unroll
    B, S, KV, G, hd = q.shape
    H = KV * G
    qq = q.reshape(B, S, H, hd)
    kk = jnp.repeat(k, G, axis=2) if G > 1 else k
    vv = jnp.repeat(v, G, axis=2) if G > 1 else v
    qq = shard_logical(qq, ("batch", "seq", "heads", None))
    kk = shard_logical(kk, ("batch", "seq", "heads", None))
    vv = shard_logical(vv, ("batch", "seq", "heads", None))
    hv = v.shape[-1]  # MLA: value head dim can differ from q/k head dim
    nq = _NQ_TARGET if (S % _NQ_TARGET == 0 and S >= 2048) else 1
    if nq == 1:
        rows = q_offset + jnp.arange(S, dtype=jnp.int32)
        out = _sdpa_block(qq, kk, vv, rows, causal=causal, window=window)
        return out.reshape(B, S, KV, G, hv)
    L = S // nq
    qs = jnp.moveaxis(qq.reshape(B, nq, L, H, hd), 1, 0)

    def body(_, xs):
        qc, ci = xs
        rows = q_offset + ci * L + jnp.arange(L, dtype=jnp.int32)
        return 0, _sdpa_block(qc, kk, vv, rows, causal=causal,
                              window=window)

    _, out = jax.lax.scan(body, 0, (qs, jnp.arange(nq, dtype=jnp.int32)),
                          unroll=scan_unroll())
    return jnp.moveaxis(out, 0, 1).reshape(B, S, KV, G, hv)


def _sdpa_masked(q, k, v, mask):
    """Single-block SDPA with an explicit mask (decode: S=1, tiny)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA attention: full-sequence mode
# ---------------------------------------------------------------------------
def gqa_full(params, x, cfg, *, positions, window=None, build_cache=False,
             use_pallas=False):
    """x: (B,S,D). Returns (out, cache|None)."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_logical(q, ("batch", "seq", "heads", None))
    k = shard_logical(k, ("batch", "seq", "kv_heads", None))
    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    else:
        qg = q.reshape(B, S, KV, H // KV, hd)
        out = _sdpa(qg, k, v, causal=True, window=window
                    ).reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    cache = {"k": k, "v": v} if build_cache else None
    return y, cache


def _cache_write(buf, val, slot, vec):
    """Write one decode entry into a (B, W, ...) ring buffer.

    Scalar ``slot``: the legacy lockstep write — every batch row stores
    at the same index (dynamic_update_slice). Vector ``slot`` (B,): the
    per-slot serving form — row b writes at its OWN index slot[b]."""
    if vec:
        return buf.at[jnp.arange(buf.shape[0]), slot].set(val[:, 0])
    return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)


def gqa_step(params, x, cfg, cache, *, t, slot, positions_buf, window=None):
    """One decode step. x: (B,1,D); cache k/v: (B,W,KV,hd) ring buffer.

    t: scalar absolute position of the new token. slot: write index in the
    ring buffer. positions_buf: (W,) absolute position of each slot (-1 =
    empty), already updated by the caller for this step.

    Vectorized (continuous-batching) form: ``t``/``slot`` may be (B,)
    int32 with ``positions_buf`` (B, W) — every batch row then decodes
    at its OWN absolute position, writes its OWN ring slot, and masks
    against its OWN position row (the serving engine's per-slot
    sequence state). Scalar inputs take the original lockstep path
    unchanged.
    """
    B = x.shape[0]
    vec = jnp.ndim(t) > 0
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.rope_theta:
        pos = t[:, None] if vec else jnp.full((B, 1), t, jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        ck = _cache_write(cache["k"], kq, slot, vec)
        cv = _cache_write(cache["v"], vq, slot, vec)
        cks = _cache_write(cache["k_scale"], ks, slot, vec)
        cvs = _cache_write(cache["v_scale"], vs, slot, vec)
        kd = (ck.astype(jnp.float32)
              * cks.astype(jnp.float32)[..., None]).astype(k.dtype)
        vd = (cv.astype(jnp.float32)
              * cvs.astype(jnp.float32)[..., None]).astype(v.dtype)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        kd = _cache_write(cache["k"], k, slot, vec)
        vd = _cache_write(cache["v"], v, slot, vec)
        new_cache = {"k": kd, "v": vd}
    tt = t[:, None] if vec else t
    valid = (positions_buf >= 0) & (positions_buf <= tt)
    if window is not None:
        valid &= (tt - positions_buf) < window
    qg = q.reshape(B, 1, KV, H // KV, hd)
    mask = (valid[:, None, None, None, :] if vec
            else valid[None, None, None, None, :])
    out = _sdpa_masked(qg, kd, vd, mask).reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_gqa_cache(cfg, B, cache_len, dtype, *, quant: bool = False):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if quant:
        # Beyond-paper perf knob (§Perf): int8 KV entries + per-entry f16
        # scales -> ~2x less decode HBM traffic on the cache-read term.
        return {"k": jnp.zeros((B, cache_len, KV, hd), jnp.int8),
                "v": jnp.zeros((B, cache_len, KV, hd), jnp.int8),
                "k_scale": jnp.zeros((B, cache_len, KV), jnp.float16),
                "v_scale": jnp.zeros((B, cache_len, KV), jnp.float16)}
    return {"k": jnp.zeros((B, cache_len, KV, hd), dtype),
            "v": jnp.zeros((B, cache_len, KV, hd), dtype)}


def _quantize(x):
    """x: (B,1,KV,hd) -> (int8 values, f16 scales (B,1,KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-8)[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): compressed KV latent cache
# ---------------------------------------------------------------------------
def mla_full(params, x, cfg, *, positions, window=None, build_cache=False,
             use_pallas=False):
    """Expanded (training/prefill) form; cache stores the latent only."""
    B, S, D = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    from repro.models.common import rmsnorm
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                 params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])     # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])       # (B,S,kvr+dr)
    c_kv = rmsnorm(kv[..., :kvr], params["kv_norm"])         # latent
    k_rope = kv[..., kvr:][:, :, None, :]                    # (B,S,1,dr)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope,
                          jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    qg = qf.reshape(B, S, H, 1, dn + dr)
    out = _sdpa(qg, kf, v, causal=True, window=window).reshape(B, S, H, dv)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    cache = ({"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
             if build_cache else None)
    return y, cache


def mla_step(params, x, cfg, cache, *, t, slot, positions_buf, window=None):
    """Absorbed decode form: attention runs directly against the latent cache
    (c_kv, k_rope) without expanding per-head K/V for the whole history —
    the memory- and bandwidth-saving MLA inference trick. Accepts the
    same scalar (lockstep) or (B,)-vector (per-slot) ``t``/``slot`` as
    :func:`gqa_step`."""
    B = x.shape[0]
    vec = jnp.ndim(t) > 0
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    from repro.models.common import rmsnorm
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                 params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_new = rmsnorm(kv[..., :kvr], params["kv_norm"])        # (B,1,kvr)
    kr_new = kv[..., kvr:][:, :, None, :]
    pos = t[:, None] if vec else jnp.full((B, 1), t, jnp.int32)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    kr_new = apply_rope(kr_new, pos, cfg.rope_theta)[:, :, 0, :]
    c_kv = _cache_write(cache["c_kv"], c_new, slot, vec)
    k_rope = _cache_write(cache["k_rope"], kr_new, slot, vec)
    # absorb W_uk into the query: q_abs (B,H,kvr)
    q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, params["wk_b"])
    scores = (jnp.einsum("bhr,btr->bht", q_abs.astype(jnp.float32),
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bht", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32)))
    scores *= 1.0 / np.sqrt(dn + dr)
    tt = t[:, None] if vec else t
    valid = (positions_buf >= 0) & (positions_buf <= tt)
    if window is not None:
        valid &= (tt - positions_buf) < window
    scores = jnp.where(valid[:, None, :] if vec else valid[None, None, :],
                       scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", w, c_kv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhk->bhk", ctx.astype(x.dtype), params["wv_b"])
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None, :]
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg, B, cache_len, dtype):
    return {"c_kv": jnp.zeros((B, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, cache_len, cfg.qk_rope_head_dim), dtype)}


# ---------------------------------------------------------------------------
# Cross attention (Whisper decoder). K/V come from the encoder output and
# are precomputed once at prefill time; no rope.
# ---------------------------------------------------------------------------
def cross_kv(params, enc_out, cfg):
    k = jnp.einsum("btd,dhk->bthk", enc_out, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, params["wv"])
    if cfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    return {"xk": k, "xv": v}


def cross_attend(params, x, cfg, kv):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    qg = q.reshape(B, S, KV, H // KV, hd)
    out = _sdpa(qg, kv["xk"], kv["xv"], causal=False).reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])
