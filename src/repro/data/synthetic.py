"""Synthetic federated tasks (the offline stand-ins for MNIST/FMNIST/CIFAR
and the text tasks — see DESIGN.md §6).

Three task families with *controllable difficulty*, so the paper's
grid-search-on-one-task → transfer-to-others protocol is reproducible:

  * ``gaussian_mixture`` — k-class Gaussian blobs through a random rotation,
    difficulty set by class margin and within-class scale ("hard" ≈ CIFAR,
    "easy" ≈ MNIST in the paper's narrative).
  * ``two_layer_teacher`` — labels from a random 2-layer teacher net; the
    optimum has genuinely non-uniform local smoothness.
  * ``image_blobs`` — (H,W,1) images: class-dependent frequency patterns +
    noise, for the CNN model.
  * ``lm_tokens`` — synthetic Markov-chain token streams for the
    transformer archs (vocab-sized transition matrix, per-client priors).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class TaskData:
    name: str
    x: np.ndarray          # (N, ...) float32
    y: np.ndarray          # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


def gaussian_mixture(name: str, *, dim=32, num_classes=10, n_train=50_000,
                     n_test=5_000, margin=3.0, scale=1.0, seed=0,
                     nonlinear=False) -> TaskData:
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim)).astype(np.float32)
    means *= margin / np.linalg.norm(means, axis=1, keepdims=True)
    rot = np.linalg.qr(rng.normal(size=(dim, dim)))[0].astype(np.float32)

    def sample(n):
        y = rng.integers(0, num_classes, n).astype(np.int32)
        x = means[y] + scale * rng.normal(size=(n, dim)).astype(np.float32)
        x = x @ rot
        if nonlinear:
            x = np.tanh(x) + 0.1 * x ** 2
        return x.astype(np.float32), y

    x, y = sample(n_train)
    xt, yt = sample(n_test)
    return TaskData(name, x, y, xt, yt, num_classes)


def two_layer_teacher(name: str, *, dim=32, num_classes=10, hidden=64,
                      n_train=50_000, n_test=5_000, seed=0,
                      temp=1.0) -> TaskData:
    rng = np.random.default_rng(seed)
    w1 = rng.normal(size=(dim, hidden)).astype(np.float32) / np.sqrt(dim)
    w2 = rng.normal(size=(hidden, num_classes)).astype(np.float32) \
        / np.sqrt(hidden)

    def sample(n):
        x = rng.normal(size=(n, dim)).astype(np.float32)
        logits = np.maximum(x @ w1, 0) @ w2 / temp
        # sample labels from the teacher's softmax (label noise built in)
        z = logits - logits.max(1, keepdims=True)
        p = np.exp(z) / np.exp(z).sum(1, keepdims=True)
        y = np.array([rng.choice(num_classes, p=pi) for pi in p],
                     dtype=np.int32)
        return x, y

    x, y = sample(n_train)
    xt, yt = sample(n_test)
    return TaskData(name, x, y, xt, yt, num_classes)


def image_blobs(name: str, *, size=16, num_classes=10, n_train=50_000,
                n_test=5_000, noise=0.5, seed=0) -> TaskData:
    """Class-dependent 2-D sinusoid patterns + Gaussian noise, (H,W,1)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    patterns = np.stack([
        np.sin(2 * np.pi * ((c % 4 + 1) * xx + (c // 4 + 1) * yy
                            + c / num_classes))
        for c in range(num_classes)]).astype(np.float32)

    def sample(n):
        y = rng.integers(0, num_classes, n).astype(np.int32)
        x = patterns[y] + noise * rng.normal(
            size=(n, size, size)).astype(np.float32)
        return x[..., None].astype(np.float32), y

    x, y = sample(n_train)
    xt, yt = sample(n_test)
    return TaskData(name, x, y, xt, yt, num_classes)


def lm_tokens(name: str, *, vocab=256, n_train=4_000, n_test=400, seq=64,
              seed=0, order_sparsity=4) -> TaskData:
    """Markov-chain token sequences; "x" = tokens (N, seq), "y" unused
    (labels are next tokens). Per-sample class = dominant transition block,
    so the Dirichlet partitioner still applies."""
    rng = np.random.default_rng(seed)
    num_classes = 10
    # block-structured transition matrices, one per class
    mats = []
    for c in range(num_classes):
        m = rng.dirichlet(np.full(vocab, 0.05), size=vocab)
        mats.append(m.astype(np.float32))

    def sample(n):
        y = rng.integers(0, num_classes, n).astype(np.int32)
        x = np.zeros((n, seq), np.int32)
        for i in range(n):
            m = mats[y[i]]
            t = rng.integers(0, vocab)
            for j in range(seq):
                x[i, j] = t
                t = rng.choice(vocab, p=m[t])
        return x, y

    x, y = sample(n_train)
    xt, yt = sample(n_test)
    return TaskData(name, x, y, xt, yt, num_classes)


# Named task registry used by benchmarks (difficulty ordering mirrors the
# paper's MNIST < FMNIST < CIFAR-10 < CIFAR-100 ladder).
def get_task(task_id: str, seed: int = 0) -> TaskData:
    if task_id == "easy":        # ~MNIST: well-separated blobs
        return gaussian_mixture("easy", margin=4.0, scale=0.6, seed=seed)
    if task_id == "medium":      # ~FMNIST
        return gaussian_mixture("medium", margin=2.5, scale=1.0,
                                nonlinear=True, seed=seed + 1)
    if task_id == "hard":        # ~CIFAR: teacher net, high label noise
        return two_layer_teacher("hard", temp=0.7, seed=seed + 2)
    if task_id == "image":       # CNN task
        return image_blobs("image", noise=0.8, seed=seed + 3)
    if task_id == "lm":          # text-domain analog
        return lm_tokens("lm", seed=seed + 4)
    raise KeyError(task_id)
