"""Latent-Dirichlet non-iid client partitioner (Hsu et al. 2019), exactly the
paper's protocol: each client draws a label distribution q ~ Dir(α·p) and its
local examples are sampled label-by-label from that distribution.

α = 1 ≈ near-iid; α = 0.1 moderately skewed; α = 0.01 most clients see only
one or two classes (paper Fig. 8).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def dirichlet_partition(y: np.ndarray, num_clients: int, alpha: float,
                        samples_per_client: int = 500, *, seed: int = 0,
                        variable_sizes: Optional[Sequence[int]] = None
                        ) -> List[np.ndarray]:
    """Returns per-client index arrays into ``y``.

    variable_sizes: per-client n_i (paper Appendix B.3 uses
    n_i ~ U[100, 500]); default = samples_per_client for all.
    """
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    by_class = [np.flatnonzero(y == c) for c in range(num_classes)]
    ptr = [0] * num_classes
    for c in range(num_classes):
        rng.shuffle(by_class[c])

    prior = np.full(num_classes, 1.0 / num_classes)
    sizes = (list(variable_sizes) if variable_sizes is not None
             else [samples_per_client] * num_clients)

    clients = []
    for i in range(num_clients):
        q = rng.dirichlet(alpha * prior * num_classes)
        counts = rng.multinomial(sizes[i], q)
        idx = []
        for c, n_c in enumerate(counts):
            take = by_class[c][ptr[c]:ptr[c] + n_c]
            if len(take) < n_c:  # class exhausted: resample with replacement
                extra = rng.choice(by_class[c], n_c - len(take))
                take = np.concatenate([take, extra])
            ptr[c] += n_c
            idx.append(take)
        idx = np.concatenate(idx) if idx else np.empty((0,), np.int64)
        rng.shuffle(idx)
        clients.append(idx.astype(np.int64))
    return clients


def client_label_histogram(y: np.ndarray, clients: List[np.ndarray]
                           ) -> np.ndarray:
    """(num_clients, num_classes) counts — for the Fig. 8 style diagnostic."""
    num_classes = int(y.max()) + 1
    out = np.zeros((len(clients), num_classes), np.int64)
    for i, idx in enumerate(clients):
        for c in range(num_classes):
            out[i, c] = int((y[idx] == c).sum())
    return out
