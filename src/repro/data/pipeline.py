"""Federated batching: client sampling (participation p) and (C, K, b, ...)
round-batch assembly consumed by ``make_fl_round``.

Cohort selection goes through the federation scheduler protocol
(repro.federation.schedulers): a JAX-PRNG draw keyed on (seed, round),
the SAME function the jitted round uses to report cohort composition —
so the ids this pipeline gathers data for and the ids the round engine
sees always agree. The cohort size comes from the shared
``cohort_size`` helper, the single place |S_t| = round(p·m) is computed.

Fleet regime (``num_registered``): the scheduler draws over
C_registered >> C_cohort VIRTUAL clients while the dataset keeps only
``num_clients`` physical partitions — registered client i trains on
partition ``i % num_clients``. Cohort draws, weights, and the arena
gather all key on the REGISTERED id (what the fleet loop's
``ClientArena`` is indexed by); only the example gather maps down to
the physical partition, so a 10^5-client fleet costs no extra dataset
memory.

Also provides the synthetic LM round batches used when training the assigned
transformer architectures federatedly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import TaskData
from repro.federation.schedulers import cohort_size, make_scheduler


@dataclass
class FederatedDataset:
    task: TaskData
    clients: List[np.ndarray]          # per-client example sampling
    rng: np.random.Generator           # within-client example sampling
    seed: int = 0                      # scheduler PRNG seed (cohort draw)
    scenario: object = None            # optional repro.federation.Scenario
    _round: int = field(default=0, repr=False)
    # eval draws get their OWN stream: test_batch must not advance the
    # training-data rng, or the eval cadence would perturb the training
    # trajectory (and break round-fused vs host-loop bit-exactness —
    # the fused loop pre-draws a whole block of round indices before
    # any eval runs).
    eval_rng: np.random.Generator = None
    # fleet regime: registered (virtual) clients >> physical partitions;
    # registered id i maps to partition i % num_clients. None = legacy
    # (registered == num_clients).
    num_registered: Optional[int] = None

    @classmethod
    def build(cls, task: TaskData, *, num_clients: int, alpha: float,
              samples_per_client: int = 500, seed: int = 0,
              variable_sizes=None, scenario=None,
              num_registered=None) -> "FederatedDataset":
        clients = dirichlet_partition(task.y, num_clients, alpha,
                                      samples_per_client, seed=seed,
                                      variable_sizes=variable_sizes)
        return cls(task, clients, np.random.default_rng(seed + 17),
                   seed=seed, scenario=scenario,
                   eval_rng=np.random.default_rng(seed + 23),
                   num_registered=num_registered)

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    @property
    def registered_clients(self) -> int:
        """C_registered — what the schedulers draw over (>= num_clients)."""
        m = self.num_registered
        if m is not None and m < len(self.clients):
            raise ValueError(f"num_registered={m} < {len(self.clients)} "
                             "physical partitions")
        return len(self.clients) if m is None else m

    def client_sizes(self) -> np.ndarray:
        return np.array([len(c) for c in self.clients], np.float32)

    def registered_sizes(self) -> np.ndarray:
        """(C_registered,) per-REGISTERED-client sizes: the physical
        partition sizes cycled over the virtual ids — one numpy array,
        no per-client Python objects at fleet scale."""
        sizes = self.client_sizes()
        m = self.registered_clients
        if m == len(self.clients):
            return sizes
        return sizes[np.arange(m) % len(self.clients)]

    def _scheduler(self, C: int):
        """Scheduler + base key for the cohort draw. With a scenario the
        draw is the scenario's (scheduler kind, seed) — identical to the
        in-round reporting draw; without one it is the uniform scheduler
        keyed on the dataset seed (the seed repo's protocol, now on JAX
        PRNG). The draw runs over the REGISTERED fleet."""
        import jax
        if self.scenario is not None:
            sch = self.scenario.make_scheduler(
                self.registered_clients, C, sizes=self.registered_sizes())
            return sch, jax.random.key(self.scenario.seed)
        sch = make_scheduler("uniform",
                             num_clients=self.registered_clients,
                             cohort=C)
        return sch, jax.random.key(self.seed)

    def sample_round_indices(self, participation: float, local_steps: int,
                             batch_size: int,
                             round_idx: Optional[int] = None):
        """Cohort draw + within-client example draw WITHOUT gathering:
        returns (take (C, K, b) int32 indices into the task arrays,
        client_weights (C,), client_ids). Consumes the exact rng stream
        ``sample_round`` consumes, so a run that pre-computes index
        blocks for the round-fused loop sees the same batches a
        round-at-a-time run would gather. ``client_ids`` are REGISTERED
        ids; the example gather maps them to physical partitions
        (i % num_clients).

        BOTH draws are keyed on (seed, round) — the cohort through the
        scheduler, the within-client example draw through a per-round
        generator derived here — never on call history: a --resume
        restarting at round T stages the exact batches the
        uninterrupted run staged for T (the resume-parity tests in
        tests/test_checkpoint.py / test_serving.py pin this; a stateful
        stream would desync the moment the replayed prefix is
        skipped)."""
        m = self.num_clients
        C = cohort_size(participation, self.registered_clients)
        t = self._round if round_idx is None else round_idx
        if round_idx is None:
            self._round += 1
        sch, key = self._scheduler(C)
        ids = np.asarray(sch.sample(key, t))
        ex_rng = np.random.default_rng([self.seed + 17, int(t)])
        takes = []
        for i in ids:
            idx = self.clients[i % m]
            take = ex_rng.choice(idx, size=local_steps * batch_size,
                                 replace=len(idx) < local_steps
                                 * batch_size)
            takes.append(take.reshape(local_steps, batch_size))
        weights = self.client_sizes()[ids % m]
        return (np.stack(takes).astype(np.int32),
                weights.astype(np.float32), ids)

    def sample_round(self, participation: float, local_steps: int,
                     batch_size: int, round_idx: Optional[int] = None):
        """Returns (client_batches dict of (C,K,b,...) arrays,
        client_weights (C,), client_ids).

        ``round_idx`` defaults to an internal counter (one per call), so
        driver loops that also track rounds can pass their own t and
        stay aligned with the jitted round's scenario draws."""
        take, weights, ids = self.sample_round_indices(
            participation, local_steps, batch_size, round_idx)
        batches = {"x": self.task.x[take], "y": self.task.y[take]}
        return batches, weights, ids

    def sample_block(self, participation: float, local_steps: int,
                     batch_size: int, *, round0: int, rounds: int):
        """R rounds of gather indices for ONE round-fused loop call
        (core.fed_loop): (idx (R, C, K, b) int32, weights (R, C),
        ids (R, C)). The cohort draws are keyed on round0..round0+R-1 —
        the same (seed, round) keys the in-scan scheduler reporting
        uses — and the within-client rng stream advances in round order,
        matching an equivalent sequence of ``sample_round`` calls."""
        take, w, ids = zip(*(self.sample_round_indices(
            participation, local_steps, batch_size, round_idx=round0 + r)
            for r in range(rounds)))
        return np.stack(take), np.stack(w), np.stack(ids)

    def arena(self):
        """The device-stageable example arena the fused loop gathers
        from: the full task arrays, staged once per run instead of
        re-shipping (C, K, b, ...) batches every round."""
        return {"x": self.task.x, "y": self.task.y}

    def epoch_steps(self, batch_size: int) -> int:
        """K for one local epoch (paper: K = E·n_i / b with E = 1)."""
        n = int(np.median(self.client_sizes()))
        return max(1, n // batch_size)

    def test_batch(self, n: Optional[int] = None):
        if n is None or n >= len(self.task.y_test):
            return self.task.x_test, self.task.y_test
        rng = self.eval_rng if self.eval_rng is not None else self.rng
        idx = rng.choice(len(self.task.y_test), n, replace=False)
        return self.task.x_test[idx], self.task.y_test[idx]


def lm_round_batches(rng: np.random.Generator, *, clients: int,
                     local_steps: int, batch: int, seq: int, vocab: int,
                     extras: Optional[Dict] = None):
    """Synthetic LM round batch (C, K, b, S) tokens + next-token labels.
    ``extras`` adds stub-frontend arrays (frames / image_embeds) with a
    (C, K, b, ...) leading layout."""
    toks = rng.integers(0, vocab, (clients, local_steps, batch, seq + 1),
                        dtype=np.int32)
    out = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    if extras:
        for k, shape in extras.items():
            out[k] = rng.normal(size=(clients, local_steps, batch) + shape
                                ).astype(np.float32)
    return out
