"""Device-native telemetry plane: observe without perturbing.

Four pieces (PR 8):

  * :mod:`repro.telemetry.schema` — typed metric registry every
    producer registers into; renders ``docs/TELEMETRY.md``.
  * :mod:`repro.telemetry.spec` — :class:`TelemetrySpec` trace-time
    switch + :func:`round_telemetry`, the in-scan distribution block
    (η histogram, loss deciles, guard hit counts) that rides the fused
    loop's (R, ·) metrics stack. Read-only over round-end values:
    trajectories are bit-exact with telemetry on vs off.
  * :mod:`repro.telemetry.events` — buffered JSONL sink with a run
    metadata header, flushed once per block boundary (zero per-round
    host syncs inside a block).
  * :mod:`repro.telemetry.spans` / :mod:`repro.telemetry.profiling` —
    span wall-clock accounting and ``jax.profiler`` / compile-time
    static telemetry hooks for ``--profile``.
"""
from . import schema
from .events import EventLog, config_hash, load_events, run_metadata
from .profiling import (kernel_launch_snapshot, reset_kernel_launches,
                        static_telemetry, trace_block)
from .spans import SpanTimer
from .spec import TelemetrySpec, resolve_telemetry, round_telemetry

__all__ = [
    "schema", "EventLog", "config_hash", "load_events", "run_metadata",
    "kernel_launch_snapshot", "reset_kernel_launches", "static_telemetry",
    "trace_block", "SpanTimer", "TelemetrySpec", "resolve_telemetry",
    "round_telemetry",
]
