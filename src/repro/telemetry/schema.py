"""Typed metric schema registry: the single source of truth for every
metric name the round engines emit.

Every producer — ``core.fed_round._round_metrics``, the faults
round-health block, the compression wire accounting, the fleet arena
bookkeeping, the telemetry distributions — has its keys registered
here as a :class:`MetricSpec` (dtype, shape, unit, per-run reduction,
docstring, producer module). Consumers stop hardcoding key lists:

  * ``launch/train._ScenarioStats`` collects every registered metric
    (and warns ONCE per unregistered producer name instead of silently
    dropping it — the old ``KEYS`` whitelist bug);
  * ``launch/report.scenario_summary`` derives its per-run aggregation
    from each spec's ``summaries``;
  * ``scripts/gen_docs.py`` renders ``docs/TELEMETRY.md`` from
    :func:`markdown_table` under the docs-drift CI gate.

Shapes are symbolic: ``"()"`` scalar, ``"(C,)"`` per-cohort-client,
``"(B,)"`` η-histogram bins, ``"(Q,)"`` quantile points. Only scalars
and the fixed-shape distribution vectors ride in the fused loop's
scanned metrics block (every leaf gains a leading R axis there).
"""
from __future__ import annotations

import warnings
from typing import Dict, NamedTuple, Optional, Tuple


class MetricSpec(NamedTuple):
    """One registered metric. ``summaries`` maps the per-round stream
    to per-run report fields: ``(out_name, reduction)`` pairs with
    reduction in {mean, sum, min, max}; empty = reported elsewhere
    (the round log / eval path) or not aggregated."""
    name: str
    dtype: str = "f32"
    shape: str = "()"
    unit: str = ""
    doc: str = ""
    producer: str = ""
    summaries: Tuple[Tuple[str, str], ...] = ()


REGISTRY: Dict[str, MetricSpec] = {}

_REDUCTIONS = ("mean", "sum", "min", "max")


def register(name: str, **kw) -> MetricSpec:
    """Register (or re-register, idempotently) one metric name."""
    spec = MetricSpec(name=name, **kw)
    for _, red in spec.summaries:
        if red not in _REDUCTIONS:
            raise ValueError(f"{name}: unknown reduction {red!r} "
                             f"(expected one of {_REDUCTIONS})")
    REGISTRY[name] = spec
    return spec


def get(name: str) -> Optional[MetricSpec]:
    return REGISTRY.get(name)


def specs() -> Tuple[MetricSpec, ...]:
    return tuple(REGISTRY.values())


def is_scalar(name: str) -> bool:
    spec = REGISTRY.get(name)
    return spec is not None and spec.shape == "()"


_warned: set = set()


def warn_unregistered(name: str, producer: str = "") -> None:
    """Warn ONCE per unregistered metric name (a producer emitting a
    key the registry does not know about — register it in
    repro.telemetry.schema instead of silently dropping it)."""
    if name in _warned:
        return
    _warned.add(name)
    src = f" (from {producer})" if producer else ""
    warnings.warn(f"metric {name!r}{src} is not registered in "
                  f"repro.telemetry.schema — add a MetricSpec so "
                  f"reports and docs can carry it", stacklevel=2)


def markdown_table() -> str:
    """The docs/TELEMETRY.md metric table (scripts/gen_docs.py)."""
    lines = ["| metric | shape | dtype | unit | per-run summary | "
             "producer | description |",
             "|---|---|---|---|---|---|---|"]
    for s in REGISTRY.values():
        summ = ("; ".join(f"{red} → `{out}`" for out, red in s.summaries)
                if s.summaries else "—")
        lines.append(f"| `{s.name}` | `{s.shape}` | {s.dtype} | "
                     f"{s.unit or '—'} | {summ} | `{s.producer}` | "
                     f"{s.doc} |")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# registrations, grouped by producer
# ---------------------------------------------------------------------------

_CORE = "core.fed_round._round_metrics"
register("loss", unit="nats", producer=_CORE,
         doc="mean per-step training loss over the cohort's active "
             "local steps")
register("loss_last_step", unit="nats", producer=_CORE,
         doc="mean loss at each client's last (K_c-th) local step")
register("eta_mean", unit="step size", producer=_CORE,
         doc="cohort mean of the round-end Δ-SGD step size")
register("eta_min", unit="step size", producer=_CORE,
         doc="cohort minimum round-end η")
register("eta_max", unit="step size", producer=_CORE,
         doc="cohort maximum round-end η")

_SCN = "core.fed_round._scenario_extras"
register("cohort_ids", dtype="i32", shape="(C,)", producer=_SCN,
         doc="the scheduler's cohort draw for the round (the SAME "
             "(seed, round)-keyed draw the data pipeline gathered)")
register("k_eff_mean", producer=_SCN, unit="steps",
         summaries=(("k_eff_mean", "mean"),),
         doc="mean drawn per-client step budget K_c")
register("k_eff_min", producer=_SCN, unit="steps",
         summaries=(("k_eff_min", "min"),),
         doc="min drawn K_c")
register("k_eff_max", producer=_SCN, unit="steps",
         summaries=(("k_eff_max", "max"),),
         doc="max drawn K_c")

_ASYNC = "core.fed_round (async tail)"
register("stale_mean", producer=_ASYNC, unit="rounds",
         summaries=(("stale_mean", "mean"),),
         doc="mean drawn staleness of the round's buffered updates")
register("stale_max", producer=_ASYNC, unit="rounds",
         summaries=(("stale_max", "max"),),
         doc="max drawn staleness")
register("buffer_fill", producer=_ASYNC, unit="updates",
         summaries=(("buffer_fill_mean", "mean"),),
         doc="FedBuff buffer occupancy after the round's merge")
register("flushed", producer=_ASYNC,
         summaries=(("flush_rate", "mean"),),
         doc="1.0 when the buffer reached M updates and the server "
             "stepped this round")

_COMP = "core.fed_round (compression)"
register("wire_bytes", producer=_COMP, unit="bytes",
         summaries=(("wire_bytes_round", "mean"),
                    ("wire_bytes_total", "sum")),
         doc="cohort-total compressed delta payload for the round")
register("comp_ratio", producer=_COMP, unit="x",
         summaries=(("comp_ratio", "mean"),),
         doc="full-precision f32 delta bytes / wire bytes")
register("comp_level_mean", producer=_COMP,
         summaries=(("comp_level_mean", "mean"),),
         doc="mean drawn per-client compression level "
             "(bandwidth-heterogeneous scenarios)")

_FAULT = "federation.faults round health"
register("eta_clip_rate", producer=_FAULT,
         summaries=(("eta_clip_rate", "mean"),),
         doc="fraction of (client, step) lanes whose η hit the "
             "ETA_CLAMP guard ceiling")
register("nan_guard_rate", producer=_FAULT,
         summaries=(("nan_guard_rate", "mean"),),
         doc="fraction of clients whose NaN guard latched this round")
register("valid_count", producer=_FAULT, unit="clients",
         summaries=(("valid_mean", "mean"),),
         doc="clients surviving the round's faults (guard tail only)")
register("round_skipped", producer=_FAULT,
         summaries=(("skipped_rounds", "sum"),),
         doc="1.0 when the quorum check skipped the server update")
register("drop_frac", producer=_FAULT,
         summaries=(("drop_frac", "mean"),),
         doc="fraction of clients that dropped mid-round")
register("byz_frac", producer=_FAULT,
         summaries=(("byz_frac", "mean"),),
         doc="fraction of byzantine clients this round")
register("overstale_frac", producer=_FAULT,
         summaries=(("overstale_frac", "mean"),),
         doc="fraction of updates forced over the staleness ceiling")
register("agg_clip_rate", producer="federation.faults.robust_aggregate",
         summaries=(("agg_clip_rate", "mean"),),
         doc="fraction of client deltas clipped by the robust "
             "aggregator's norm ceiling")

_FLEET = "core.fed_loop.make_fleet_loop"
register("revisit_frac", producer=_FLEET,
         summaries=(("revisit_frac", "mean"),),
         doc="fraction of the cohort that participated before")
register("realized_stale_mean", producer=_FLEET, unit="rounds",
         summaries=(("realized_stale_mean", "mean"),),
         doc="mean rounds since a returning client's last "
             "participation")
register("eta_carry_mean", producer=_FLEET, unit="step size",
         summaries=(("eta_carry_mean", "mean"),),
         doc="mean arena-carried η entering the round")

_TELE = "telemetry.spec.round_telemetry"
register("eta_hist", shape="(B,)", producer=_TELE, unit="clients",
         summaries=(("eta_hist", "sum"),),
         doc="per-round η distribution over client lanes: counts in "
             "log-spaced bins (TelemetrySpec.eta_edges; first bin = "
             "underflow, last = overflow)")
register("loss_deciles", shape="(Q,)", producer=_TELE, unit="nats",
         summaries=(("loss_deciles", "mean"),),
         doc="per-client mean-loss order statistics: min, deciles, "
             "max (Q=11)")
register("eta_clip_count", producer=_TELE, unit="lanes",
         summaries=(("eta_clip_count", "sum"),),
         doc="absolute count of η-clamp guard hits this round")
register("nan_guard_count", producer=_TELE, unit="clients",
         summaries=(("nan_guard_count", "sum"),),
         doc="absolute count of NaN-guard latches this round")

_SERVE = "serving.engine"
register("serve_tokens", dtype="i32", producer=_SERVE, unit="tokens",
         summaries=(("serve_tokens_total", "sum"),),
         doc="decode tokens emitted this flush interval (all slots, "
             "after per-request budget truncation)")
register("serve_occupancy", producer=_SERVE,
         summaries=(("serve_occupancy_mean", "mean"),),
         doc="active slots / pool slots at this flush (continuous-"
             "batching utilization)")
register("serve_version", dtype="i32", producer=_SERVE, unit="round",
         summaries=(("serve_version_last", "max"),),
         doc="training round of the params that produced every token "
             "of this flush (hot-swaps land only at flush boundaries)")
register("serve_swapped", dtype="i32", producer=_SERVE,
         summaries=(("serve_swaps_total", "sum"),),
         doc="1 when a staged checkpoint version hot-swapped in at "
             "this flush boundary")
register("serve_swap_stall_s", producer=_SERVE, unit="s",
         summaries=(("serve_swap_stall_mean", "mean"),
                    ("serve_swap_stall_max", "max")),
         doc="registry-notice to traffic-serving delay of the swap "
             "applied at this flush (restore + wait-to-boundary)")

_LOADGEN = "serving.loadgen.run_load"
register("serve_tok_per_s", producer=_LOADGEN, unit="tokens/s",
         summaries=(("serve_tok_per_s", "max"),),
         doc="load-generator end-to-end decode throughput")
register("serve_latency_p50_s", producer=_LOADGEN, unit="s",
         summaries=(("serve_latency_p50_s", "max"),),
         doc="median request latency (submit to last token, queueing "
             "included under poisson arrivals)")
register("serve_latency_p99_s", producer=_LOADGEN, unit="s",
         summaries=(("serve_latency_p99_s", "max"),),
         doc="99th-percentile request latency")
