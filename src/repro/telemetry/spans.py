"""Lightweight span timing for the launch drivers.

Wall-clock accounting over named phases (compile / pack / stage /
block-execute / eval / ckpt) with near-zero overhead: one
``perf_counter`` pair per span, accumulated in a dict. The summary
lands in the event log's ``spans`` event and the end-of-run print —
the coarse picture a ``--profile`` trace then drills into.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict


class SpanTimer:
    """Accumulating span timer: ``with spans.span("block_execute"): ...``."""

    def __init__(self):
        self._acc: Dict[str, list] = {}

    @contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            cell = self._acc.setdefault(name, [0.0, 0])
            cell[0] += dt
            cell[1] += 1

    def add(self, name: str, seconds: float) -> None:
        """Manual accumulation for spans not expressible as a with
        block (e.g. compile time split out of the first block call)."""
        cell = self._acc.setdefault(name, [0.0, 0])
        cell[0] += seconds
        cell[1] += 1

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {k: {"s": round(v[0], 6), "n": v[1]}
                for k, v in self._acc.items()}

    def __str__(self) -> str:
        return " ".join(f"{k} {v[0]:.2f}s/{v[1]}"
                        for k, v in sorted(self._acc.items()))
