"""Host-side structured event log: a buffered JSONL sink.

Line 1 is a run-metadata header (config hash, git sha, mesh, jax /
backend versions); every later line is one event dict with a ``kind``
field. ``emit()`` only appends to an in-memory buffer — device arrays
included, UNCONVERTED — and ``flush()`` does the single host sync +
write. The drivers flush at block boundaries only, so the fused hot
loop stays free of per-round host transfers (the zero-host-sync test
in tests/test_telemetry.py runs a fused block under
``jax.transfer_guard("disallow")``).

Consumed by ``launch/report.py`` (``load_events``) and the bench
suites.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time
from typing import Any, Dict, Optional


def config_hash(config: Optional[dict]) -> str:
    """Stable short hash of a (JSON-able) run config."""
    if not config:
        return ""
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except Exception:
        return ""


def run_metadata(config: Optional[dict] = None,
                 mesh: Any = None) -> Dict[str, Any]:
    """The header payload: enough to tie an event stream back to the
    exact code + config + runtime that produced it."""
    import jax
    meta: Dict[str, Any] = {
        "kind": "header",
        "time": time.time(),
        "git_sha": git_sha(),
        "config_hash": config_hash(config),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "mesh": (dict(zip((str(a) for a in mesh.axis_names),
                          (int(s) for s in mesh.devices.shape)))
                 if mesh is not None else None),
    }
    if config:
        meta["config"] = config
    return meta


def _jsonable(v):
    """Device/np leaves -> plain python at FLUSH time (the only host
    sync in the pipeline)."""
    import numpy as np
    if hasattr(v, "ndim"):        # jax / np array
        a = np.asarray(v)
        return a.item() if a.ndim == 0 else a.tolist()
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


class EventLog:
    """Buffered JSONL event sink; see module docstring.

    Usable as a context manager; ``close()`` flushes. ``emit()`` is
    sync-free by contract: values (device arrays included) are stored
    as-is and converted in ``flush()``."""

    def __init__(self, path: str, *, config: Optional[dict] = None,
                 mesh: Any = None):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._buf: list = []
        self._f = open(path, "w", encoding="utf-8")
        self._f.write(json.dumps(run_metadata(config, mesh),
                                 default=str) + "\n")
        self._f.flush()
        self.events_written = 0

    def emit(self, kind: str, **fields) -> None:
        self._buf.append((kind, fields))

    def flush(self) -> int:
        """Convert + write every buffered event; returns the count."""
        n = len(self._buf)
        for kind, fields in self._buf:
            row = {"kind": kind}
            row.update({k: _jsonable(v) for k, v in fields.items()})
            self._f.write(json.dumps(row, default=str) + "\n")
        self._buf.clear()
        self._f.flush()
        self.events_written += n
        return n

    def close(self) -> None:
        if not self._f.closed:
            self.flush()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def load_events(path: str):
    """-> (header dict, [event dicts]) from a JSONL artifact."""
    with open(path, encoding="utf-8") as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    if not lines or lines[0].get("kind") != "header":
        raise ValueError(f"{path}: missing event-log header line")
    return lines[0], lines[1:]
