"""TelemetrySpec: the trace-time switch for the in-scan telemetry
block, plus the device-side metric computation it gates.

Non-perturbing by construction: :func:`round_telemetry` only READS
round-end values (``S.eta``, the loss matrix, the guard latches) and
adds new keys to the metrics dict — it never touches the update path,
so trajectories are bit-exact with telemetry on vs off
(tests/test_telemetry.py pins this on the host, fused, and 8-device
block engines). All outputs are fixed-shape, so they ride as extra
leaves of the fused loop's scanned (R, ·) metrics block with zero host
syncs inside a block.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import numpy as np


class TelemetrySpec(NamedTuple):
    """In-scan telemetry configuration (trace-time constants).

    ``eta_bins`` log-spaced η bins between ``eta_lo`` and ``eta_hi``
    (first bin catches [0, eta_lo), last [eta_hi, inf) — Δ-SGD's η is
    nonnegative); ``loss_deciles`` adds the per-client mean-loss order
    statistics (skipped on the block-sharded path, where deciles would
    need a cross-client sort)."""
    enabled: bool = False
    eta_bins: int = 16
    eta_lo: float = 1e-4
    eta_hi: float = 10.0
    loss_deciles: bool = True
    quantiles: int = 11

    def eta_edges(self) -> np.ndarray:
        """(eta_bins+1,) ascending f32 bin edges: 0, log-spaced
        interior, +inf."""
        if self.eta_bins < 3:
            raise ValueError(f"eta_bins must be >= 3 (underflow + >=1 "
                             f"log bin + overflow), got {self.eta_bins}")
        interior = np.logspace(np.log10(self.eta_lo),
                               np.log10(self.eta_hi),
                               self.eta_bins - 1)
        return np.concatenate([[0.0], interior, [np.inf]]
                              ).astype(np.float32)


def resolve_telemetry(telemetry: Union[None, bool, TelemetrySpec]
                      ) -> TelemetrySpec:
    """None/False -> disabled spec; True -> enabled defaults; a spec
    passes through."""
    if isinstance(telemetry, TelemetrySpec):
        return telemetry
    if telemetry is None or telemetry is False:
        return TelemetrySpec()
    if telemetry is True:
        return TelemetrySpec(enabled=True)
    raise ValueError(f"telemetry must be None, bool, or TelemetrySpec, "
                     f"got {telemetry!r}")


def round_telemetry(tele: TelemetrySpec, etas, losses, clips=None,
                    valid=None, *, backend: str = "xla",
                    use_kernel: Optional[bool] = None, rep=lambda x: x
                    ) -> dict:
    """The in-scan telemetry block for one round: η histogram over
    client lanes, per-client mean-loss deciles, absolute guard/clip hit
    counts. Pure read-only function of round-end values — adding it to
    a metrics dict cannot perturb the trajectory.

    ``use_kernel`` selects the Pallas kernels (kernels/telemetry, own
    LAUNCHES counter); default: only on the un-meshed pallas engine —
    jnp ref math elsewhere (meshed/pjit callers and ``backend="xla"``),
    mirroring how the Δ-SGD engines pick their backend. ``rep`` pins
    outputs replicated under meshes (same contract as the scenario
    draws)."""
    import jax.numpy as jnp

    if not tele.enabled:
        return {}
    from repro.kernels import telemetry as tk
    if use_kernel is None:
        use_kernel = backend == "pallas"
    edges = jnp.asarray(tele.eta_edges())
    out = {}
    if use_kernel:
        out["eta_hist"] = rep(tk.lane_histogram(etas, edges))
    else:
        out["eta_hist"] = rep(tk.lane_histogram_ref(etas, edges))
    if tele.loss_deciles:
        client_loss = jnp.mean(losses.astype(jnp.float32), axis=1)
        if use_kernel:
            out["loss_deciles"] = rep(
                tk.lane_quantiles(client_loss, tele.quantiles))
        else:
            out["loss_deciles"] = rep(
                tk.lane_quantiles_ref(client_loss, tele.quantiles))
    if clips is not None:
        out["eta_clip_count"] = jnp.sum(clips.astype(jnp.float32))
    if valid is not None:
        out["nan_guard_count"] = jnp.sum((~valid).astype(jnp.float32))
    return out
