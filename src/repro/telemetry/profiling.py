"""Profiler hooks: compile-time static telemetry + one-block traces.

``static_telemetry`` turns a compiled fused loop into a telemetry row
at COMPILE time — no execution needed: Pallas launch counts (from the
per-namespace trace-time counters), collective instruction count and
payload bytes per round (``roofline.parse_collectives`` over the
compiled HLO). The launch drivers emit it as a ``"static"`` event so a
perf regression shows up in the JSONL artifact even when the run
itself is too short to time.

``trace_block`` wraps one block execution in a ``jax.profiler`` trace
(uploaded as a CI artifact); failures degrade to a warning — profiling
must never take the run down.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional


def static_telemetry(compiled, *, rounds: int = 1,
                     launches: Optional[Dict[str, int]] = None) -> Dict:
    """Compile-time telemetry row for a compiled executable covering
    ``rounds`` rounds: collective count / payload bytes per round, plus
    any trace-time launch counters the caller snapshotted."""
    from repro import roofline

    hlo = compiled.as_text()
    colls = roofline.parse_collectives(hlo)
    rounds = max(rounds, 1)
    row = {
        "rounds": rounds,
        "collective_count": len(colls),
        "collectives_per_round": len(colls) / rounds,
        "collective_bytes": int(sum(c.bytes for c in colls)),
        "collective_bytes_per_round": sum(c.bytes for c in colls) / rounds,
        "collective_wire_bytes": float(sum(c.wire_bytes for c in colls)),
        "collective_kinds": sorted({c.kind for c in colls}),
        "hlo_instructions": hlo.count("\n"),
    }
    if launches is not None:
        row["pallas_launches"] = dict(launches)
        row["pallas_launches_per_round"] = {
            k: v / rounds for k, v in launches.items()}
    return row


def kernel_launch_snapshot() -> Dict[str, int]:
    """Merged view of every kernel namespace's trace-time LAUNCHES
    counter, keys prefixed by namespace."""
    out: Dict[str, int] = {}
    from repro.kernels import telemetry as tk
    from repro.kernels.compress import compress as ck
    from repro.kernels.delta_sgd import delta_sgd as dk
    for ns, counter in (("delta_sgd", dk.LAUNCHES),
                        ("compress", ck.LAUNCHES),
                        ("telemetry", tk.LAUNCHES)):
        for k, v in counter.items():
            out[f"{ns}/{k}"] = int(v)
    return out


def reset_kernel_launches() -> None:
    from repro.kernels import telemetry as tk
    from repro.kernels.compress import compress as ck
    from repro.kernels.delta_sgd import delta_sgd as dk
    dk.reset_launch_count()
    ck.LAUNCHES.clear()
    tk.reset_launch_count()


def trace_block(fn: Callable, logdir: str):
    """Run ``fn()`` under a ``jax.profiler`` trace written to
    ``logdir``; returns fn's result. Trace failures warn, never raise."""
    import jax

    try:
        with jax.profiler.trace(logdir):
            out = fn()
            jax.block_until_ready(out)
        return out
    except Exception as e:  # profiling is best-effort by contract
        warnings.warn(f"jax.profiler trace failed ({e!r}); "
                      f"running block untraced")
        return fn()
