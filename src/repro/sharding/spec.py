"""FederationSpec + sharding rules: how FL roles map onto mesh axes.

Production mesh (launch/mesh.py): (pod, data, model) = (2, 16, 16) multi-pod
or (data, model) = (16, 16) single-pod.

FL mapping:
  client_axes — mesh axes that enumerate simultaneously-trained clients
                (the FedAvg aggregation all-reduces over these);
  fsdp_axes   — within-client param/optimizer sharding (ZeRO-style);
  tp_axes     — tensor parallel (heads / experts / ffn).

Two stock specs:
  * cross_device : clients over (pod, data) — many small clients
    (tinyllama-class models, one model replica per (pod,data) coordinate,
    sharded over `model`).
  * cross_silo   : clients over (pod,) — 2 giant silos; each silo trains
    FSDP over `data` × TP over `model` (deepseek-class models).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class FederationSpec:
    client_axes: Tuple[str, ...]
    fsdp_axes: Tuple[str, ...]
    tp_axes: Tuple[str, ...] = ("model",)
    # Beyond-paper (§Perf): shard the expert dim over tp×fsdp jointly
    # (1 expert per device for deepseek on 16×16) — expert weights are
    # never FSDP-gathered; tokens travel via all-to-all instead.
    expert_2d: bool = False

    def clients_on(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.client_axes])) or 1

    # -- flat (C, N) buffer layout (core/flat.py packed engine) ------------
    def flat_axes(self, mesh: Mesh):
        """(client_axes, param_shard_axes) for the packed (C, N) buffer:
        C over the client axes, N over every remaining fsdp/tp axis present
        in the mesh. Disjoint by construction."""
        ca = tuple(a for a in self.client_axes if a in mesh.shape)
        na = tuple(a for a in self.fsdp_axes + self.tp_axes
                   if a in mesh.shape and a not in ca)
        return ca, na

    def flat_spec(self, mesh: Mesh) -> P:
        """PartitionSpec for the packed (C, N) flat buffer: clients over
        the client axes, the flat param dim over fsdp+tp axes. The layout
        must be built with ``shards=self.flat_shards(mesh)`` so every
        device's slab stays lane/row-block aligned."""
        ca, na = self.flat_axes(mesh)
        return P(ca if ca else None, na if na else None)

    def flat_client_spec(self, mesh: Mesh) -> P:
        """PartitionSpec for per-client (C,) vectors (η, θ, ‖g‖)."""
        ca, _ = self.flat_axes(mesh)
        return P(ca if ca else None)

    def flat_shards(self, mesh: Mesh) -> int:
        """Number of shards of the flat param dim N under flat_spec."""
        _, na = self.flat_axes(mesh)
        return int(np.prod([mesh.shape[a] for a in na])) or 1


def cross_device(mesh: Mesh) -> FederationSpec:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return FederationSpec(client_axes=axes, fsdp_axes=())


def cross_silo(mesh: Mesh) -> FederationSpec:
    if "pod" in mesh.shape:
        return FederationSpec(client_axes=("pod",), fsdp_axes=("data",))
    # single-pod: the pod IS the silo -> one client, FSDP+TP inside it.
    return FederationSpec(client_axes=(), fsdp_axes=("data",))


def get_federation_spec(kind: str, mesh: Mesh) -> FederationSpec:
    return {"cross_device": cross_device, "cross_silo": cross_silo}[kind](mesh)


# ---------------------------------------------------------------------------
# Param sharding rules: regex on the param path -> PartitionSpec (rightmost
# dims). Leading stacked-layer axes are padded with None automatically.
# ---------------------------------------------------------------------------
def _param_rules(spec: FederationSpec):
    fsdp = spec.fsdp_axes or None
    tp = spec.tp_axes or None
    f = fsdp[0] if fsdp else None
    t = tp[0] if tp else None
    return [
        # embeddings / head
        (r"embed$",                    (t, f)),
        (r"lm_head$",                  (f, t)),
        # attention
        (r"attn/wq$",                  (f, t, None)),
        (r"attn/w[kv]$",               (f, "kv", None)),
        (r"attn/wo$",                  (t, None, f)),
        (r"attn/b[qkv]$",              (None, None)),
        # MLA
        (r"attn/wq_a$",                (f, None)),
        (r"attn/wq_b$",                (None, t, None)),
        (r"attn/wkv_a$",               (f, None)),
        (r"attn/w[kv]_b$",             (None, t, None)),
        # cross attention
        (r"xattn/wq$",                 (f, t, None)),
        (r"xattn/w[kv]$",              (f, "kv", None)),
        (r"xattn/wo$",                 (t, None, f)),
        # dense mlp
        (r"mlp/w_(gate|in)$",          (f, t)),
        (r"mlp/w_out$",                (t, f)),
        (r"mlp/b_in$",                 (t,)),
        (r"mlp/b_out$",                (None,)),
        # moe
        (r"moe/router$",               (f, None)),
        (r"moe/w_(gate|in)$",          (("e2d" if spec.expert_2d else t),
                                        (None if spec.expert_2d else f),
                                        None)),
        (r"moe/w_out$",                (("e2d" if spec.expert_2d else t),
                                        None,
                                        (None if spec.expert_2d else f))),
        (r"moe/shared/w_(gate|in)$",   (f, t)),
        (r"moe/shared/w_out$",         (t, f)),
        # mamba2
        (r"mixer/w_zx$",               (f, t)),
        (r"mixer/w_dt$",               (f, "heads_t")),
        (r"mixer/conv_w$",             (None, t)),
        (r"mixer/conv_b$",             (t,)),
        (r"mixer/(A_log|dt_bias|D_skip)$", ("heads_t",)),
        (r"mixer/norm$",               (t,)),
        (r"mixer/w_out$",              (t, f)),
        # mlstm / slstm
        (r"mixer/w_up$",               (f, t)),
        (r"mixer/w[qkv]$",             (t, None)),
        (r"mixer/w_if$",               (t, None)),
        (r"mixer/w_x$",                (f, t)),
        (r"mixer/r$",                  (None, "hd_t", None)),
        (r"mixer/ff_gate$",            (f, t)),
        (r"mixer/ff_out$",             (t, f)),
        # mtp
        (r"mtp/proj$",                 (f, t)),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspec(spec: FederationSpec, path: str, leaf) -> P:
    """PartitionSpec for one param leaf. Axis names 'kv'/'heads_t'/'hd_t'
    mean: use tp if the dim is divisible by the tp size, else None."""
    rules = _param_rules(spec)
    for pat, dims in rules:
        if re.search(pat, path):
            nd = leaf.ndim
            dims = tuple(dims)
            if len(dims) > nd:     # un-stacked rule longer than leaf rank
                dims = dims[-nd:]
            pad = (None,) * (nd - len(dims))
            return P(*(pad + dims))
    return P(*((None,) * leaf.ndim))


def _resolve_conditional(pspec: P, shape, mesh: Mesh, tp_axis: str) -> P:
    """Resolve 'kv'/'heads_t'/'hd_t' placeholders to tp-or-None based on
    divisibility; also drop any tp/fsdp assignment that doesn't divide."""
    out = []
    for dim, name in zip(shape, pspec):
        if name in ("kv", "heads_t", "hd_t"):
            name = tp_axis
        if name == "e2d":
            cand = tuple(a for a in (tp_axis, "data") if a in mesh.shape)
            name = cand if len(cand) > 1 else (cand[0] if cand else None)
        if name is None:
            out.append(None)
            continue
        axes = name if isinstance(name, tuple) else (name,)
        size = int(np.prod([mesh.shape.get(a, 1) for a in axes]))
        out.append(name if size and dim % size == 0 else None)
    return P(*out)


def make_param_shardings(spec: FederationSpec, mesh: Mesh, params_shape):
    """NamedSharding pytree matching a params shape-pytree."""
    tp_axis = spec.tp_axes[0] if spec.tp_axes else None

    def one(path, leaf):
        ps = param_pspec(spec, _path_str(path), leaf)
        ps = _resolve_conditional(ps, leaf.shape, mesh, tp_axis)
        ps = _dedupe(ps)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _dedupe(ps: P) -> P:
    """A mesh axis may appear at most once in a PartitionSpec."""
    seen = set()
    out = []
    for name in ps:
        axes = name if isinstance(name, tuple) else (name,)
        if name is not None and any(a in seen for a in axes):
            out.append(None)
        else:
            out.append(name)
            seen.update(a for a in axes if a)
    return P(*out)


# ---------------------------------------------------------------------------
# Batch / cache / state shardings
# ---------------------------------------------------------------------------
def batch_shardings(spec: FederationSpec, mesh: Mesh, batch_shape):
    """FL round batches: leaves (C, K, b, ...): C over client axes, b over
    fsdp axes."""
    ca = spec.client_axes if len(spec.client_axes) > 1 else \
        (spec.client_axes[0] if spec.client_axes else None)
    fa = spec.fsdp_axes[0] if spec.fsdp_axes else None

    def one(leaf):
        dims = [ca, None, fa] + [None] * (leaf.ndim - 3)
        return NamedSharding(mesh, P(*dims[:leaf.ndim]))

    return jax.tree.map(one, batch_shape)


def serve_batch_shardings(mesh: Mesh, batch_shape, *, data_axes=("data",)):
    """Serving: batch dim over all data-like axes present in the mesh."""
    axes = tuple(a for a in ("pod",) + tuple(data_axes) if a in mesh.shape)
    axes = axes if len(axes) > 1 else (axes[0] if axes else None)

    def one(leaf):
        dims = [axes] + [None] * (leaf.ndim - 1)
        # tiny batch (long_500k B=1): replicate instead
        if leaf.ndim == 0 or (leaf.shape and leaf.shape[0] == 1):
            dims[0] = None
        return NamedSharding(mesh, P(*dims[:max(leaf.ndim, 1)])
                             if leaf.ndim else P())

    return jax.tree.map(one, batch_shape)


def cache_shardings(spec: FederationSpec, mesh: Mesh, cache_shape, *,
                    batch_size: int, seq_shard: bool = False):
    """Decode caches: shard batch dim over data axes when divisible; for
    B=1 long-context, shard the sequence/state dim over `model`.

    seq_shard=True (beyond-paper §Perf): ALSO shard the cache sequence dim
    over `model` — for MQA/GQA archs whose few KV heads leave the tensor
    axis idle during decode, each device then reads only 1/tp of the cache
    (softmax over the sharded length lowers to small stat all-reduces)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) or 1
    tp = spec.tp_axes[0] if spec.tp_axes else None
    tsize = mesh.shape.get(tp, 1) if tp else 1

    def one(path, leaf):
        p = _path_str(path)
        if leaf.ndim == 0 or p.endswith(("t", "positions")):
            return NamedSharding(mesh, P(*((None,) * leaf.ndim)))
        dims = [None] * leaf.ndim
        # stacked layer axis first, batch second for run caches
        bdim = 1 if p.startswith("runs/") or "enc_kv" in p else 0
        if leaf.ndim > bdim and leaf.shape[bdim] == batch_size \
                and batch_size % dsize == 0 and dsize > 1:
            dims[bdim] = data_axes if len(data_axes) > 1 else data_axes[0]
            if seq_shard and leaf.ndim > bdim + 1 and tp \
                    and leaf.shape[bdim + 1] % tsize == 0 \
                    and leaf.shape[bdim + 1] >= 1024:
                dims[bdim + 1] = tp
        elif leaf.ndim > bdim + 1 and tp and leaf.shape[bdim + 1] % tsize == 0:
            # B too small: shard the next (seq/state) dim over model
            dims[bdim + 1] = tp
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# Logical-activation rules (installed via models.common.logical_rules)
# ---------------------------------------------------------------------------
class LogicalRules:
    """Maps logical activation axis names to mesh axes and applies
    with_sharding_constraint. Works under the client vmap too: jax inserts
    UNCONSTRAINED for the batched (client) dim, so client sharding is free
    to propagate from the batch inputs (verified empirically).

    serve=True maps the batch dim over all data-like axes (global serving
    batch); serve=False maps it over the within-client fsdp axes."""

    def __init__(self, spec: FederationSpec, mesh: Mesh, *,
                 serve: bool = False, seq_shard: bool = False):
        fsdp = spec.fsdp_axes[0] if spec.fsdp_axes else None
        tp = spec.tp_axes[0] if spec.tp_axes else None
        if serve:
            data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            batch = (data_axes if len(data_axes) > 1 else
                     (data_axes[0] if data_axes else None))
        else:
            batch = fsdp
        self.mesh = mesh
        # seq_shard (beyond-paper, Megatron-SP analog): keep the residual
        # stream sharded over the tensor axis along SEQUENCE between blocks
        # so row-parallel matmul epilogues lower to reduce-scatter instead
        # of all-reduce (and norms compute on 1/tp of the tokens).
        ex = tp
        if getattr(spec, "expert_2d", False):
            cand = tuple(a for a in (tp, "data") if a in mesh.shape)
            ex = cand if len(cand) > 1 else ex
        self.map = {"batch": batch, "seq": tp if seq_shard else None,
                    "embed": None, "heads": tp, "kv_heads": None,
                    "ffn": tp, "experts": ex, "vocab": tp}
        if seq_shard:
            # heads/ffn/vocab constraints would conflict with seq on the
            # same axis inside blocks; keep only the residual-stream rule.
            self.map.update(heads=None, ffn=None, experts=tp, vocab=None)

    def constrain(self, x, names):
        dims = [self.map.get(n) if n else None for n in names]
        if len(dims) != x.ndim:
            return x
        try:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, _dedupe(P(*dims))))
        except Exception:
            return x
