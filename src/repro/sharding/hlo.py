"""Post-SPMD HLO inspection for sharding assertions.

After SPMD partitioning, every instruction in ``compiled.as_text()``
carries PER-DEVICE (local) shapes. If the packed flat (C, N) buffer of
the sharded flat engine (core/flat.py + FederationSpec.flat_spec) is
kept sharded end to end, its full global shape can never appear in the
compiled module — any ``f32[C,N]`` hit means some op (an all-gather, a
resharding copy, a rematerialized concatenate) rebuilt the unsharded
buffer on one device. ``flat_buffer_report`` counts those hits, which
is the machine-checkable form of the ROADMAP open item "the packed
(C, N) buffer stays client-sharded end to end".
"""
from __future__ import annotations

import re
from typing import Dict, Sequence


def full_shape_lines(hlo_text: str, shape: Sequence[int],
                     dtype: str = "f32"):
    """HLO lines mentioning the full (global) ``dtype[shape]`` tensor."""
    dims = ",".join(str(int(d)) for d in shape)
    pat = re.compile(rf"\b{re.escape(dtype)}\[{dims}\]")
    return [ln for ln in hlo_text.splitlines() if pat.search(ln)]


def flat_buffer_report(hlo_text: str, C: int, N: int) -> Dict:
    """Count involuntary rematerializations of the packed (C, N) buffer.

    Returns {"full_shape": #lines with the global f32[C,N] shape,
             "gather_or_copy": #those lines that are all-gather/copy ops,
             "sample": first few offending lines}. A sharded round must
    report full_shape == 0 (the replicated engine reports dozens).
    """
    lines = full_shape_lines(hlo_text, (C, N))
    bad = [ln for ln in lines
           if "all-gather" in ln or re.search(r"\bcopy\(", ln)]
    return {"full_shape": len(lines), "gather_or_copy": len(bad),
            "sample": [ln.strip()[:160] for ln in lines[:4]]}


def assert_flat_buffer_sharded(compiled, C: int, N: int) -> Dict:
    """Raise AssertionError if the compiled module ever materializes the
    full (C, N) flat buffer; returns the report otherwise."""
    rep = flat_buffer_report(compiled.as_text(), C, N)
    assert rep["full_shape"] == 0, (
        f"packed ({C}, {N}) flat buffer rematerialized in compiled HLO: "
        f"{rep}")
    return rep
