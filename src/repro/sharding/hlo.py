"""Post-SPMD HLO inspection for sharding assertions.

After SPMD partitioning, every instruction in ``compiled.as_text()``
carries PER-DEVICE (local) shapes. If the packed flat (C, N) buffer of
the sharded flat engine (core/flat.py + FederationSpec.flat_spec) is
kept sharded end to end, its full global shape can never appear in the
compiled module — any ``f32[C,N]`` hit means some op (an all-gather, a
resharding copy, a rematerialized concatenate) rebuilt the unsharded
buffer on one device. ``flat_buffer_report`` counts those hits, which
is the machine-checkable form of the ROADMAP open item "the packed
(C, N) buffer stays client-sharded end to end".
"""
from __future__ import annotations

import re
from typing import Dict, Sequence


def full_shape_lines(hlo_text: str, shape: Sequence[int],
                     dtype: str = "f32"):
    """HLO lines mentioning the full (global) ``dtype[shape]`` tensor."""
    dims = ",".join(str(int(d)) for d in shape)
    pat = re.compile(rf"\b{re.escape(dtype)}\[{dims}\]")
    return [ln for ln in hlo_text.splitlines() if pat.search(ln)]


def flat_buffer_report(hlo_text: str, C: int, N: int) -> Dict:
    """Count involuntary rematerializations of the packed (C, N) buffer.

    Returns {"full_shape": #lines with the global f32[C,N] shape,
             "gather_or_copy": #those lines that are all-gather/copy ops,
             "sample": first few offending lines}. A sharded round must
    report full_shape == 0 (the replicated engine reports dozens).
    """
    lines = full_shape_lines(hlo_text, (C, N))
    bad = [ln for ln in lines
           if "all-gather" in ln or re.search(r"\bcopy\(", ln)]
    return {"full_shape": len(lines), "gather_or_copy": len(bad),
            "sample": [ln.strip()[:160] for ln in lines[:4]]}


def assert_flat_buffer_sharded(compiled, C: int, N: int) -> Dict:
    """Raise AssertionError if the compiled module ever materializes the
    full (C, N) flat buffer; returns the report otherwise."""
    rep = flat_buffer_report(compiled.as_text(), C, N)
    assert rep["full_shape"] == 0, (
        f"packed ({C}, {N}) flat buffer rematerialized in compiled HLO: "
        f"{rep}")
    return rep


# ---------------------------------------------------------------------------
# compressed-round boundary check (repro.compression): no full-precision
# client delta may cross the CLIENT shard boundary (the simulated wire)
# ---------------------------------------------------------------------------
# the opcode of an HLO instruction is the token directly before its "(";
# operand references (%all-reduce.5) are %-prefixed and never match
_COLLECTIVE_OP_RE = re.compile(
    r"(?<![%.\w-])(all-reduce|all-gather|all-to-all|collective-permute|"
    r"reduce-scatter)(?:-start|-done)?\(")
_F32_SHAPE_RE = re.compile(r"\bf32\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"(?:replica_groups|source_target_pairs)="
                        r"\{((?:\{[\d,]*\},?)*)\}")
_GROUP_RE = re.compile(r"\{([\d,]*)\}")


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _parse_groups(line: str):
    """Device groups of a collective line, or None if unparseable (e.g.
    the iota replica-group format) — callers treat None as spanning.
    ``replica_groups={}`` (= one group of ALL devices) also returns
    None: it spans every client shard."""
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    groups = [tuple(int(d) for d in g.group(1).split(",") if d)
              for g in _GROUP_RE.finditer(m.group(1))]
    return groups or None


def _client_coords(mesh, client_axes) -> Dict:
    """device id -> its coordinates along the CLIENT mesh axes."""
    import numpy as np
    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    axis_idx = [mesh.axis_names.index(a) for a in client_axes]
    return {int(ids[idx]): tuple(idx[i] for i in axis_idx)
            for idx in np.ndindex(ids.shape)}


def fullprec_collective_report(hlo_text: str, *, max_elems: int,
                               client_coord_of: Dict = None) -> Dict:
    """Collectives that move >= ``max_elems`` f32 elements ACROSS client
    shards.

    Post-SPMD HLO shapes are per-device, so a collective that ships a
    client-indexed full-precision delta slab over the client axes shows
    up as an all-reduce/all-gather/permute of >= (C_local, N_local) f32
    elements whose replica groups mix devices with different client
    coordinates. Collectives whose groups stay WITHIN one client
    coordinate (``client_coord_of``) are intra-client reshards of the
    flat dim (the pack/unpack seam), not wire traffic, and are exempt;
    unparseable groups are conservatively treated as client-crossing.
    Returns {"collectives": #collective instructions, "fullprec":
    #violations, "sample": first few}.
    """
    lines = [ln for ln in hlo_text.splitlines()
             if _COLLECTIVE_OP_RE.search(ln)]
    bad = []
    for ln in lines:
        if not any(_elems(m.group(1)) >= max_elems
                   for m in _F32_SHAPE_RE.finditer(ln)):
            continue
        if client_coord_of is not None:
            groups = _parse_groups(ln)
            if groups is not None and all(
                    len({client_coord_of.get(d) for d in g}) <= 1
                    for g in groups):
                continue    # stays within one client coordinate
        bad.append(ln)
    return {"collectives": len(lines), "fullprec": len(bad),
            "sample": [ln.strip()[:160] for ln in bad[:4]]}


# ---------------------------------------------------------------------------
# fleet memory ceiling (repro.core.fed_loop.make_fleet_loop): only the
# sampled cohort's client state may be materialized wider than a scalar
# ---------------------------------------------------------------------------
_ANY_SHAPE_RE = re.compile(r"\b(?:f32|bf16|f16|s32|u32|s8|u8|pred)"
                           r"\[([0-9,]+)\]")


def cohort_materialization_report(hlo_text: str, num_registered: int,
                                  *, max_cols: int = 1) -> Dict:
    """Tensors wider than O(C_registered) scalars in the compiled HLO.

    The fleet loop's memory contract is that per-REGISTERED-client state
    stays 1-D: the arena's (C_registered,) scalar rows are the ONLY
    tensors allowed to carry the registered dimension, while everything
    two-dimensional (parameter slabs, gradients, batches) is bounded by
    the COHORT size C << C_registered. Any shape that contains the
    registered dim alongside >= ``max_cols + 1`` other elements (default:
    anything beyond a flat vector) means per-registered-client wide
    state leaked into the program — e.g. a (C_registered, N) gather the
    scheduler or arena scatter accidentally materialized. Returns
    {"vectors": #O(C_registered) 1-D hits, "wide": #violations,
    "sample": first few offending lines}.
    """
    vectors = wide = 0
    sample = []
    for ln in hlo_text.splitlines():
        worst = None
        for m in _ANY_SHAPE_RE.finditer(ln):
            dims = [int(d) for d in m.group(1).split(",") if d]
            if num_registered not in dims:
                continue
            cols = _elems(m.group(1)) // num_registered
            worst = max(worst or 0, cols)
        if worst is None:
            continue
        if worst > max_cols:
            wide += 1
            if len(sample) < 4:
                sample.append(ln.strip()[:160])
        else:
            vectors += 1
    return {"vectors": vectors, "wide": wide, "sample": sample}


def assert_cohort_only_materialization(compiled, num_registered: int, *,
                                       max_cols: int = 1) -> Dict:
    """Raise AssertionError if the compiled fleet program materializes
    any tensor wider than O(C_registered) scalars along the registered-
    client dimension; returns the report otherwise.

    ``max_cols`` relaxes the bound when wider per-registered-client
    state is intentional (e.g. an EF21 arena slab is (C_registered, N)
    by design — pass ``max_cols=N`` there, or skip the check: the
    ceiling being asserted is exactly that NO such slab exists in the
    EF-free configuration).
    """
    rep = cohort_materialization_report(compiled.as_text(),
                                        num_registered, max_cols=max_cols)
    assert rep["wide"] == 0, (
        f"fleet memory ceiling violated: tensor(s) wider than "
        f"({num_registered},)x{max_cols} materialized along the "
        f"registered-client dim: {rep}")
    return rep


def assert_no_fullprec_delta_collective(compiled, C: int, N: int, *,
                                        mesh, federation,
                                        max_payload_elems=None) -> Dict:
    """Assert the compiled compressed sharded round ships no
    full-precision (C, N) client delta across the client shard boundary
    — the machine-checkable form of "compression happens before the
    client-mean psum".

    In a correctly compressed round the largest legitimate f32 payload
    crossing the client axes is the (N/n_shards,) aggregated client
    mean (the compressors are chunk-local and run strictly before that
    psum). A client-crossing collective carrying >=
    (C_local, N/n_shards) f32 elements therefore means an uncompressed
    per-client delta slab went over the simulated wire. Needs
    C_local >= 2 to tell the two apart (raises ValueError otherwise —
    e.g. one-client-per-shard production specs).

    ``max_payload_elems`` optionally TIGHTENS the bound: a robust-
    aggregation round (repro.federation.faults) can declare its largest
    legitimate client-crossing payload — e.g. ``2 * n_loc`` for the
    aggregated mean plus the bucketed robust partial — so the check
    trips on anything bigger even when it is smaller than a full
    (C_local, N_local) slab. The default keeps the PR 4 compression
    bound.
    """
    import numpy as np
    client_axes, _ = federation.flat_axes(mesh)
    c_shards = int(np.prod([mesh.shape[a] for a in client_axes])) or 1
    n_shards = federation.flat_shards(mesh)
    c_loc, n_loc = C // max(1, c_shards), N // max(1, n_shards)
    if c_loc < 2:
        raise ValueError(
            "assert_no_fullprec_delta_collective needs >= 2 clients per "
            f"client shard to separate a delta slab from the aggregated "
            f"mean (C={C}, client shards={c_shards})")
    max_elems = c_loc * n_loc
    if max_payload_elems is not None:
        if max_payload_elems < 1:
            raise ValueError(
                f"max_payload_elems must be >= 1, got {max_payload_elems}")
        max_elems = min(max_elems, int(max_payload_elems) + 1)
    rep = fullprec_collective_report(
        compiled.as_text(), max_elems=max_elems,
        client_coord_of=_client_coords(mesh, client_axes))
    assert rep["fullprec"] == 0, (
        f"full-precision client delta (>= ({c_loc}, {n_loc}) f32) "
        f"crossed the client shard boundary: {rep}")
    return rep
