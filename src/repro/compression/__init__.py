"""Delta-compression subsystem: pluggable client->server compression on
the packed (C, N) flat buffer (see README §Delta compression).

  spec — CompressionSpec (kind / k_frac / error_feedback), the LEVELS
         bandwidth ladder, and analytic wire-byte accounting.
  ops  — compress_flat / compress_flat_sharded: apply a spec to the
         flat delta, per-client bandwidth levels as lane selects,
         chunk-local under shard_map (compress BEFORE the client-mean
         psum).

Fused kernels live in repro.kernels.compress (int8 quantize/dequantize
with per-chunk f32 scales, magnitude top-k threshold pass), with the
pure-jnp oracle in repro.kernels.compress.ref.
"""
from repro.compression.ops import compress_flat, compress_flat_sharded
from repro.compression.spec import (KINDS, LEVELS, CompressionSpec,
                                    get_compression)

__all__ = ["KINDS", "LEVELS", "CompressionSpec", "get_compression",
           "compress_flat", "compress_flat_sharded"]
