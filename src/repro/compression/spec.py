"""CompressionSpec: the pluggable client->server delta-compression config.

The paper motivates Δ-SGD with clients whose data, participation and
computing power vary; at production scale the fourth axis is BANDWIDTH —
full-precision deltas are the dominant wire cost of a round. A
``CompressionSpec`` picks a compressor for the packed (C, N) flat delta
(repro.compression.ops applies it, repro.kernels.compress supplies the
fused kernels):

  kind="none"  — identity. The round engines take their exact
                 pre-compression code path, so results are bit-exact
                 with an uncompressed run.
  kind="int8"  — per-chunk symmetric int8 quantization with f32 scales
                 (chunk = LANES consecutive elements).
  kind="topk"  — magnitude top-k per chunk: keep
                 ``k = max(1, round(k_frac * LANES))`` slots, zero the
                 rest (threshold pass, exactly k kept).

``error_feedback=True`` adds EF21-style error feedback (Richtárik et
al., 2021): each cohort slot carries a reconstruction state g_c
(``FLState.ef``), the client ships only the compressed difference
c_c = C(Δ_c − g_c), and both sides roll g_c ← g_c + c_c — the server
aggregates the g_c, so compression error does not accumulate across
rounds. With kind="none" the difference is exact and EF is a no-op up
to f32 rounding.

The LEVELS ladder ("none" < "int8" < "topk" by wire cost) is shared
with the scenario engine's ``bandwidth`` heterogeneity axis
(repro.federation.scenarios): a bandwidth-heterogeneous scenario draws
a per-client level each round, exactly like K_c on the compute axis,
and the engine selects the matching compressor per client lane.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.flat import LANES

KINDS = ("none", "int8", "topk")
# bandwidth-level ladder: index into KINDS, drawn per client per round
# by bandwidth-heterogeneous scenarios (0 = uncompressed, cheapest wire
# representation last)
LEVELS = KINDS


@dataclass(frozen=True)
class CompressionSpec:
    kind: str = "none"            # none | int8 | topk
    k_frac: float = 0.25          # topk: keep round(k_frac*LANES)/chunk
    error_feedback: bool = False  # EF21 state in FLState.ef

    def __post_init__(self):
        if self.kind not in KINDS:
            raise KeyError(f"unknown compression kind {self.kind!r}; "
                           f"one of {KINDS}")
        if not 0.0 < self.k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {self.k_frac}")

    @property
    def k(self) -> int:
        """topk slots kept per LANES-chunk."""
        return max(1, min(LANES, int(round(self.k_frac * LANES))))

    @property
    def level(self) -> int:
        return KINDS.index(self.kind)

    def active(self, scenario=None) -> bool:
        """Does this spec change the round at all? Inert specs route the
        engines through their exact pre-compression code path
        (bit-exactness guarantee for kind="none")."""
        if self.kind != "none" or self.error_feedback:
            return True
        return scenario is not None and getattr(
            scenario, "bandwidth_heterogeneous", False)

    # ---- wire accounting (the telemetry the reports surface) ------------
    def level_wire_bytes(self, n: int) -> np.ndarray:
        """(len(LEVELS),) f32: client->server payload bytes for an
        n-element delta at each bandwidth level. int8 ships 1 byte per
        element + one f32 scale per chunk; topk ships k (f32 value +
        1-byte lane index) per chunk; none ships raw f32. ``n`` is the
        VALID element count (FlatLayout.size): tail padding exists only
        on device and never crosses the wire, so the accounting is
        identical across per-shard padded layouts."""
        chunks = -(-n // LANES)
        return np.asarray([
            4.0 * n,                          # none: f32
            1.0 * n + 4.0 * chunks,           # int8: values + scales
            (4.0 + 1.0) * self.k * chunks,    # topk: values + lane idx
        ], np.float32)

    def wire_bytes(self, n: int, levels=None, num_clients: int = 1):
        """Per-client wire bytes for one round's deltas.

        ``levels`` is the optional (C,) int32 per-client bandwidth draw
        (None = everyone at this spec's kind). Returns a (C,) f32 jnp
        vector (jit-safe — ``levels`` may be traced)."""
        table = jnp.asarray(self.level_wire_bytes(n))
        if levels is None:
            return jnp.full((num_clients,), table[self.level], jnp.float32)
        return jnp.take(table, levels)


def get_compression(spec_or_kind, **overrides) -> CompressionSpec:
    """Resolve a CompressionSpec from a spec (passed through), a kind
    name, or None (-> inert "none" spec), with field overrides."""
    if spec_or_kind is None:
        spec_or_kind = "none"
    if isinstance(spec_or_kind, CompressionSpec):
        import dataclasses
        return (dataclasses.replace(spec_or_kind, **overrides)
                if overrides else spec_or_kind)
    return CompressionSpec(kind=spec_or_kind, **overrides)
