"""Apply a CompressionSpec to the packed (C, N) flat delta.

``compress_flat`` is the simulate-the-wire primitive: it maps each
client's flat delta row to the value the SERVER would reconstruct after
the client shipped the compressed representation (int8 values + scales,
or top-k value/index pairs). The compressed form itself never needs to
materialize as a host object — quantize/dequantize run back to back on
device, and the wire cost is accounted analytically
(``CompressionSpec.wire_bytes``).

Per-client bandwidth levels: a bandwidth-heterogeneous scenario draws a
(C,) level vector each round (repro.federation.scenarios); each client
lane then gets the compressor of ITS level (0=none, 1=int8, 2=topk) via
a lane select — same pattern as the compute axis's η=0 lane masks, no
extra launches per lane.

``compress_flat_sharded`` is the mesh-native variant: every op is
chunk-local (chunk = LANES elements, and per-shard slabs are whole
row blocks by FlatLayout construction), so the whole compressor runs
inside ``shard_map`` on each device's local slab with ZERO cross-shard
traffic. Compression therefore happens strictly BEFORE the client-mean
psum: the only full-precision tensor that crosses the client shard
boundary afterwards is the (N_shard,) aggregated mean — machine-checked
by ``repro.sharding.hlo.assert_no_fullprec_delta_collective``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compression.spec import CompressionSpec


def _kernels(backend: str, interpret: Optional[bool]):
    """(quant_dequant, topk) callables for the backend. ``pallas`` uses
    the fused kernels (interpret mode off-TPU), ``xla`` the pure-jnp
    oracle — identical math, which is what meshed/pjit callers use."""
    if backend == "pallas":
        from repro.kernels.compress import compress as k
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        ip = interpret

        def qdq(x):
            return k.dequantize_int8(*k.quantize_int8(x, interpret=ip),
                                     interpret=ip)

        return qdq, (lambda x, kk: k.topk_mask(x, kk, interpret=ip))
    from repro.kernels.compress import ref as r
    return (lambda x: r.dequantize_int8_ref(*r.quantize_int8_ref(x)),
            lambda x, kk: r.topk_mask_ref(x, kk))


def compress_flat(delta: jax.Array, spec: CompressionSpec, *,
                  levels: Optional[jax.Array] = None,
                  backend: str = "xla",
                  interpret: Optional[bool] = None) -> jax.Array:
    """(C, N) f32 delta -> (C, N) f32 server-side reconstruction.

    ``levels`` is the optional (C,) int32 per-client bandwidth draw
    (None = every client at ``spec.kind``). Deterministic and
    chunk-local, so sharded and replicated rounds agree exactly.
    """
    qdq, topk = _kernels(backend, interpret)
    if levels is None:
        if spec.kind == "int8":
            return qdq(delta)
        if spec.kind == "topk":
            return topk(delta, spec.k)
        return delta
    # per-client level select: compute each enabled representation once
    # for the whole buffer, then pick per client lane
    out = jnp.where((levels == 1)[:, None], qdq(delta), delta)
    return jnp.where((levels == 2)[:, None], topk(delta, spec.k), out)


def compress_flat_sharded(delta: jax.Array, spec: CompressionSpec, *,
                          mesh, pspec,
                          levels: Optional[jax.Array] = None,
                          backend: str = "xla",
                          interpret: Optional[bool] = None) -> jax.Array:
    """``compress_flat`` on a mesh-sharded (C, N) buffer: the compressor
    runs inside ``shard_map`` on each device's (C_loc, N_loc) slab —
    chunk locality guarantees no collective is emitted, so compression
    completes strictly before the client-mean psum."""
    from jax.sharding import PartitionSpec as PS

    from repro.core.delta_sgd import _shard_map
    ca = pspec[0] if len(pspec) > 0 else None
    na = pspec[1] if len(pspec) > 1 else None
    buf, vec = PS(ca, na), PS(ca)
    with_levels = levels is not None

    def local(d, *rest):
        lv = rest[0] if with_levels else None
        return compress_flat(d, spec, levels=lv, backend=backend,
                             interpret=interpret)

    ins, specs = [delta], [buf]
    if with_levels:
        ins.append(levels)
        specs.append(vec)
    fn = _shard_map(local, mesh, tuple(specs), buf)
    return fn(*ins)
