"""Granite-20B (code) — llama-arch with MQA. [arXiv:2405.04324]

52L, d_model=6144, 48H (MQA kv=1), d_ff=24576, vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu",     # granite-20b-code uses gpt-bigcode style MLP
    block_pattern=("attn",),
    sliding_window=8192,
    citation="arXiv:2405.04324",
)
