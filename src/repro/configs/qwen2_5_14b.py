"""Qwen2.5-14B — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B card family]

48L, d_model=5120, 40H (GQA kv=8), d_ff=13824, vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    block_pattern=("attn",),
    sliding_window=8192,
    citation="hf:Qwen/Qwen2.5-0.5B",
)
