"""Config system: model configs, input shapes, federation configs.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro/configs/`` citing the source paper/model card. The model builder
(`repro.models.model.build_model`) consumes only this dataclass.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block types understood by the model builder. A layer is one block.
#   attn        : self-attention (GQA/MQA/MLA per config) + dense MLP
#   moe         : self-attention + MoE MLP (top-k routed + shared experts)
#   mamba2      : Mamba2 SSD mixer block (norm + mixer; no separate MLP)
#   mlstm       : xLSTM matrix-LSTM block
#   slstm       : xLSTM scalar-LSTM block
#   shared_attn : attention+MLP block whose params are SHARED across all
#                 occurrences (Zamba2-style global shared block)
BLOCK_TYPES = ("attn", "moe", "mamba2", "mlstm", "slstm", "shared_attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("attn",)   # cycled to num_layers
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention options ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # used by long-context decode path
    tie_embeddings: bool = False
    # --- MLA (DeepSeek-V3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (d_ff used if 0)
    router_aux_coef: float = 0.01
    # --- SSM (Mamba2 / xLSTM) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # frames produced by the (stub) frontend
    cross_attention: bool = False
    # --- VLM ---
    num_image_tokens: int = 0        # stub-frontend patch embeddings prepended
    # --- multi-token prediction (DeepSeek-V3) ---
    mtp_depth: int = 0
    # --- activation / norm flavour ---
    mlp_variant: str = "swiglu"      # swiglu | gelu
    norm_variant: str = "rmsnorm"    # rmsnorm | layernorm
    citation: str = ""

    def __post_init__(self):
        for b in self.block_pattern:
            if b not in BLOCK_TYPES:
                raise ValueError(f"unknown block type {b!r}")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived ----
    @property
    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer block types, pattern cycled to num_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables are padded to a multiple of 128 so the
        vocab dim tiles TPU lanes and shards over the model axis (16).
        Logits beyond vocab_size are masked to -inf (whisper's 51865 and
        internvl2's 151655 are otherwise unshardable)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_decoder_only(self) -> bool:
        return not self.cross_attention

    @property
    def supports_long_context(self) -> bool:
        """True if the arch has a sub-quadratic path for 500k decode."""
        has_recurrent = any(t in ("mamba2", "mlstm", "slstm")
                            for t in self.layer_types)
        return has_recurrent or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for roofline
        MODEL_FLOPS and memory napkin math)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        nh = max(2, min(4, self.num_heads))
        kv = max(1, min(nh, self.num_kv_heads if self.num_kv_heads < self.num_heads else nh))
        if self.num_kv_heads == self.num_heads:
            kv = nh
        upd = dict(
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=nh,
            num_kv_heads=kv,
            head_dim=d_model // nh,
            d_ff=2 * d_model,
            vocab_size=vocab,
        )
        if self.num_experts:
            upd.update(num_experts=4, num_experts_per_tok=2,
                       moe_d_ff=d_model, num_shared_experts=min(1, self.num_shared_experts))
        if self.use_mla:
            upd.update(q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                       qk_nope_head_dim=16, v_head_dim=d_model // nh)
        if self.ssm_state:
            upd.update(ssm_state=16, ssm_head_dim=32)
        if self.encoder_layers:
            upd.update(encoder_layers=2, encoder_seq=64)
        if self.num_image_tokens:
            upd.update(num_image_tokens=16)
        if self.mtp_depth:
            upd.update(mtp_depth=1)
        if self.sliding_window:
            upd.update(sliding_window=64)
        return dataclasses.replace(self, **upd)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning round configuration (paper §4 defaults)."""
    num_clients: int = 100           # m
    participation: float = 0.1       # p  -> |S_t| = p*m
    local_steps: int = 2             # K (for the jitted round; paper uses E=1
                                     # epoch ≈ 7 steps at b=64,n=500)
    client_opt: str = "delta_sgd"
    server_opt: str = "fedavg"
    loss: str = "ce"
    fedprox_mu: float = 0.0
    moon_mu: float = 0.0
    moon_tau: float = 0.5
    # Δ-SGD defaults (paper footnotes 2-3: γ=2, η0=0.2, θ0=1, δ=0.1)
    gamma: float = 2.0
    eta0: float = 0.2
    theta0: float = 1.0
    delta: float = 0.1
    # generic client-opt hparams
    lr: float = 0.01
    momentum: float = 0.9
    weighted_agg: bool = False
    # flat-parameter Δ-SGD engine: pack the param pytree + client axis
    # into one (C, N) buffer for the whole local scan (core/fed_round)
    flat_engine: bool = False
    # federation scenario preset name (repro.federation.scenarios): adds
    # participation scheduling, compute heterogeneity, and/or async
    # buffered aggregation to the round. None = the plain sync round.
    scenario: Optional[str] = None
    # client->server delta compression (repro.compression, flat engine):
    # kind over the LEVELS ladder ("none"|"int8"|"topk"), the top-k keep
    # fraction per LANES-chunk, and EF21 error feedback (FLState.ef).
    # "none" without error feedback is inert — bit-exact seed behavior.
    compression: str = "none"
    compression_k_frac: float = 0.25
    error_feedback: bool = False
    # robust server aggregation + quorum degradation (repro.federation
    # .faults, flat engine): overrides applied onto the scenario —
    # "mean"/0 are inert and keep the exact legacy round tail.
    robust_agg: str = "mean"         # mean|clip|trimmed|median
    quorum: int = 0                  # skip round when < Q valid clients
    # fleet scale (repro.federation.arena): C_registered clients known
    # to the server, of which only |S_t| = p·m are sampled per round.
    # None keeps the legacy regime (registered == num_clients); setting
    # it routes training through make_fleet_loop — per-registered-client
    # state lives in the sharded ClientArena and cohort draws run over
    # all C_registered candidates. num_clients then bounds the DATA
    # partitions: registered client i trains on partition i % m
    # (virtual clients), so fleet scale never multiplies dataset memory.
    num_registered_clients: Optional[int] = None
    # device-native telemetry plane (repro.telemetry): in-scan η
    # histogram / loss deciles / guard counts. Read-only over round-end
    # values — the trained trajectory is bit-exact on or off.
    telemetry: bool = False

    @property
    def telemetry_spec(self):
        from repro.telemetry import resolve_telemetry
        return resolve_telemetry(self.telemetry)

    @property
    def compression_spec(self):
        from repro.compression import CompressionSpec
        return CompressionSpec(kind=self.compression,
                               k_frac=self.compression_k_frac,
                               error_feedback=self.error_feedback)

    @property
    def registered_clients(self) -> int:
        """C_registered: the fleet size the schedulers draw over.
        Defaults to ``num_clients`` (legacy regime, every registered
        client has its own data partition)."""
        m = self.num_registered_clients
        if m is not None and m < self.num_clients:
            raise ValueError(f"num_registered_clients={m} must be >= "
                             f"num_clients={self.num_clients}")
        return self.num_clients if m is None else m

    @property
    def fleet(self) -> bool:
        return self.num_registered_clients is not None

    @property
    def clients_per_round(self) -> int:
        # shared helper (repro.federation.schedulers.cohort_size): the
        # data pipeline computes |S_t| with the SAME rounding, so config
        # and sampled batches can never disagree on the cohort shape.
        # Fleet regime: participation applies to the REGISTERED fleet
        # (|S_t| = p·C_registered), same as the cross-device deployments
        # the schedulers model.
        from repro.federation.schedulers import cohort_size
        return cohort_size(self.participation, self.registered_clients)
