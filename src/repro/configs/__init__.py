"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from repro.configs.base import (FLConfig, INPUT_SHAPES, ModelConfig,
                                ShapeConfig)

_ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen2.5-14b": "qwen2_5_14b",
    "whisper-tiny": "whisper_tiny",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-1b": "internvl2_1b",
    "granite-20b": "granite_20b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    try:
        modname = _ARCH_MODULES[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    import importlib
    return importlib.import_module(f"repro.configs.{modname}").CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return INPUT_SHAPES[shape_id]


__all__ = ["ARCH_IDS", "FLConfig", "INPUT_SHAPES", "ModelConfig",
           "ShapeConfig", "get_config", "get_shape"]
