"""DeepSeek-V3-671B — MLA + MoE (1 shared + 256 routed, top-8) + MTP.
[arXiv:2412.19437]

61L, d_model=7168, 128 heads, per-expert d_ff=2048, vocab=129280.
MLA dims follow the paper: q_lora=1536, kv_lora=512, rope head dim 64,
nope head dim 128, v head dim 128. Per the assignment's single d_ff we use
MoE in every layer (the release model keeps 3 dense first layers; DESIGN §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    block_pattern=("moe",),
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    mtp_depth=1,
    sliding_window=8192,
    citation="arXiv:2412.19437",
)
