"""xLSTM-1.3B — sLSTM + mLSTM blocks. [arXiv:2405.04517]

48L, d_model=2048, 4 heads, vocab=50304. d_ff=0 per the assignment: xLSTM
blocks carry their own up/down projections (proj factor 2 for mLSTM, 4/3 for
sLSTM feed-forward). Pattern [m,m,m,s] per 4 layers (DESIGN §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_state=64,          # unused by xLSTM math; marks recurrent family
    citation="arXiv:2405.04517",
)
