"""OLMoE-1B-7B — 64 experts, top-8. [arXiv:2409.02060]

16L, d_model=2048, 16H (kv=16), per-expert d_ff=1024, vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    block_pattern=("moe",),
    num_experts=64,
    num_experts_per_tok=8,
    moe_d_ff=1024,
    sliding_window=8192,
    citation="arXiv:2409.02060",
)
