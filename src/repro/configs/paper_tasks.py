"""Paper-side configs for the faithful Δ-SGD reproduction (Section 4).

The paper trains a shallow CNN (MNIST/FMNIST), ResNet-18/50 (CIFAR), and
DistilBERT (text). Those datasets are unavailable offline, so the repro
protocol runs on synthetic federated tasks (see repro/data/synthetic.py and
DESIGN.md §6) with small models of the same *kinds*: an MLP, a shallow CNN,
and a tiny transformer LM. These are not in the assigned-architecture pool;
they exist to validate the paper's own claims.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MLPConfig:
    name: str
    input_dim: int
    hidden_dims: Tuple[int, ...]
    num_classes: int


@dataclass(frozen=True)
class CNNConfig:
    """Paper's shallow CNN: two conv + two FC layers, dropout + ReLU."""
    name: str
    image_size: int
    channels: int
    conv_channels: Tuple[int, int]
    fc_dim: int
    num_classes: int


MLP_SMALL = MLPConfig("mlp-small", input_dim=32, hidden_dims=(64, 64), num_classes=10)
MLP_WIDE = MLPConfig("mlp-wide", input_dim=32, hidden_dims=(256, 256, 128), num_classes=10)
CNN_PAPER = CNNConfig("cnn-paper", image_size=16, channels=1,
                      conv_channels=(16, 32), fc_dim=128, num_classes=10)
