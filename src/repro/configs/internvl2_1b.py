"""InternVL2-1B — InternViT (stub) + InternLM2/Qwen2-style LM. [arXiv:2404.16821]

24L, d_model=896, 14H (GQA kv=2), d_ff=4864, vocab=151655. The vision
encoder + projector is a STUB per the assignment: ``input_specs()`` provides
256 precomputed patch embeddings of width d_model, prepended to text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    block_pattern=("attn",),
    num_image_tokens=256,
    sliding_window=8192,
    citation="arXiv:2404.16821",
)
