"""Zamba2-7B — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

81 layers, d_model=3584, 32 heads (GQA kv=32 for the shared attention block),
d_ff=14336, vocab=32000, ssm_state=64. We insert one globally *shared*
attention+MLP block after every 6 Mamba2 layers (the HF model alternates two
shared blocks with per-site LoRA; we use one shared block — see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("mamba2",) * 6 + ("shared_attn",),
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    sliding_window=8192,   # shared attention uses a window on the 500k path
    citation="arXiv:2411.15242",
)
