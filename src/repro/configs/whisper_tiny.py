"""Whisper-tiny — encoder-decoder, conv frontend stubbed. [arXiv:2212.04356]

4 encoder + 4 decoder layers, d_model=384, 6H, d_ff=1536, vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 384).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("attn",),
    encoder_layers=4,
    encoder_seq=1500,
    cross_attention=True,
    mlp_variant="gelu",
    norm_variant="layernorm",
    rope_theta=0.0,          # whisper uses learned/sinusoidal abs positions
    sliding_window=8192,
    citation="arXiv:2212.04356",
)
