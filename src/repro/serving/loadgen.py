"""Load generator for the serving plane: synthetic request streams
(Poisson open-loop or closed-loop) over prompt/generation length mixes,
driven through a :class:`~repro.serving.engine.DecodeEngine`, reporting
throughput, latency percentiles, batch occupancy, and swap stall.

Open loop ("poisson"): request i arrives at the cumulative sum of
Exponential(1/rate) gaps, regardless of how the engine keeps up —
latency includes queueing, which is what a p99 under overload should
show. Closed loop ("closed"): a fixed number of in-flight requests,
each replaced on completion — measures the engine's saturated
throughput without unbounded queue growth.

All randomness is seeded (``numpy.random.default_rng``); the request
STREAM is deterministic, only arrival timing depends on the wall clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Workload:
    num_requests: int = 16
    arrival: str = "poisson"           # "poisson" | "closed"
    rate: float = 100.0                # req/s (poisson)
    concurrency: int = 4               # in-flight target (closed)
    prompt_lens: Sequence[int] = (16,)
    gen_lens: Sequence[int] = (8,)
    personalized_frac: float = 0.0     # fraction routed to a client id
    client_ids: Sequence[int] = (0,)
    seed: int = 0


def make_requests(workload: Workload, vocab: int
                  ) -> List[Tuple[np.ndarray, int, Optional[int], float]]:
    """The deterministic request stream: a list of
    (prompt, gen_len, client_id, arrival_time_s) tuples."""
    rng = np.random.default_rng(workload.seed)
    gaps = (rng.exponential(1.0 / workload.rate, workload.num_requests)
            if workload.arrival == "poisson"
            else np.zeros(workload.num_requests))
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(workload.num_requests):
        plen = int(rng.choice(np.asarray(workload.prompt_lens)))
        gen = int(rng.choice(np.asarray(workload.gen_lens)))
        cid = None
        if (workload.personalized_frac > 0.0
                and rng.random() < workload.personalized_frac):
            cid = int(rng.choice(np.asarray(workload.client_ids)))
        prompt = rng.integers(0, vocab, size=(plen,)).astype(np.int32)
        out.append((prompt, gen, cid, float(arrivals[i])))
    return out


def run_load(engine, workload: Workload, vocab: int) -> dict:
    """Drive ``workload`` through ``engine``; returns the report dict
    (tok_per_s, p50/p99 latency, occupancy, swap counters)."""
    reqs = make_requests(workload, vocab)
    done: list = []
    t0 = time.time()
    if workload.arrival == "closed":
        pending = list(reqs)
        for _ in range(min(workload.concurrency, len(pending))):
            prompt, gen, cid, _at = pending.pop(0)
            engine.submit(prompt, gen, client_id=cid)
        while engine.has_work() or pending:
            done.extend(engine.step())
            while pending and engine.queue == [] and \
                    sum(s is None for s in engine._slots) > 0:
                # keep `concurrency` in flight: refill freed capacity
                in_flight = (len(engine.queue)
                             + sum(s is not None for s in engine._slots))
                if in_flight >= workload.concurrency:
                    break
                prompt, gen, cid, _at = pending.pop(0)
                engine.submit(prompt, gen, client_id=cid)
    else:
        i = 0
        while i < len(reqs) or engine.has_work():
            now = time.time() - t0
            while i < len(reqs) and reqs[i][3] <= now:
                prompt, gen, cid, _at = reqs[i]
                engine.submit(prompt, gen, client_id=cid)
                i += 1
            if engine.has_work():
                done.extend(engine.step())
            elif i < len(reqs):
                time.sleep(min(0.001, max(0.0, reqs[i][3] - now)))
    wall = max(time.time() - t0, 1e-9)
    lat = np.asarray([c.latency_s for c in done], np.float64)
    m = engine.metrics()
    report = {"requests": len(done),
              "tok_per_s": m["serve_tokens_total"] / wall,
              "p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
              "p99_s": float(np.percentile(lat, 99)) if lat.size else 0.0,
              "occupancy": m["serve_occupancy_mean"],
              "swaps": m["serve_swaps_total"],
              "swap_stall_mean_s": m["serve_swap_stall_mean"],
              "swap_stall_max_s": m["serve_swap_stall_max"],
              "wall_s": wall}
    if engine.events is not None:
        engine.events.emit("serve_load", t=0,
                           serve_tok_per_s=report["tok_per_s"],
                           serve_latency_p50_s=report["p50_s"],
                           serve_latency_p99_s=report["p99_s"])
        engine.events.flush()
    return report
