"""Federated serving plane: round-versioned continuous-batching decode.

Pieces (see each module's docstring for the contract):

  * :class:`ModelRegistry` — watches a training checkpoint dir and
    stages new rounds for hot-swap.
  * :class:`DecodeEngine` — fixed-slot KV pool, fused flush-interval
    decode blocks, block-boundary swap, personalized overlays.
  * :class:`PersonalizationStore` — per-client flat deltas (e.g. the
    fleet arena's EF21 slab) applied as a params overlay.
  * :class:`Workload` / :func:`run_load` — load generator + report.
"""
from repro.serving.engine import (Completion, DecodeEngine, Request,
                                  greedy_decode)
from repro.serving.loadgen import Workload, make_requests, run_load
from repro.serving.personalize import PersonalizationStore
from repro.serving.registry import ModelRegistry, StagedVersion

__all__ = ["Completion", "DecodeEngine", "Request", "greedy_decode",
           "ModelRegistry", "StagedVersion", "PersonalizationStore",
           "Workload", "make_requests", "run_load"]
