"""Round-versioned model registry: watch a training checkpoint dir and
stage new params for the decode engine to hot-swap.

The training driver saves FLState checkpoints keyed on the ROUND
counter (``launch/train._maybe_ckpt`` / ``_run_fused``); the registry
polls ``repro.checkpoint.latest_step`` and, whenever a round newer than
the one currently serving appears, loads its params subtree through
``restore_params`` (the ``params/`` manifest-prefix mapping, so
training checkpoints serve directly) into a :class:`StagedVersion`.

The registry only STAGES; the engine APPLIES. ``DecodeEngine.step``
polls once per flush interval and swaps at the block boundary — params
are never replaced while a decode block is in flight, which is what
makes the swap atomic from a request's point of view (no token is ever
produced from mixed-version params). ``StagedVersion.seen_at`` is
stamped when the poll first notices the new checkpoint on disk; the
engine's ``serve_swap_stall_s`` metric is the time from then until the
staged params actually serve traffic (restore + wait-to-boundary).
"""
from __future__ import annotations

import os
import time
from typing import Any, NamedTuple, Optional

from repro.checkpoint import latest_step, restore_params


class StagedVersion(NamedTuple):
    params: Any          # restored params pytree (serving template shapes)
    step: int            # training round the checkpoint was keyed on
    seen_at: float       # wall time the poll first saw the checkpoint


class ModelRegistry:
    """Poll-based checkpoint watcher; see module docstring.

    ``template`` fixes the serving param shapes: every restore is
    verified leaf-by-leaf against it (``restore_params`` raises on any
    shape mismatch), so a staged version can always hot-swap into an
    engine built from the same template.
    """

    def __init__(self, ckpt_dir: str, template: Any):
        self.ckpt_dir = ckpt_dir
        self.template = template
        self.version: Optional[int] = None   # last step handed out
        self.loads = 0

    def poll(self) -> Optional[StagedVersion]:
        """Stage the newest checkpoint round if it is newer than the
        last one handed out; None when already current (or the dir is
        still empty). Load errors from a half-written checkpoint cannot
        occur: ``save`` publishes via atomic tmp-dir rename."""
        step = latest_step(self.ckpt_dir)
        if step is None or (self.version is not None
                            and step <= self.version):
            return None
        seen_at = time.time()
        params, step = restore_params(self.ckpt_dir, self.template,
                                      step=step)
        self.version = step
        self.loads += 1
        return StagedVersion(params=params, step=step, seen_at=seen_at)

    def step_dir(self, step: int) -> str:
        return os.path.join(self.ckpt_dir, f"step_{step:08d}")
