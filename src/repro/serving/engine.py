"""Continuous-batching decode engine: a fixed-slot KV-cache pool with
per-slot sequence state, flush-interval decode blocks, and block-
boundary checkpoint hot-swap.

Design (mirrors the training engine's host-sync discipline):

  * POOL — one vectorized decode cache for S slots built from
    ``model.init_cache``: every ``runs`` leaf keeps its batch axis
    (axis 1), ``t`` becomes (S,) and ``positions`` (S, W). Slot s is
    row s of every leaf; ``model.decode_step`` branches on ``t``'s rank
    and runs each row at its OWN position / ring slot
    (``attention._cache_write``), so admitting or retiring one sequence
    never touches another row's state.
  * DECODE BLOCK — ``flush_tokens`` greedy steps fused into ONE jitted
    ``lax.scan`` (no per-token host sync; the per-token Python loop in
    the old ``launch/serve.py`` paid one dispatch + implicit sync per
    token). Inactive slots are masked OUT of the carry by
    ``_merge_cache`` — their cache rows, t, and last token are
    bit-frozen while the active rows advance. The host reads ONE
    device_get per flush (the stacked (S, flush_tokens) token matrix),
    exactly the ``_RoundLog`` cadence of the training loop.
  * ADMIT / EVICT — at flush boundaries only. Admission prefuills the
    request alone (B=1, jitted per prompt length) and scatters the
    resulting cache rows into the pool; eviction just frees the host-
    side slot record (the pool row is garbage until the next admit
    overwrites it).
  * HOT SWAP — ``step()`` polls the :class:`~repro.serving.registry.
    ModelRegistry` once per flush and applies a staged version BEFORE
    the next decode block: every token of every flush is produced by
    exactly one params version (atomicity is asserted in
    tests/test_serving.py by replaying the per-flush version schedule).
    The KV pool is REUSED across the swap — valid because the cache
    stores activations keyed only by model config, and the swap is
    shape-gated: params that do not match the serving template
    leaf-for-leaf are refused (build a new engine for a new
    architecture).
  * PERSONALIZATION — a request with a client id known to the
    :class:`~repro.serving.personalize.PersonalizationStore` decodes
    under ``unpack(pack(params) + scale·delta_c)``. Active slots are
    grouped by overlay identity each flush; every group reuses the ONE
    compiled decode block (params are traced arguments), so per-client
    models cost one axpy + unpack, cached until the next swap.

Caveat: MoE blocks route with batch-global expert capacity, so a
sequence's tokens can be capacity-dropped differently depending on its
pool neighbours — continuous batching is exact (vs isolated decode) for
dense/SSM stacks, best-effort for MoE.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# fused greedy decode (lockstep): serve.py's jitted tail
# ---------------------------------------------------------------------------
def greedy_decode(model, params, cache, tok, n, *, window=None):
    """n greedy decode steps as ONE ``lax.scan`` — the fused form of the
    legacy per-token host loop, token-exact against it (same per-step
    ops, one dispatch total). Works on both cache forms (lockstep and
    per-slot pool). Returns (tokens (B, n) int32, cache, last token)."""
    def body(carry, _):
        cache, tok = carry
        logits, cache = model.decode_step(params, cache, tok,
                                          window=window)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (cache, tok), tok[:, 0]

    (cache, tok), toks = jax.lax.scan(body, (cache, tok), None, length=n)
    return jnp.moveaxis(toks, 0, 1), cache, tok


# ---------------------------------------------------------------------------
# masked decode block (per-slot): the engine's flush interval
# ---------------------------------------------------------------------------
def _bcast(mask, ndim, axis):
    shape = [1] * ndim
    shape[axis] = mask.shape[0]
    return mask.reshape(shape)


def _merge_cache(active, new, old):
    """Keep ``new`` state only on active rows; inactive rows stay
    bit-identical to ``old`` (runs leaves carry batch on axis 1; t /
    positions on axis 0; enc_kv is per-slot static, passed through)."""
    out = {"runs": jax.tree.map(
        lambda n_, o: jnp.where(_bcast(active, n_.ndim, 1), n_, o),
        new["runs"], old["runs"]),
        "t": jnp.where(active, new["t"], old["t"]),
        "positions": jnp.where(active[:, None], new["positions"],
                               old["positions"])}
    if "enc_kv" in new:
        out["enc_kv"] = new["enc_kv"]
    return out


def _decode_block(model, params, cache, tok, active, n, window):
    """n masked greedy steps; returns (cache, tok, tokens (S, n))."""
    def body(carry, _):
        cache, tok = carry
        logits, new_cache = model.decode_step(params, cache, tok,
                                              window=window)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(active[:, None], nxt, tok)
        return (_merge_cache(active, new_cache, cache), nxt), nxt[:, 0]

    (cache, tok), toks = jax.lax.scan(body, (cache, tok), None, length=n)
    return cache, tok, jnp.moveaxis(toks, 0, 1)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------
@dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int
    client_id: Optional[int] = None
    request_id: int = 0
    extras: Optional[Dict[str, np.ndarray]] = None  # frames/image_embeds
    submit_time: float = field(default_factory=time.time)


class Completion(NamedTuple):
    request_id: int
    tokens: np.ndarray                 # (max_new_tokens,) int32
    client_id: Optional[int]
    latency_s: float
    versions: tuple                    # params version per flush touched


class _Slot(NamedTuple):
    req: Request
    remaining: int
    out: List[int]
    overlay: Optional[int]             # personalization key (client id)
    versions: List[int]


class DecodeEngine:
    """Fixed-slot continuous-batching greedy decode; see module doc."""

    def __init__(self, model, params, *, slots: int = 4,
                 cache_len: int = 64, flush_tokens: int = 8,
                 window: Optional[int] = None, version: int = 0,
                 registry=None, personalization=None, events=None):
        self.model, self.slots = model, int(slots)
        self.cache_len, self.flush_tokens = int(cache_len), int(flush_tokens)
        self.window = window
        self.registry = registry
        self.store = personalization
        self.events = events
        self._params = params
        self._shapes = jax.tree.map(
            lambda a: (jnp.shape(a), str(jnp.result_type(a))), params)
        self._params_flat = None       # packed lazily (personalization)
        self._overlays: Dict[int, Any] = {}
        self.version = int(version)
        self._ids = itertools.count()
        self.queue: List[Request] = []
        self._slots: List[Optional[_Slot]] = [None] * self.slots
        self.pool = self._init_pool()
        self._tok = jnp.zeros((self.slots, 1), jnp.int32)
        self._block = jax.jit(
            lambda p, c, tok, act: _decode_block(
                self.model, p, c, tok, act, self.flush_tokens,
                self.window))
        self._insert = jax.jit(self._insert_impl)
        self._prefills: Dict[Any, Any] = {}
        self.history: List[dict] = []
        self.completed: List[Completion] = []
        self.stats = {"tokens": 0, "flushes": 0, "occupancy_sum": 0.0,
                      "swaps": 0, "swap_stalls": [], "kv_reuse_swaps": 0,
                      "admitted": 0, "completed": 0}
        if self.registry is not None:
            staged = self.registry.poll()   # initial version, if any
            if staged is not None:
                self._params = staged.params
                self._params_flat = None
                self.version = staged.step

    # --------------------------------------------------------------- pool
    def _init_pool(self):
        cache = self.model.init_cache(self.slots, self.cache_len,
                                      window=self.window)
        cache["t"] = jnp.zeros((self.slots,), jnp.int32)
        cache["positions"] = jnp.full((self.slots, self.cache_len), -1,
                                      jnp.int32)
        return cache

    def _insert_impl(self, pool, tok_pool, c1, tok0, s):
        """Scatter a B=1 prefill cache into pool row s (one jit; ``s``
        is a traced scalar, so every admission reuses the compile)."""
        row = jax.tree.map(lambda pl, cl: pl.at[:, s].set(cl[:, 0]),
                           pool["runs"], c1["runs"])
        new = dict(pool)
        new["runs"] = row
        new["t"] = pool["t"].at[s].set(c1["t"])
        new["positions"] = pool["positions"].at[s].set(c1["positions"])
        if "enc_kv" in pool:
            new["enc_kv"] = jax.tree.map(
                lambda pl, cl: pl.at[:, s].set(cl[:, 0]),
                pool["enc_kv"], c1["enc_kv"])
        return new, tok_pool.at[s].set(tok0[0])

    # ------------------------------------------------------------ params
    def _client_params(self, overlay_key):
        if overlay_key is None:
            return self._params
        if overlay_key not in self._overlays:
            if self._params_flat is None:
                from repro.core.flat import pack
                self._params_flat = pack(self._params, self.store.layout)
            self._overlays[overlay_key] = self.store.overlay(
                self._params_flat, overlay_key)
        return self._overlays[overlay_key]

    def swap(self, params, step: int, *, seen_at: Optional[float] = None):
        """Hot-swap the serving params at this block boundary. Shape-
        gated: the new tree must match the serving template leaf-for-
        leaf (shape AND dtype) — that is the condition under which the
        in-flight KV pool remains valid and is reused."""
        shapes = jax.tree.map(
            lambda a: (jnp.shape(a), str(jnp.result_type(a))), params)
        if shapes != self._shapes:
            raise ValueError(
                "hot-swap refused: new params do not match the serving "
                "template's shapes/dtypes — the KV pool cannot be "
                "reused across an architecture change; build a new "
                "DecodeEngine")
        self._params = params
        self._params_flat = None
        self._overlays.clear()
        self.version = int(step)
        self.stats["swaps"] += 1
        if any(s is not None for s in self._slots):
            self.stats["kv_reuse_swaps"] += 1
        stall = (time.time() - seen_at) if seen_at is not None else 0.0
        self.stats["swap_stalls"].append(stall)
        return stall

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new_tokens: int, *, client_id=None,
               extras=None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be (S,), got {prompt.shape}")
        need = (prompt.shape[0] + max_new_tokens
                + (self.model.cfg.num_image_tokens or 0))
        if self.window is None and need > self.cache_len:
            raise ValueError(
                f"request needs {need} cache entries > pool cache_len "
                f"{self.cache_len} (pass a sliding window to roll)")
        rid = next(self._ids)
        self.queue.append(Request(prompt=prompt,
                                  max_new_tokens=int(max_new_tokens),
                                  client_id=client_id, request_id=rid,
                                  extras=extras))
        return rid

    # ------------------------------------------------------------- admit
    def _admit(self, completions):
        for s in range(self.slots):
            if not self.queue:
                break
            if self._slots[s] is not None:
                continue
            req = self.queue.pop(0)
            overlay = (req.client_id
                       if (self.store is not None
                           and self.store.has(req.client_id)) else None)
            sig = (req.prompt.shape[0],
                   tuple(sorted((req.extras or {}).keys())))
            fn = self._prefills.get(sig)
            if fn is None:
                fn = jax.jit(lambda p, b: self.model.prefill(
                    p, b, cache_len=self.cache_len, window=self.window))
                self._prefills[sig] = fn
            batch = {"tokens": jnp.asarray(req.prompt[None])}
            for k, v in (req.extras or {}).items():
                batch[k] = jnp.asarray(v)[None]
            logits, c1 = fn(self._client_params(overlay), batch)
            tok0 = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            if "enc_kv" in c1 and "enc_kv" not in self.pool:
                self.pool["enc_kv"] = jax.tree.map(
                    lambda e: jnp.zeros(
                        (e.shape[0], self.slots) + e.shape[2:], e.dtype),
                    c1["enc_kv"])
            self.pool, self._tok = self._insert(self.pool, self._tok, c1,
                                                tok0, jnp.int32(s))
            first = int(tok0[0, 0])
            slot = _Slot(req=req, remaining=req.max_new_tokens - 1,
                         out=[first], overlay=overlay,
                         versions=[self.version])
            self.stats["admitted"] += 1
            if slot.remaining == 0:
                completions.append(self._finish_slot(slot))
            else:
                self._slots[s] = slot

    def _finish_slot(self, slot: _Slot) -> Completion:
        self.stats["completed"] += 1
        c = Completion(request_id=slot.req.request_id,
                       tokens=np.asarray(slot.out, np.int32),
                       client_id=slot.req.client_id,
                       latency_s=time.time() - slot.req.submit_time,
                       versions=tuple(dict.fromkeys(slot.versions)))
        self.completed.append(c)
        return c

    # -------------------------------------------------------------- step
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self._slots)

    def step(self) -> List[Completion]:
        """One flush interval: swap (if staged) -> admit -> one fused
        decode block per overlay group -> ONE device_get -> harvest.
        Returns the requests completed this flush."""
        completions: List[Completion] = []
        swapped, stall = 0, 0.0
        if self.registry is not None:
            staged = self.registry.poll()
            if staged is not None:
                stall = self.swap(staged.params, staged.step,
                                  seen_at=staged.seen_at)
                swapped = 1
        self._admit(completions)
        groups: Dict[Optional[int], List[int]] = {}
        for s, sl in enumerate(self._slots):
            if sl is not None:
                groups.setdefault(sl.overlay, []).append(s)
        order = list(groups.items())
        mats = []
        for key, idxs in order:
            act = np.zeros((self.slots,), bool)
            act[idxs] = True
            self.pool, self._tok, toks = self._block(
                self._client_params(key), self.pool, self._tok,
                jnp.asarray(act))
            mats.append(toks)
        mats = jax.device_get(mats)    # the ONE host sync of the flush
        emitted = 0
        for (key, idxs), mat in zip(order, mats):
            for s in idxs:
                sl = self._slots[s]
                take = min(sl.remaining, self.flush_tokens)
                sl.out.extend(int(x) for x in mat[s, :take])
                sl.versions.append(self.version)
                emitted += take
                sl = sl._replace(remaining=sl.remaining - take)
                self._slots[s] = sl
                if sl.remaining == 0:
                    self._slots[s] = None
                    completions.append(self._finish_slot(sl))
        occ = sum(len(v) for v in groups.values()) / self.slots
        self.stats["tokens"] += emitted
        self.stats["flushes"] += 1
        self.stats["occupancy_sum"] += occ
        self.history.append({"flush": self.stats["flushes"] - 1,
                             "version": self.version,
                             "groups": {k: list(v)
                                        for k, v in groups.items()},
                             "swapped": swapped, "swap_stall_s": stall,
                             "tokens": emitted, "occupancy": occ})
        if self.events is not None:
            self.events.emit("serve_flush",
                             t=self.stats["flushes"] - 1,
                             serve_tokens=emitted, serve_occupancy=occ,
                             serve_version=self.version,
                             serve_swapped=swapped,
                             serve_swap_stall_s=stall)
            self.events.flush()
        return completions

    def run_until_idle(self, max_flushes: int = 100_000
                       ) -> List[Completion]:
        out: List[Completion] = []
        while self.has_work():
            out.extend(self.step())
            if self.stats["flushes"] >= max_flushes:
                raise RuntimeError("run_until_idle: flush budget "
                                   "exhausted with work pending")
        return out

    # ------------------------------------------------------------ report
    def metrics(self) -> dict:
        f = max(1, self.stats["flushes"])
        stalls = self.stats["swap_stalls"]
        return {"serve_tokens_total": self.stats["tokens"],
                "serve_occupancy_mean": self.stats["occupancy_sum"] / f,
                "serve_swaps_total": self.stats["swaps"],
                "serve_swap_stall_mean": (float(np.mean(stalls))
                                          if stalls else 0.0),
                "serve_swap_stall_max": (float(np.max(stalls))
                                         if stalls else 0.0),
                "kv_reuse_swaps": self.stats["kv_reuse_swaps"],
                "requests_completed": self.stats["completed"]}
