"""Personalized decode: serve a registered client's locally adapted
delta as a low-cost overlay on the global params.

À la *Locally Adaptive Federated Learning* (PAPERS.md): a client that
participated in training carries local state the server already holds —
in this repo, its row of the PR-7 client-state arena
(``repro.federation.arena.ClientArena``), whose EF21 slab is exactly a
per-client flat ``(N,)`` correction in the training layout. The overlay
is one axpy on the packed buffer plus an unpack:

    params_c = unpack(pack(params) + scale * delta_c, layout)

so a personalized request costs O(N) — no per-client model copies live
longer than the request group that needs them, and the decode engine
reuses one compiled decode block for every overlay (params are traced
arguments).

``PersonalizationStore`` keys flat deltas by client id. Deltas come
from ``ClientArena.ef`` rows (:meth:`from_arena`) or are set directly
(:meth:`set_delta` accepts a params-shaped pytree or an already-flat
vector). The engine gathers the overlay per request at admission and
groups active slots by overlay identity per flush.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.flat import layout_of, pack, unpack


class PersonalizationStore:
    """Flat per-client param deltas over a serving template layout."""

    def __init__(self, template_params: Any, *, scale: float = 1.0):
        self.layout = layout_of(template_params)
        self.scale = float(scale)
        self._deltas: Dict[int, jnp.ndarray] = {}

    # ------------------------------------------------------------- build
    @classmethod
    def from_arena(cls, arena, template_params: Any, *,
                   client_ids: Optional[Iterable[int]] = None,
                   scale: float = 1.0) -> "PersonalizationStore":
        """Deltas from the fleet arena's EF21 slab: row i is registered
        client i's flat correction in the training layout (which must
        be the serving layout — same template tree). Clients without an
        ``ef`` row (arena built without error feedback) cannot be
        personalized this way."""
        store = cls(template_params, scale=scale)
        if arena.ef is None:
            raise ValueError("arena has no EF21 slab (ef=None): train "
                             "with --error-feedback to accumulate "
                             "per-client deltas, or set_delta directly")
        ef = np.asarray(arena.ef)
        if ef.shape[1] != store.layout.padded_size:
            raise ValueError(
                f"arena EF width {ef.shape[1]} != serving layout "
                f"padded_size {store.layout.padded_size}: the arena was "
                f"trained on a different model than this template")
        ids = (range(ef.shape[0]) if client_ids is None else client_ids)
        for cid in ids:
            store._deltas[int(cid)] = jnp.asarray(ef[int(cid)],
                                                  jnp.float32)
        return store

    def set_delta(self, client_id: int, delta: Any) -> None:
        """delta: params-shaped pytree or flat (padded_size,) vector."""
        if hasattr(delta, "ndim") and delta.ndim == 1:
            flat = jnp.asarray(delta, jnp.float32)
            if flat.shape[0] != self.layout.padded_size:
                raise ValueError(f"flat delta width {flat.shape[0]} != "
                                 f"layout {self.layout.padded_size}")
        else:
            flat = pack(delta, self.layout)
        self._deltas[int(client_id)] = flat

    # ------------------------------------------------------------- query
    def has(self, client_id) -> bool:
        return client_id is not None and int(client_id) in self._deltas

    def client_ids(self):
        return sorted(self._deltas)

    def overlay(self, params_flat: jnp.ndarray, client_id: int) -> Any:
        """Global flat params + this client's scaled delta -> pytree."""
        delta = self._deltas[int(client_id)]
        return unpack(params_flat + self.scale * delta, self.layout)
