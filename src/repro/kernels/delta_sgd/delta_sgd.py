"""Pallas TPU kernels for Δ-SGD's per-step param work.

The paper's step size needs two global reductions per local step
(‖g_k − g_{k−1}‖², ‖g_k‖² — the ‖Δx‖ term reuses the previous ‖g‖ since
Δx = −η·g for SGD updates). The reductions must complete before η is known,
so the update itself is a second pass.

Flat packed layout (the fast path — see ``repro.core.flat``): the whole
param pytree is ONE lane-aligned f32 buffer and the client axis is the
leading dim of a dense ``(C, N)`` buffer, ``N = M·128`` with ``M`` an
exact multiple of the row-block. The kernel pair runs a 2-D grid over
(client, row-block):

  batched_norms  — ONE HBM pass over (G, G_prev) producing BOTH partial
                   sums per block, accumulated across the sequential
                   row-block grid axis into per-client (C, 1, 1) outputs.
                   No vmap, no per-leaf loop: the client axis is a grid
                   dimension, so the kernel is vmap-free by construction.
  batched_apply  — P ← P − η_c·G with per-client η, tiled through VMEM;
                   P is aliased to the output so the update is in-place.
                   An optional per-element round mask reproduces the
                   reference path's per-step bf16 rounding for sub-f32
                   leaves packed into the f32 buffer.

Launch-count math, per local step over a ``num_leaves``-leaf tree and
``C`` clients: the per-leaf path costs ``num_leaves × C × 2`` pallas
launches (norms + apply per leaf per client, under vmap) plus a
``_pad_2d`` concatenate copy per call; the packed path costs exactly
**2** launches — one ``batched_norms``, one ``batched_apply`` — for any
leaf count and any client count, with zero per-call padding (the layout
pre-pads once at pack time). Both paths read {G, G_prev} once and
read {P, G}/write {P} once, i.e. the HBM-bandwidth floor for the rule;
the packed path is the one that reaches it at small-leaf granularity.

The single-tensor ``norms`` / ``apply_update`` kernels below are the
legacy per-leaf path, kept as the benchmark baseline and for callers
that operate on individual tensors.
"""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# single source of truth for the tile geometry: the packer pads layouts
# to exactly these block sizes, so both modules must agree
from repro.core.flat import BLOCK_ROWS, LANES

# trace-time launch accounting: incremented once per pallas_call *built*,
# i.e. launches per traced step (what the compiled program will execute).
LAUNCHES: Counter = Counter()


def reset_launch_count() -> None:
    LAUNCHES.clear()


def launch_count() -> int:
    return sum(LAUNCHES.values())


# --------------------------------------------------------------------------
# packed (C, N) kernels — one launch per op for all leaves and all clients
# --------------------------------------------------------------------------

def _batched_norms_kernel(g_ref, gp_ref, dg_ref, gg_ref):
    j = pl.program_id(1)  # row-block axis: sequential, innermost
    g = g_ref[...].astype(jnp.float32)
    gp = gp_ref[...].astype(jnp.float32)
    d = g - gp

    @pl.when(j == 0)
    def _init():
        dg_ref[0, 0, 0] = 0.0
        gg_ref[0, 0, 0] = 0.0

    dg_ref[0, 0, 0] += jnp.sum(d * d)
    gg_ref[0, 0, 0] += jnp.sum(g * g)


def _batched_apply_kernel(eta_ref, p_ref, g_ref, out_ref):
    eta = eta_ref[0, 0, 0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p - eta * g).astype(out_ref.dtype)


def _batched_apply_masked_kernel(eta_ref, p_ref, g_ref, mask_ref, out_ref):
    eta = eta_ref[0, 0, 0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    r = p - eta * g
    # mask=1 elements belong to bf16 leaves: round exactly like the
    # per-leaf reference's astype(bf16) so flat K-step scans stay on par
    rounded = r.astype(jnp.bfloat16).astype(jnp.float32)
    out_ref[...] = jnp.where(mask_ref[...] > 0.0, rounded, r)


def _grid_shapes(n: int):
    """(M, rows, blocks) for a lane-aligned flat length n (no re-padding:
    FlatLayout guarantees M % rows == 0)."""
    assert n % LANES == 0, f"flat length {n} not lane-aligned"
    m = n // LANES
    rows = min(BLOCK_ROWS, m)
    assert m % rows == 0, f"flat length {n} not row-block aligned"
    return m, rows, m // rows


def batched_norms(g: jax.Array, g_prev: jax.Array, *,
                  interpret: bool = False):
    """Per-client (sum((g-gp)^2), sum(g^2)) over packed (C, N) buffers.

    ONE pallas launch for all clients and all (packed) leaves; returns a
    pair of (C,) f32 vectors.
    """
    C, n = g.shape
    m, rows, blocks = _grid_shapes(n)
    g3 = g.reshape(C, m, LANES)
    gp3 = g_prev.reshape(C, m, LANES)
    LAUNCHES["batched_norms"] += 1
    dg, gg = pl.pallas_call(
        _batched_norms_kernel,
        grid=(C, blocks),
        in_specs=[pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
                  pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0))],
        out_specs=[pl.BlockSpec((1, 1, 1), lambda c, j: (c, 0, 0)),
                   pl.BlockSpec((1, 1, 1), lambda c, j: (c, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((C, 1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((C, 1, 1), jnp.float32)],
        interpret=interpret,
    )(g3, gp3)
    return dg[:, 0, 0], gg[:, 0, 0]


def batched_apply(p: jax.Array, g: jax.Array, eta: jax.Array, *,
                  mask: jax.Array | None = None,
                  interpret: bool = False) -> jax.Array:
    """P ← P − η_c·G on packed (C, N) buffers with per-client η (C,).

    ONE pallas launch; P is donated to the output (in-place on TPU).
    ``mask`` is the optional (N,) round mask from FlatLayout.round_mask.
    """
    C, n = p.shape
    m, rows, blocks = _grid_shapes(n)
    p3 = p.reshape(C, m, LANES)
    g3 = g.reshape(C, m, LANES)
    eta3 = eta.astype(jnp.float32).reshape(C, 1, 1)
    LAUNCHES["batched_apply"] += 1
    common = dict(
        grid=(C, blocks),
        out_specs=pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
        out_shape=jax.ShapeDtypeStruct((C, m, LANES), p.dtype),
        interpret=interpret,
    )
    eta_spec = pl.BlockSpec((1, 1, 1), lambda c, j: (c, 0, 0))
    buf_spec = pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0))
    if mask is None:
        out = pl.pallas_call(
            _batched_apply_kernel,
            in_specs=[eta_spec, buf_spec, buf_spec],
            input_output_aliases={1: 0},
            **common,
        )(eta3, p3, g3)
    else:
        mask2 = mask.reshape(m, LANES)
        mask_spec = pl.BlockSpec((rows, LANES), lambda c, j: (j, 0))
        out = pl.pallas_call(
            _batched_apply_masked_kernel,
            in_specs=[eta_spec, buf_spec, buf_spec, mask_spec],
            input_output_aliases={1: 0},
            **common,
        )(eta3, p3, g3, mask2)
    return out.reshape(C, n)


# --------------------------------------------------------------------------
# legacy per-leaf kernels (benchmark baseline / single-tensor callers)
# --------------------------------------------------------------------------

def _norms_kernel(g_ref, gp_ref, dg_ref, gg_ref):
    i = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)
    gp = gp_ref[...].astype(jnp.float32)
    d = g - gp
    dg = jnp.sum(d * d)
    gg = jnp.sum(g * g)

    @pl.when(i == 0)
    def _init():
        dg_ref[0, 0] = 0.0
        gg_ref[0, 0] = 0.0

    dg_ref[0, 0] += dg
    gg_ref[0, 0] += gg


def _apply_kernel(eta_ref, p_ref, g_ref, out_ref):
    eta = eta_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p - eta * g).astype(out_ref.dtype)


def _pad_2d(x: jax.Array):
    """Flatten to (M, LANES) with zero padding; returns (x2d, orig_size)."""
    n = x.size
    m = -(-n // LANES)
    pad = m * LANES - n
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat.reshape(m, LANES), n


def norms(g: jax.Array, g_prev: jax.Array, *, interpret: bool = False):
    """(sum((g-gp)^2), sum(g^2)) over one tensor, single HBM pass."""
    g2, _ = _pad_2d(g)
    gp2, _ = _pad_2d(g_prev)
    m = g2.shape[0]
    rows = min(BLOCK_ROWS, m)
    grid = -(-m // rows)
    if m % rows:
        extra = grid * rows - m
        g2 = jnp.pad(g2, ((0, extra), (0, 0)))
        gp2 = jnp.pad(gp2, ((0, extra), (0, 0)))
    LAUNCHES["norms_leaf"] += 1
    dg, gg = pl.pallas_call(
        _norms_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(g2, gp2)
    return dg[0, 0], gg[0, 0]


def apply_update(p: jax.Array, g: jax.Array, eta, *,
                 interpret: bool = False) -> jax.Array:
    """p ← p − η·g, tiled through VMEM. Same shape/dtype as p."""
    p2, n = _pad_2d(p)
    g2, _ = _pad_2d(g)
    m = p2.shape[0]
    rows = min(BLOCK_ROWS, m)
    grid = -(-m // rows)
    if m % rows:
        extra = grid * rows - m
        p2 = jnp.pad(p2, ((0, extra), (0, 0)))
        g2 = jnp.pad(g2, ((0, extra), (0, 0)))
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    LAUNCHES["apply_leaf"] += 1
    out = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(p2.shape, p.dtype),
        interpret=interpret,
    )(eta_arr, p2, g2)
    return out.reshape(-1)[:n].reshape(p.shape)
