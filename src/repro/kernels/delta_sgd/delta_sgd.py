"""Pallas TPU kernels for Δ-SGD's per-step param work.

The paper's step size needs two global reductions per local step
(‖g_k − g_{k−1}‖², ‖g_k‖² — the ‖Δx‖ term reuses the previous ‖g‖ since
Δx = −η·g for SGD updates). The reductions must complete before η is known,
so the update itself is a second pass. Kernel pair:

  delta_sgd_norms  — ONE HBM pass over (g, g_prev) producing BOTH partial
                     sums per block, accumulated across the sequential TPU
                     grid into a (1,1) output. bf16-in / f32-accumulate.
  delta_sgd_apply  — p ← p − η·g, tiled through VMEM; the caller donates
                     p so the update is in-place, and g is carried forward
                     as the next g_prev without a copy.

vs. the naive 3-pass schedule (norm Δg, norm g, update + state copy) this
is the HBM-bandwidth floor for the rule: read {g, g_prev} once, read {p, g}
once, write {p} once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024
LANES = 128


def _norms_kernel(g_ref, gp_ref, dg_ref, gg_ref):
    i = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)
    gp = gp_ref[...].astype(jnp.float32)
    d = g - gp
    dg = jnp.sum(d * d)
    gg = jnp.sum(g * g)

    @pl.when(i == 0)
    def _init():
        dg_ref[0, 0] = 0.0
        gg_ref[0, 0] = 0.0

    dg_ref[0, 0] += dg
    gg_ref[0, 0] += gg


def _apply_kernel(eta_ref, p_ref, g_ref, out_ref):
    eta = eta_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p - eta * g).astype(out_ref.dtype)


def _pad_2d(x: jax.Array):
    """Flatten to (M, LANES) with zero padding; returns (x2d, orig_size)."""
    n = x.size
    m = -(-n // LANES)
    pad = m * LANES - n
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat.reshape(m, LANES), n


def norms(g: jax.Array, g_prev: jax.Array, *, interpret: bool = False):
    """(sum((g-gp)^2), sum(g^2)) over one tensor, single HBM pass."""
    g2, _ = _pad_2d(g)
    gp2, _ = _pad_2d(g_prev)
    m = g2.shape[0]
    rows = min(BLOCK_ROWS, m)
    grid = -(-m // rows)
    if m % rows:
        extra = grid * rows - m
        g2 = jnp.pad(g2, ((0, extra), (0, 0)))
        gp2 = jnp.pad(gp2, ((0, extra), (0, 0)))
    dg, gg = pl.pallas_call(
        _norms_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(g2, gp2)
    return dg[0, 0], gg[0, 0]


def apply_update(p: jax.Array, g: jax.Array, eta, *,
                 interpret: bool = False) -> jax.Array:
    """p ← p − η·g, tiled through VMEM. Same shape/dtype as p."""
    p2, n = _pad_2d(p)
    g2, _ = _pad_2d(g)
    m = p2.shape[0]
    rows = min(BLOCK_ROWS, m)
    grid = -(-m // rows)
    if m % rows:
        extra = grid * rows - m
        p2 = jnp.pad(p2, ((0, extra), (0, 0)))
        g2 = jnp.pad(g2, ((0, extra), (0, 0)))
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(p2.shape, p.dtype),
        interpret=interpret,
    )(eta_arr, p2, g2)
    return out.reshape(-1)[:n].reshape(p.shape)
