"""jit'd wrapper: full Δ-SGD local step over a param pytree using the
Pallas kernels (falls back to interpret mode off-TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.delta_sgd import delta_sgd as k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def tree_norms(grads, prev_grads):
    """Global ‖g − g_prev‖ and ‖g‖ via the one-pass dual-reduction kernel."""
    dg2 = jnp.zeros((), jnp.float32)
    gg2 = jnp.zeros((), jnp.float32)
    for g, gp in zip(jax.tree_util.tree_leaves(grads),
                     jax.tree_util.tree_leaves(prev_grads)):
        a, b = k.norms(g, gp, interpret=_interpret())
        dg2 += a
        gg2 += b
    return jnp.sqrt(dg2), jnp.sqrt(gg2)


def tree_apply(params, grads, eta):
    leaves_p, tdef = jax.tree_util.tree_flatten(params)
    leaves_g = jax.tree_util.tree_leaves(grads)
    out = [k.apply_update(p, g, eta, interpret=_interpret())
           for p, g in zip(leaves_p, leaves_g)]
    return jax.tree_util.tree_unflatten(tdef, out)


def fused_delta_sgd_update(params, grads, state, *, gamma: float,
                           delta: float, eta0: float):
    """Drop-in replacement for core.delta_sgd.delta_sgd_update (global
    variant): kernel-backed norms + update."""
    from repro.core.delta_sgd import DeltaSGDState, _eta_rule
    first = (state.k == 0)
    dg_norm, grad_norm = tree_norms(grads, state.prev_grads)
    dx_norm = state.eta * state.prev_grad_norm
    eta, theta = _eta_rule(state.eta, state.theta, dx_norm, dg_norm,
                           gamma, delta)
    eta = jnp.where(first, jnp.asarray(eta0, jnp.float32), eta)
    theta = jnp.where(first, state.theta, theta)
    new_params = tree_apply(params, grads, eta)
    return new_params, DeltaSGDState(grads, eta, theta, grad_norm,
                                     state.k + 1)
