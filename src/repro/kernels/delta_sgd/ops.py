"""Kernel-backed Δ-SGD local step over a param pytree.

The pytree is packed into one lane-aligned flat buffer (repro.core.flat)
and the step delegates to the batched flat engine with C = 1 — two
pallas launches total, replacing the old per-leaf Python loops
(``num_leaves × 2`` launches plus a pad-concatenate copy per call).
Falls back to interpret mode off-TPU.
"""
from __future__ import annotations

from repro.core import flat as flatlib


def fused_delta_sgd_update(params, grads, state, *, gamma: float,
                           delta: float, eta0: float):
    """Drop-in replacement for core.delta_sgd.delta_sgd_update (global
    variant): the flat engine's step on (1, N) packed buffers."""
    from repro.core.delta_sgd import (DeltaSGDState, FlatDeltaSGDState,
                                      flat_delta_sgd_step)
    layout = flatlib.layout_of(params)
    mask = flatlib.round_mask(layout)
    P = flatlib.pack(params, layout)[None]            # (1, N)
    G = flatlib.pack(grads, layout)[None]
    fstate = FlatDeltaSGDState(
        flatlib.pack(state.prev_grads, layout)[None],
        state.eta[None], state.theta[None],
        state.prev_grad_norm[None], state.k)
    P, fstate = flat_delta_sgd_step(P, G, fstate, gamma=gamma, delta=delta,
                                    eta0=eta0, mask=mask)
    new_params = flatlib.unpack(P[0], layout)
    return new_params, DeltaSGDState(grads, fstate.eta[0], fstate.theta[0],
                                     fstate.prev_grad_norm[0], fstate.k)
