"""Pure-jnp oracle for the fused Δ-SGD kernels.

Two ops, matching the kernel pair:
  norms_ref(g, g_prev)      -> (sum((g-g_prev)^2), sum(g^2))  [one pass]
  apply_ref(p, g, eta)      -> p - eta * g                    [one pass]
"""
from __future__ import annotations

import jax.numpy as jnp


def norms_ref(g: jnp.ndarray, g_prev: jnp.ndarray):
    g32 = g.astype(jnp.float32)
    gp32 = g_prev.astype(jnp.float32)
    return (jnp.sum(jnp.square(g32 - gp32)), jnp.sum(jnp.square(g32)))


def apply_ref(p: jnp.ndarray, g: jnp.ndarray, eta) -> jnp.ndarray:
    return (p.astype(jnp.float32)
            - eta * g.astype(jnp.float32)).astype(p.dtype)
