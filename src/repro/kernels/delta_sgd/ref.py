"""Pure-jnp oracle for the fused Δ-SGD kernels.

Two ops, matching the kernel pair:
  norms_ref(g, g_prev)      -> (sum((g-g_prev)^2), sum(g^2))  [one pass]
  apply_ref(p, g, eta)      -> p - eta * g                    [one pass]
"""
from __future__ import annotations

import jax.numpy as jnp


def norms_ref(g: jnp.ndarray, g_prev: jnp.ndarray):
    g32 = g.astype(jnp.float32)
    gp32 = g_prev.astype(jnp.float32)
    return (jnp.sum(jnp.square(g32 - gp32)), jnp.sum(jnp.square(g32)))


def apply_ref(p: jnp.ndarray, g: jnp.ndarray, eta) -> jnp.ndarray:
    return (p.astype(jnp.float32)
            - eta * g.astype(jnp.float32)).astype(p.dtype)


def batched_norms_ref(g: jnp.ndarray, g_prev: jnp.ndarray):
    """Per-client sums over packed (C, N) buffers -> pair of (C,)."""
    g32 = g.astype(jnp.float32)
    gp32 = g_prev.astype(jnp.float32)
    return (jnp.sum(jnp.square(g32 - gp32), axis=1),
            jnp.sum(jnp.square(g32), axis=1))


def batched_apply_ref(p: jnp.ndarray, g: jnp.ndarray, eta: jnp.ndarray,
                      mask=None) -> jnp.ndarray:
    """P − η_c·G on (C, N) with per-client η (C,); optional bf16 rounding
    on mask=1 elements."""
    r = p.astype(jnp.float32) - eta[:, None] * g.astype(jnp.float32)
    if mask is None:
        return r.astype(p.dtype)
    rounded = r.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(mask[None, :] > 0.0, rounded, r).astype(p.dtype)
