"""Pallas TPU kernels for delta compression on the packed (C, N) buffer.

At the ROADMAP's millions-of-users scale the client->server link, not
the local step, is the bottleneck: every round each of the C cohort
clients ships an N-element f32 delta. These kernels compress that delta
IN PLACE on the flat engine's packed (C, N) buffer (repro.core.flat) so
that only compressed representations need to cross shard/wire
boundaries:

  quantize_int8   — per-chunk symmetric int8: one HBM pass over the
                    delta producing int8 values + one f32 scale per
                    LANES-chunk (absmax/127). Wire cost per element:
                    1 byte + 4/LANES bytes of scale (~3.88x vs f32).
  dequantize_int8 — the server-side inverse, one pass.
  topk_mask       — magnitude top-k sparsification with a THRESHOLD
                    pass (no host gather): per chunk the k-th largest
                    |x| is found by an in-register sort, then a
                    vectorized keep-mask with first-index tie-break
                    retains exactly k slots. Wire cost per chunk:
                    k x (4 + 1) bytes (value + lane index).

All three ops are chunk-local (chunk = one row of LANES consecutive
elements), so a per-shard slab of the flat dim — a whole number of
row blocks by FlatLayout construction — compresses independently:
under ``shard_map`` no cross-device traffic is ever generated.

Launch-count math, per round: int8 costs exactly 2 launches
(quantize + dequantize), top-k exactly 1, independent of leaf count,
client count, and K — the Δ-SGD step pair (2/step) is untouched.
Like the delta_sgd kernels, everything runs in interpret mode
off-TPU, and ``repro.kernels.compress.ref`` is the pure-jnp oracle
(used directly by the ``backend="xla"`` path of meshed callers).
"""
from __future__ import annotations

import functools
from collections import Counter

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import BLOCK_ROWS, LANES

# trace-time launch accounting, same contract as kernels.delta_sgd:
# incremented once per pallas_call *built* (launches per traced program)
LAUNCHES: Counter = Counter()


def reset_launch_count() -> None:
    LAUNCHES.clear()


def launch_count() -> int:
    return sum(LAUNCHES.values())


def _grid_shapes(n: int):
    """(M, rows, blocks) for a lane-aligned flat length n (no re-padding:
    FlatLayout guarantees M % rows == 0)."""
    assert n % LANES == 0, f"flat length {n} not lane-aligned"
    m = n // LANES
    rows = min(BLOCK_ROWS, m)
    assert m % rows == 0, f"flat length {n} not row-block aligned"
    return m, rows, m // rows


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (1, rows, LANES)
    absmax = jnp.max(jnp.abs(x), axis=-1)           # (1, rows)
    s_ref[...] = absmax / 127.0
    inv = jnp.where(absmax > 0.0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(x * inv[..., None]), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)


def _dequantize_kernel(q_ref, s_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = q * s_ref[...][..., None]


def _topk_kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)
    a = jnp.abs(x)
    thr = jnp.sort(a, axis=-1)[..., LANES - k]      # (1, rows)
    greater = a > thr[..., None]
    n_greater = jnp.sum(greater, axis=-1, keepdims=True)
    eq = a == thr[..., None]
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    keep = greater | (eq & (eq_rank <= (k - n_greater)))
    out_ref[...] = jnp.where(keep, x, 0.0)


def quantize_int8(x: jax.Array, *, interpret: bool = False):
    """Packed (C, N) f32 -> ((C, N) int8, (C, M) f32 per-chunk scales).

    ONE pallas launch for all clients and all chunks (2-D grid over
    (client, row-block)).
    """
    C, n = x.shape
    m, rows, blocks = _grid_shapes(n)
    x3 = x.reshape(C, m, LANES)
    LAUNCHES["quantize_int8"] += 1
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(C, blocks),
        in_specs=[pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0))],
        out_specs=[pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
                   pl.BlockSpec((1, rows), lambda c, j: (c, j))],
        out_shape=[jax.ShapeDtypeStruct((C, m, LANES), jnp.int8),
                   jax.ShapeDtypeStruct((C, m), jnp.float32)],
        interpret=interpret,
    )(x3)
    return q.reshape(C, n), s


def dequantize_int8(q: jax.Array, scales: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """((C, N) int8, (C, M) f32) -> (C, N) f32. ONE pallas launch."""
    C, n = q.shape
    m, rows, blocks = _grid_shapes(n)
    q3 = q.reshape(C, m, LANES)
    LAUNCHES["dequantize_int8"] += 1
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(C, blocks),
        in_specs=[pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
                  pl.BlockSpec((1, rows), lambda c, j: (c, j))],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
        out_shape=jax.ShapeDtypeStruct((C, m, LANES), jnp.float32),
        interpret=interpret,
    )(q3, scales)
    return out.reshape(C, n)


def topk_mask(x: jax.Array, k: int, *, interpret: bool = False) -> jax.Array:
    """Keep exactly ``k`` slots per LANES-chunk of (C, N) by magnitude,
    zero the rest (threshold pass + first-index tie-break, fully on
    device). ONE pallas launch."""
    if not 1 <= k <= LANES:
        raise ValueError(f"topk k must be in [1, {LANES}], got {k}")
    C, n = x.shape
    m, rows, blocks = _grid_shapes(n)
    x3 = x.reshape(C, m, LANES)
    LAUNCHES["topk_mask"] += 1
    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(C, blocks),
        in_specs=[pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0))],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda c, j: (c, j, 0)),
        out_shape=jax.ShapeDtypeStruct((C, m, LANES), jnp.float32),
        interpret=interpret,
    )(x3)
    return out.reshape(C, n)
