"""Fused delta-compression kernels for the packed (C, N) flat buffer."""
from repro.kernels.compress.compress import (LAUNCHES, dequantize_int8,
                                             launch_count, quantize_int8,
                                             reset_launch_count, topk_mask)
from repro.kernels.compress import ref

__all__ = ["LAUNCHES", "dequantize_int8", "launch_count", "quantize_int8",
           "reset_launch_count", "topk_mask", "ref"]
