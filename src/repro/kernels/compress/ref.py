"""Pure-jnp oracle for the delta-compression kernels.

All three ops are CHUNK-LOCAL on the packed (C, N) flat buffer
(repro.core.flat): a chunk is one lane row of ``LANES`` consecutive
elements, so the (C, N) buffer is viewed as (C, M, LANES) with
``M = N // LANES``. Chunk locality is what makes the ops trivially
shardable — a per-shard slab of the flat dim is a whole number of
chunks by FlatLayout construction, so compression never communicates.

  quantize_int8_ref    (C, N) f32 -> ((C, N) int8, (C, M) f32 scales)
  dequantize_int8_ref  ((C, N) int8, (C, M) f32) -> (C, N) f32
  topk_mask_ref        (C, N) f32 -> (C, N) f32 with exactly k nonzero
                       slots kept per chunk (magnitude top-k, threshold
                       pass + first-index tie-break — deterministic)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.flat import LANES


def _chunked(x: jnp.ndarray):
    C, n = x.shape
    assert n % LANES == 0, f"flat length {n} not lane-aligned"
    return x.reshape(C, n // LANES, LANES)


def quantize_int8_ref(x: jnp.ndarray):
    """Per-chunk symmetric int8: scale = absmax/127, q = round(x/scale).

    Zero chunks quantize to scale 0 (dequantized exactly to 0). Rounding
    is jnp.round (half-to-even), matching the Pallas kernel bit for bit.
    """
    x3 = _chunked(x.astype(jnp.float32))
    absmax = jnp.max(jnp.abs(x3), axis=-1)                    # (C, M)
    scale = absmax / 127.0
    inv = jnp.where(absmax > 0.0, 127.0 / absmax, 0.0)
    q = jnp.clip(jnp.round(x3 * inv[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize_int8_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    q3 = _chunked(q)
    return (q3.astype(jnp.float32) * scales[..., None]).reshape(q.shape)


def topk_mask_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep exactly ``k`` slots per LANES-chunk by magnitude, zero the
    rest. Threshold pass: the k-th largest |x| per chunk is the keep
    threshold; ties at the threshold are broken by first index so the
    kept count is exactly k even for constant chunks."""
    if not 1 <= k <= LANES:
        raise ValueError(f"topk k must be in [1, {LANES}], got {k}")
    x3 = _chunked(x.astype(jnp.float32))
    a = jnp.abs(x3)
    thr = jnp.sort(a, axis=-1)[..., LANES - k]                # (C, M)
    greater = a > thr[..., None]
    n_greater = jnp.sum(greater, axis=-1, keepdims=True)
    eq = a == thr[..., None]
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    keep = greater | (eq & (eq_rank <= (k - n_greater)))
    return jnp.where(keep, x3, 0.0).reshape(x.shape)
