"""Pure-jnp oracle for the Mamba2 SSD kernel: the naive sequential
recurrence, fp32.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t ⊗ B_t
    y_t = C_t · h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A_log, Bm, Cm, h0=None):
    """x: (B,S,H,P), dt: (B,S,H) (post-softplus), A_log: (H,),
    Bm/Cm: (B,S,G,N). Returns (y: (B,S,H,P), h_final: (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=2)      # (B,S,H,N)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=2)
    dA = dt * (-jnp.exp(A_log.astype(f32)))           # (B,S,H)

    h = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)

    def step(h, inp):
        xt, dtt, dAt, Bt, Ct = inp
        h = jnp.exp(dAt)[:, :, None, None] * h + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, dA, Bh, Ch))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
