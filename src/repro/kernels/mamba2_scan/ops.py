"""jit'd wrapper: full SSD scan = Pallas intra-chunk kernel + jnp
inter-chunk state combine. Drop-in for models.ssm._ssd_chunked."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.mamba2_scan import CHUNK, ssd_chunks


def ssd_scan(x, dt, A_log, Bm, Cm, h0=None, *, chunk: int = CHUNK):
    """x: (B,S,H,P), dt: (B,S,H), A_log: (H,), Bm/Cm: (B,S,G,N).

    Returns (y: (B,S,H,P) in x.dtype, h_final: (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    dA = dt.astype(f32) * (-jnp.exp(A_log.astype(f32)))
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)

    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L
    y_intra, S_c, cd, ecs = ssd_chunks(x, dt.astype(f32), dA, Bh, Ch,
                                       chunk=L)

    h0 = jnp.zeros((B, H, P, N), f32) if h0 is None else h0.astype(f32)

    def body(h, inp):
        s_c, cdc = inp
        return cdc[:, :, None, None] * h + s_c, h

    h_fin, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(cd, 1, 0)))

    # inter-chunk readout: y_q += C_q · h_prev(chunk(q)) · exp(cs_q)
    Ch_c = jnp.moveaxis(Ch.astype(f32).reshape(B, nc, L, H, N), 1, 0)
    ecs_c = jnp.moveaxis(ecs.reshape(B, nc, L, H), 1, 0)
    y_inter = jnp.einsum("cbqhn,cbhpn,cbqh->cbqhp", Ch_c, h_prev, ecs_c)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1).reshape(B, S, H, P)
    return y.astype(x.dtype), h_fin
