"""Mamba2 SSD chunk kernel for TPU (Pallas).

The SSD algorithm splits the recurrence into (i) an intra-chunk dense part
(an L×L masked matmul — MXU work) and (ii) a cheap inter-chunk state scan.
This kernel computes, per (batch·head, chunk):

    cs      = cumsum(dt * A)                      (L,)
    M[q,k]  = (C_q·B_k) · exp(cs_q − cs_k) · dt_k    for k ≤ q
    y_intra = M @ x                               (L, P)
    S_c     = Σ_k exp(cs_L − cs_k)·dt_k · x_k ⊗ B_k  (P, N)  chunk summary
    cd      = exp(cs_L)                           chunk decay

The inter-chunk combine (h ← cd·h + S_c; y += C·h_prev·exp(cs)) stays in
jnp — it is elementwise/small and keeps the sequential dependency out of
the kernel. Chunk L=64 with P=64, N=64: VMEM working set < 200 KB; the
L×L and L×P matmuls are MXU-shaped.

Grid: (B, H, nc). All refs arrive as (1, L|1, 1, ·) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

CHUNK = 64


def _ssd_chunk_kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref,
                      y_ref, s_ref, cd_ref, csl_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L,P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    dA = dA_ref[0, :, 0].astype(jnp.float32)         # (L,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (L,N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (L,N)
    L = x.shape[0]

    cs = jnp.cumsum(dA)                              # (L,)
    # intra-chunk masked decay matmul
    diff = cs[:, None] - cs[None, :]                 # (q,k)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(cols <= rows, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    M = CB * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # chunk summary state S_c = (w ⊙ x)^T-style outer-product sum -> (P,N)
    w = jnp.exp(cs[L - 1] - cs) * dt                 # (L,)
    xw = x * w[:, None]                              # (L,P)
    S_c = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    s_ref[0, 0, 0, :, :] = S_c.astype(s_ref.dtype)
    cd_ref[0, 0, 0] = jnp.exp(cs[L - 1])
    csl_ref[0, :, 0] = jnp.exp(cs).astype(csl_ref.dtype)


def ssd_chunks(x, dt, dA, Bh, Ch, *, chunk: int = CHUNK,
               interpret=None):
    """x: (B,S,H,P), dt/dA: (B,S,H), Bh/Ch: (B,S,H,N) (heads expanded).

    Returns (y_intra (B,S,H,P), S_c (B,nc,H,P,N), chunk_decay (B,nc,H),
    exp_cs (B,S,H))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = x.shape
    N = Bh.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L

    grid = (B, H, nc)
    y, S_c, cd, ecs = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, dA, Bh, Ch)
    return y, S_c, cd, ecs
