"""Pure-jnp oracle for the telemetry kernels.

Both ops reduce a per-client (C,) vector — round-end Δ-SGD step sizes,
per-client mean losses — into a fixed-shape distribution summary that
can ride in the fused loop's scanned metrics block:

  lane_histogram_ref  (C,) f32 + (B+1,) edges -> (B,) f32 counts.
                      Bin b counts  edges[b] <= x < edges[b+1]; values
                      outside every bin (including NaN — NaN fails both
                      comparisons) count nowhere. Counts are exact
                      small integers in f32, so kernel/ref/psum-summed
                      results are bit-identical, not just close.
  lane_quantiles_ref  (C,) f32 -> (Q,) f32 order statistics at the
                      fractions q/(Q-1): sort, then index
                      round(f*(C-1)) — Q=11 gives min, deciles, max.
                      Defined for finite inputs (NaNs sort last and can
                      displace the top quantiles).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantile_indices(C: int, Q: int = 11) -> tuple:
    """Static sorted-order indices for the Q evenly spaced quantile
    fractions of a C-element vector (nearest-rank with round-half-even,
    matching np.round)."""
    if C < 1 or Q < 2:
        raise ValueError(f"need C >= 1 and Q >= 2, got C={C}, Q={Q}")
    import numpy as np
    return tuple(int(np.round(q * (C - 1) / (Q - 1))) for q in range(Q))


def lane_histogram_ref(x: jnp.ndarray, edges) -> jnp.ndarray:
    """(C,) values x (B+1,) ascending edges -> (B,) f32 counts."""
    e = jnp.asarray(edges, jnp.float32)
    xf = x.astype(jnp.float32)[None, :]                       # (1, C)
    lo, hi = e[:-1, None], e[1:, None]                        # (B, 1)
    return jnp.sum((xf >= lo) & (xf < hi), axis=1).astype(jnp.float32)


def lane_quantiles_ref(x: jnp.ndarray, Q: int = 11) -> jnp.ndarray:
    """(C,) values -> (Q,) f32 order statistics (min..max via deciles
    at Q=11)."""
    idx = quantile_indices(x.shape[0], Q)
    xs = jnp.sort(x.astype(jnp.float32))
    return xs[jnp.asarray(idx, jnp.int32)]
