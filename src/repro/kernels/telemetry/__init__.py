"""Telemetry histogram/quantile kernels (own LAUNCHES namespace — the
Δ-SGD 2-launch/step invariant is counted on kernels/delta_sgd and is
untouched by telemetry)."""
from .ref import (lane_histogram_ref, lane_quantiles_ref,
                  quantile_indices)
from .telemetry import (LAUNCHES, lane_histogram, lane_quantiles,
                        launch_count, reset_launch_count)

__all__ = ["LAUNCHES", "lane_histogram", "lane_quantiles",
           "lane_histogram_ref", "lane_quantiles_ref",
           "quantile_indices", "launch_count", "reset_launch_count"]
