"""Pallas TPU kernels for the device-native telemetry plane.

The observability question the paper's claim raises — does each
client's η actually ADAPT, or did the fleet collapse onto one global
step size? — needs per-round distributions, not just eta_mean/min/max.
These kernels reduce a (C,) per-client vector into a fixed-shape
summary cheap enough to ride inside the round-fused ``lax.scan``:

  lane_histogram  (C,) values + static bin edges -> (B,) f32 counts.
                  One launch: the vector is padded with NaN (counts
                  nowhere) to a (rows, LANES) tile and every bin's
                  [lo, hi) band is summed in one VMEM pass.
  lane_quantiles  (C,) values -> (Q,) order statistics (min, deciles,
                  max at Q=11). One launch: pad with +inf, one in-VMEM
                  sort, static nearest-rank gather.

Launch accounting mirrors ``kernels/delta_sgd``: a module-level
``LAUNCHES`` counter incremented per ``pallas_call`` built, with its
OWN namespace — the Δ-SGD 2-launch/step invariant is counted on the
delta_sgd counter and stays untouched by telemetry
(tests/test_telemetry.py::test_launch_counters_separate_namespaces).
``ref.py`` is the pure-jnp oracle; both produce exact integer counts /
exact order statistics, so parity is equality, not a tolerance.
"""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import LANES

from .ref import quantile_indices

# trace-time launch accounting (same contract as kernels/delta_sgd):
# one increment per pallas_call BUILT, i.e. launches per traced step.
LAUNCHES: Counter = Counter()

# f32 min tile on TPU is (8, 128): pad the (C,) vector up to at least
# 8 full lane rows so the single-block kernels stay tile-aligned.
_MIN_ROWS = 8


def reset_launch_count() -> None:
    LAUNCHES.clear()


def launch_count() -> int:
    return sum(LAUNCHES.values())


def _pad_rows(x: jax.Array, fill: float):
    """(C,) -> (rows, LANES) with ``fill`` padding, rows >= _MIN_ROWS."""
    C = x.shape[0]
    rows = max(_MIN_ROWS, -(-C // LANES))
    pad = rows * LANES - C
    flat = x.astype(jnp.float32)
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.full((pad,), fill, jnp.float32)])
    return flat.reshape(rows, LANES)


def _hist_kernel(x_ref, e_ref, out_ref):
    xf = x_ref[...].reshape(1, -1)                  # (1, rows*LANES)
    e = e_ref[...]                                  # (1, B+1)
    lo = e[0, :-1][:, None]                         # (B, 1)
    hi = e[0, 1:][:, None]
    out_ref[...] = jnp.sum((xf >= lo) & (xf < hi), axis=1,
                           dtype=jnp.float32).reshape(1, -1)


def lane_histogram(x: jax.Array, edges, *,
                   interpret: bool | None = None) -> jax.Array:
    """(C,) f32 values, (B+1,) ascending edges -> (B,) f32 counts.

    ONE pallas launch. NaN values (and anything outside [edges[0],
    edges[-1])) count nowhere — NaN-padded lanes are free. Counts are
    exact integers in f32: bit-identical to the ref and stable under
    cross-shard psum.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e = jnp.asarray(edges, jnp.float32).reshape(1, -1)
    B = e.shape[1] - 1
    x2 = _pad_rows(x, float("nan"))
    rows = x2.shape[0]
    LAUNCHES["lane_histogram"] += 1
    out = pl.pallas_call(
        _hist_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (0, 0)),
                  pl.BlockSpec((1, B + 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, B), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.float32),
        interpret=interpret,
    )(x2, e)
    return out[0]


def lane_quantiles(x: jax.Array, Q: int = 11, *,
                   interpret: bool | None = None) -> jax.Array:
    """(C,) f32 values -> (Q,) f32 order statistics at the evenly
    spaced quantile fractions (min, deciles, max for Q=11).

    ONE pallas launch: +inf padding keeps the real values in the first
    C sorted slots, so the static nearest-rank gather is exact. Finite
    inputs only (NaNs sort after +inf and can displace top quantiles).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    C = x.shape[0]
    idx = quantile_indices(C, Q)        # static python ints
    x2 = _pad_rows(x, float("inf"))
    rows = x2.shape[0]

    def _quantile_kernel(x_ref, out_ref):
        xs = jnp.sort(x_ref[...].reshape(-1))
        out_ref[...] = jnp.stack([xs[i] for i in idx]).reshape(1, -1)

    LAUNCHES["lane_quantiles"] += 1
    out = pl.pallas_call(
        _quantile_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, Q), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, Q), jnp.float32),
        interpret=interpret,
    )(x2)
    return out[0]
