"""jnp reference for the robust-aggregation kernel (parity oracle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_trimmed_mean_ref(x: jax.Array, t: int) -> jax.Array:
    """Coordinate-wise trimmed mean of (C, N) -> (N,): sort the client
    axis, cut ``t`` per end, average — plain ``jnp.sort``."""
    C = x.shape[0]
    if not 0 <= 2 * t < C:
        raise ValueError(f"trim count {t} leaves no window for C={C}")
    s = jnp.sort(x.astype(jnp.float32), axis=0)
    return jnp.mean(s[t:C - t], axis=0)
