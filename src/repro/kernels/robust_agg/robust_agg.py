"""Pallas TPU kernel for fused robust server aggregation.

Coordinate-wise trimmed mean / median over the packed ``(C, N)`` client
delta buffer (repro.federation.faults): per flat coordinate, sort the C
client values, cut ``t`` at each end, average the surviving window. The
kernel fuses sort + trim + mean into ONE HBM pass over the buffer — the
same launch discipline as the Δ-SGD pair (repro.kernels.delta_sgd),
with the same lane-aligned (C, N) → (C, M·128) tiling and a 1-D grid
over row blocks.

The sort is a BITONIC NETWORK along the client axis: C is padded to the
next power of two with +inf rows (which sort past every real value, so
the window [t, C−t) never sees them) and each compare-exchange stage is
a vectorized ``jnp.minimum``/``jnp.maximum`` pair over a static reshape
— no ``lax.sort``, no gathers, nothing Mosaic can't lower. For
fleet-scale C the network costs O(log² C) vector passes over a block
that is already resident in VMEM, so the kernel stays HBM-bound like
the rest of the flat engine.

``ref.py`` carries the ``jnp.sort`` oracle the kernel is parity-tested
against.
"""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.flat import BLOCK_ROWS, LANES

# trace-time launch accounting, one Counter per kernel module — the
# Δ-SGD 2-launches-per-step invariant counts ITS module's launches, so
# the aggregation kernel keeps its own book.
LAUNCHES: Counter = Counter()


def reset_launch_count() -> None:
    LAUNCHES.clear()


def launch_count() -> int:
    return sum(LAUNCHES.values())


def _bitonic_sort_axis0(x: jax.Array) -> jax.Array:
    """Ascending bitonic sort along axis 0 (length must be a power of
    two). Every stage is a static reshape + min/max compare-exchange —
    the direction bit of a pair only depends on bits ABOVE the stage
    stride, so it broadcasts from the leading group axis."""
    P2 = x.shape[0]
    tail = x.shape[1:]
    k = 2
    while k <= P2:
        s = k // 2
        while s >= 1:
            groups = P2 // (2 * s)
            y = x.reshape((groups, 2, s) + tail)
            lo, hi = y[:, 0], y[:, 1]
            mn = jnp.minimum(lo, hi)
            mx = jnp.maximum(lo, hi)
            base = jnp.arange(groups) * (2 * s)
            asc = ((base & k) == 0).reshape((groups,) + (1,) * (1 + len(tail)))
            first = jnp.where(asc, mn, mx)
            second = jnp.where(asc, mx, mn)
            x = jnp.stack([first, second], axis=1).reshape((P2,) + tail)
            s //= 2
        k *= 2
    return x


def _next_pow2(c: int) -> int:
    p = 1
    while p < c:
        p *= 2
    return p


def _make_trimmed_kernel(c: int, t: int):
    def kernel(x_ref, out_ref):
        xs = _bitonic_sort_axis0(x_ref[...].astype(jnp.float32))
        # pad rows are +inf and sort past index c−1; the surviving
        # window [t, c−t) is all real values
        win = xs[t:c - t]
        out_ref[...] = jnp.sum(win, axis=0) / jnp.float32(c - 2 * t)
    return kernel


def _grid_shapes(n: int):
    """(M, rows, blocks) for a lane-aligned flat length n — same
    geometry contract as the Δ-SGD kernels (FlatLayout pre-pads)."""
    assert n % LANES == 0, f"flat length {n} not lane-aligned"
    m = n // LANES
    rows = min(BLOCK_ROWS, m)
    assert m % rows == 0, f"flat length {n} not row-block aligned"
    return m, rows, m // rows


def batched_trimmed_mean(x: jax.Array, t: int, *,
                         interpret: bool = False) -> jax.Array:
    """Coordinate-wise trimmed mean over the packed (C, N) buffer:
    sort the C client values per coordinate, drop ``t`` at each end,
    average the rest. ONE pallas launch for all coordinates. Invalid
    clients must already be zeroed by the caller (the zero delta is the
    'no contribution' element — repro.federation.faults documents the
    semantics). ``t = (C−1)//2`` gives the coordinate-wise median."""
    C, n = x.shape
    if not 0 <= 2 * t < C:
        raise ValueError(f"trim count {t} leaves no window for C={C}")
    m, rows, blocks = _grid_shapes(n)
    P2 = _next_pow2(C)
    x3 = x.astype(jnp.float32).reshape(C, m, LANES)
    if P2 > C:
        x3 = jnp.concatenate(
            [x3, jnp.full((P2 - C, m, LANES), jnp.inf, jnp.float32)])
    LAUNCHES["batched_trimmed_mean"] += 1
    out = pl.pallas_call(
        _make_trimmed_kernel(C, t),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((P2, rows, LANES), lambda j: (0, j, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANES), jnp.float32),
        interpret=interpret,
    )(x3)
    return out.reshape(n)
