"""Flash attention for TPU (Pallas): causal GQA with optional sliding
window, online-softmax accumulation over KV blocks.

Grid: (B, H, num_q_blocks, num_kv_blocks). TPU executes the grid
sequentially with the last dim innermost, so the (m, l, acc) running state
for one (b, h, qi) lives in VMEM scratch across the kv sweep:

  kv == 0      : init m = -inf, l = 0, acc = 0
  every block  : masked scores -> online-softmax update (MXU matmuls)
  kv == last   : out = acc / l

Block sizes default to (128, 128): q/k/v tiles of (128, hd) with
hd ∈ {64, 128} keep the working set ≤ ~¼ MB — far under the ~16 MB VMEM —
and are MXU-aligned (128×128 systolic array). GQA is handled in the index
map: kv head = h // (H // KV), so no KV duplication in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               causal: bool, window: Optional[int], block_q: int,
               block_k: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd) -> (B,S,H,hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    # pad seq dims to block multiples
    Sp = -(-S // block_q) * block_q
    Tp = -(-T // block_k) * block_k
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        if not causal:
            raise ValueError("non-causal padding needs an explicit mask")
    nq, nk = Sp // block_q, Tp // block_k

    kernel = functools.partial(_fa_kernel, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
