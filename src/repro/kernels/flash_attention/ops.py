"""jit'd wrapper for the flash-attention kernel (interpret mode off-TPU)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention

__all__ = ["flash_attention"]
