"""Pure-jnp oracle for the flash-attention kernel: causal (optionally
sliding-window) GQA attention, full-precision softmax."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd), H % KV == 0. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg,
                        k.astype(jnp.float32)) / np.sqrt(hd)
    T = k.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (S, T), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
