"""Roofline analysis from a compiled (dry-run) artifact.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × peak)          peak = 197 TFLOP/s bf16
    memory     = HLO_bytes / (chips × hbm_bw)        hbm  = 819 GB/s
    collective = Σ_ops coll_bytes·hops / (ici_bw)    ici  = 50 GB/s/link

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
already per-partition under SPMD — we normalise either way, see below).
Collective bytes are parsed out of the compiled HLO text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
result-shape bytes × a ring-transfer factor from the replica-group size.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveOp:
    kind: str
    bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        """Per-device bytes on the wire, ring algorithm."""
        n = max(self.group_size, 2)
        if self.kind == "all-reduce":
            return 2 * (n - 1) / n * self.bytes
        if self.kind in ("all-gather", "reduce-scatter"):
            return (n - 1) / n * self.bytes
        if self.kind == "all-to-all":
            return (n - 1) / n * self.bytes
        return self.bytes  # collective-permute: one hop


def _shape_bytes(dtype: str, dims: str) -> int:
    nelem = int(np.prod([int(d) for d in dims.split(",") if d])) if dims \
        else 1
    return nelem * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            nbytes = sum(_shape_bytes(d, s) for d, s in
                         _SHAPE_RE.findall(tuple_part))
        else:
            nbytes = _shape_bytes(dtype, dims)
        gs = 0
        mi = _GROUPS_IOTA_RE.search(line)
        if mi:
            gs = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                gs = len([x for x in ml.group(1).split(",") if x.strip()])
        ops.append(CollectiveOp(kind, nbytes, gs or 2))
    return ops


@dataclass
class Roofline:
    flops: float                  # whole-program HLO flops
    hbm_bytes: float
    coll_bytes: float             # summed wire bytes (per device)
    chips: int
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    per_device: bool = True       # cost_analysis is per-partition under SPMD

    @property
    def t_compute(self) -> float:
        f = self.flops if self.per_device else self.flops / self.chips
        return f / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        b = self.hbm_bytes if self.per_device else self.hbm_bytes / self.chips
        return b / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def summary(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_by_kind": self.coll_by_kind,
        }


def analyze(compiled, chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    ops = parse_collectives(compiled.as_text())
    coll = sum(o.wire_bytes for o in ops)
    by_kind: Dict[str, float] = {}
    for o in ops:
        by_kind[o.kind] = by_kind.get(o.kind, 0.0) + o.wire_bytes
    return Roofline(flops, nbytes, coll, chips, by_kind)


def extrapolate(r1: Roofline, r2: Roofline, l1: int, l2: int,
                L: int) -> Roofline:
    """Affine-in-depth extrapolation: programs are (fixed part) + L×(layer
    part), so two depths determine the full-depth cost exactly. Used because
    XLA cost_analysis counts while-loop bodies once (verified), making full
    unrolls necessary — but unrolling an 81-layer model is prohibitive;
    unrolling 1 and 2 pattern-cycles is not."""
    def ext(a, b):
        slope = (b - a) / (l2 - l1)
        return max(0.0, a + slope * (L - l1))

    kinds = set(r1.coll_by_kind) | set(r2.coll_by_kind)
    by_kind = {k: ext(r1.coll_by_kind.get(k, 0.0),
                      r2.coll_by_kind.get(k, 0.0)) for k in kinds}
    return Roofline(ext(r1.flops, r2.flops),
                    ext(r1.hbm_bytes, r2.hbm_bytes),
                    ext(r1.coll_bytes, r2.coll_bytes),
                    r1.chips, by_kind)


def model_flops(cfg, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) — the 'useful
    compute' denominator for the HLO-vs-model ratio. For inference steps
    use 2·N·D."""
    n = cfg.active_param_count()
    return 6.0 * n * tokens


def memory_analysis_summary(compiled) -> Dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
