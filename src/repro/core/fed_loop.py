"""Round-fused training loop: a multi-round ``lax.scan`` on persistent
flat state.

The host-loop drivers (launch/train.py et al.) pay one dispatch per
round: a jitted single-round function is re-launched from Python, and
``_make_flat_round`` re-derives the flat (C, N) buffer from the param
pytree at the top of every call. At small models and fleet-scale client
counts — exactly the regime the paper's heterogeneity experiments live
in — that host round-trip dominates wall-clock.

``make_fl_loop(rounds_per_call=R)`` fuses R rounds into ONE jitted
computation:

  * the carried state is a ``FlatFLState`` — the param pytree packed
    into the (N,) flat buffer (repro.core.flat) and the EF21
    error-feedback tree packed to (C, N). Packing happens once per
    R-round block (``flatten_fl_state``); unpacking only at
    eval/checkpoint cadence (``unflatten_fl_state``).
  * a ``lax.scan`` chains R rounds of the SAME flat round body the
    single-round engine runs (``fed_round`` attaches it to the returned
    round_fn as ``round_fn.flat_body``), so fused and host-loop rounds
    are bit-exact by construction.
  * cohort scheduling stays on device: the scenario schedulers
    (repro.federation) key every draw on ``(seed, round)`` and the round
    counter rides in the carry, so the in-scan draws equal the host
    pipeline's gather draw round for round.
  * per-round batches come either pre-stacked with a leading R axis, or
    — the fast path — as (R, C, K, b) int32 gather indices into a
    pre-staged device-resident example arena (``arena_gather``): the
    host ships a few hundred KB of indices per block instead of
    re-staging the full (C, K, b, ...) batch every round.
  * callers jit with ``donate_argnums=0`` so the carried flat buffers
    update in place: peak live memory does not grow with R.

The per-local-step kernel schedule is untouched: the scan body traces
the fused kernel pair once (2 launches per local step), and the
executed launch schedule of one R-round block is exactly R times the
single round's — 2·K·R launches, still independent of leaf and client
count.

Composition: everything the flat round engine supports — sharded meshes
(the HLO assertions hold on the scanned computation), heterogeneous K_c
lane masks, FedBuff async buffering, delta compression + EF21 — flows
through unchanged, because the scan body IS the single-round body.
Metrics come back stacked: every leaf gains a leading R axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as flatlib
from repro.core.fed_round import FLState, make_fl_round


class FlatFLState(NamedTuple):
    """FLState in persistent flat form — the scan carry of the fused
    loop, and the block-boundary checkpoint payload.

    ``P`` is the packed (N,) f32 global params; ``ef`` (EF21
    compression) is the packed (C, N) f32 reconstruction state.
    ``server_state`` and the async ``buffer`` keep their pytree form —
    the server's per-leaf dtypes are load-bearing for bit-exact
    arithmetic, and the buffer's f32 delta tree is the known-good form
    under SPMD meshes (partitioning a 1-D packed concatenate mis-
    compiles on XLA CPU, see fed_round's ``pack1``).
    """
    P: jax.Array
    server_state: Any
    round: jax.Array
    buffer: Any = None
    ef: Any = None


def flatten_fl_state(state: FLState, layout: flatlib.FlatLayout
                     ) -> FlatFLState:
    """Pack an FLState once per R-round block. Exact: params pack to the
    f32 buffer losslessly (bf16 -> f32 widens), and the ef tree is f32
    already, so pack/unpack round-trips bit-for-bit."""
    ef = state.ef
    if ef is not None:
        ef = flatlib.pack_batched(ef, layout)
    fstate = FlatFLState(flatlib.pack(state.params, layout),
                         state.server_state, state.round, state.buffer, ef)
    # donation hygiene: jax caches scalar constants, so two zero-valued
    # counters (e.g. FLState.round and the async buffer's count) can
    # alias ONE device buffer — a donating Execute rejects duplicate
    # buffers. Copy scalar leaves apart; the big buffers are fresh packs.
    return jax.tree.map(
        lambda x: jnp.array(x, copy=True) if getattr(x, "ndim", 1) == 0
        else x, fstate)


def unflatten_fl_state(fstate: FlatFLState, layout: flatlib.FlatLayout
                       ) -> FLState:
    """Back to pytree form — eval / checkpoint-interop cadence only."""
    ef = fstate.ef
    if ef is not None:
        ef = flatlib.unpack_batched(fstate.ef, layout, cast=False)
    return FLState(flatlib.unpack(fstate.P, layout), fstate.server_state,
                   fstate.round, fstate.buffer, ef)


def arena_gather(arena, idx: jax.Array):
    """Device-side per-round batch gather: ``idx`` (C, K, b) int32 rows
    index the staged example arena (leaves (num_examples, ...)), giving
    (C, K, b, ...) client batches — the on-device equivalent of the host
    pipeline's per-round numpy gather + transfer."""
    return jax.tree.map(lambda a: a[idx], arena)


def make_fl_loop(loss_fn, client_opt, server_opt, *, params_like,
                 num_rounds: int, rounds_per_call: int = 8,
                 weighted: bool = False, flat="xla", mesh=None,
                 federation=None, scenario=None,
                 num_clients: Optional[int] = None, client_sizes=None,
                 compression=None, gather=None):
    """Build the R-round fused loop.

    Returns ``loop_fn(fstate, round_data, client_weights=None,
    arena=None) -> (fstate, metrics)`` where

      * ``fstate`` is a ``FlatFLState`` (``flatten_fl_state``); jit the
        loop with ``donate_argnums=0`` so its buffers update in place;
      * ``round_data`` leaves carry a leading R axis: stacked client
        batches (R, C, K, b, ...), or — with ``gather`` (e.g.
        ``arena_gather``) — per-round gather indices resolved against
        the device-resident ``arena``;
      * ``client_weights`` is an optional (R, C) weight block
        (``weighted`` aggregation);
      * ``metrics`` leaves come back stacked over the R rounds.

    ``params_like`` (a params pytree or its ShapeDtypeStructs) fixes the
    flat layout; the remaining knobs mirror ``make_fl_round`` — the loop
    requires the flat engine (``flat`` False is rejected) and composes
    with mesh sharding, scenarios, and compression exactly like the
    single-round engine, because the scan body IS that engine's round
    body. ``rounds_per_call`` is advisory: the actual R of a call is the
    leading axis of ``round_data`` (the tail block of a training run may
    be shorter).

    State form (``loop_fn.state_form``): without a mesh the carry is the
    persistent ``FlatFLState`` ("flat"). Under ``mesh``/``federation``
    the scan carries the pytree ``FLState`` instead ("tree") and the
    per-round flat conversions cancel inside each iteration: XLA CPU
    SPMD mis-partitions a materialized 1-D packed concatenate
    (jax<=0.4.37, see fed_round), so the (N,) carry cannot cross the
    scan boundary under a mesh — the (C, N) round buffer, where the
    real traffic lives, stays sharded either way (the HLO assertions
    hold on the scanned computation).
    """
    if not flat:
        raise ValueError("the round-fused loop requires the flat engine "
                         "(flat='xla'|'pallas'): the carry is the packed "
                         "flat buffer")
    if rounds_per_call < 1:
        raise ValueError(f"rounds_per_call must be >= 1, got "
                         f"{rounds_per_call}")
    round_fn = make_fl_round(loss_fn, client_opt, server_opt,
                             num_rounds=num_rounds, weighted=weighted,
                             flat=flat, mesh=mesh, federation=federation,
                             scenario=scenario, num_clients=num_clients,
                             client_sizes=client_sizes,
                             compression=compression)
    body = getattr(round_fn, "flat_body", None)
    if body is None:  # pragma: no cover - make_fl_round always attaches it
        raise ValueError("make_fl_round returned no flat round body")
    shards = federation.flat_shards(mesh) if federation is not None else 1
    layout = flatlib.layout_of(params_like, shards=shards)

    sharded = mesh is not None

    def loop_fn(carry, round_data, client_weights=None, arena=None):
        if gather is not None and arena is None:
            raise ValueError("this loop gathers batches from a staged "
                             "arena: pass arena=")

        def one_round(st, inp):
            data, w_r = inp
            w_r = w_r if has_w else None
            batches = gather(arena, data) if gather is not None else data
            if sharded:
                st, metrics, _ = round_fn(st, batches,
                                          client_weights=w_r)
            else:
                st, metrics, _ = body(st, batches, layout,
                                      client_weights=w_r)
            return st, metrics

        # scan xs must be arrays: a missing weight block rides along as
        # a zero-size per-round placeholder
        R = jax.tree_util.tree_leaves(round_data)[0].shape[0]
        w = (client_weights if client_weights is not None
             else jnp.zeros((R, 0), jnp.float32))
        has_w = client_weights is not None
        return jax.lax.scan(one_round, carry, (round_data, w))

    loop_fn.layout = layout
    loop_fn.rounds_per_call = rounds_per_call
    loop_fn.state_form = "tree" if sharded else "flat"
    return loop_fn
