"""Round-fused training loop: a multi-round ``lax.scan`` on persistent
flat state.

The host-loop drivers (launch/train.py et al.) pay one dispatch per
round: a jitted single-round function is re-launched from Python, and
``_make_flat_round`` re-derives the flat (C, N) buffer from the param
pytree at the top of every call. At small models and fleet-scale client
counts — exactly the regime the paper's heterogeneity experiments live
in — that host round-trip dominates wall-clock.

``make_fl_loop(rounds_per_call=R)`` fuses R rounds into ONE jitted
computation:

  * the carried state is a ``FlatFLState`` — the param pytree packed
    into the (N,) flat buffer (repro.core.flat) and the EF21
    error-feedback tree packed to (C, N). Packing happens once per
    R-round block (``flatten_fl_state``); unpacking only at
    eval/checkpoint cadence (``unflatten_fl_state``).
  * a ``lax.scan`` chains R rounds of the SAME flat round body the
    single-round engine runs (``fed_round`` attaches it to the returned
    round_fn as ``round_fn.flat_body``), so fused and host-loop rounds
    are bit-exact by construction.
  * cohort scheduling stays on device: the scenario schedulers
    (repro.federation) key every draw on ``(seed, round)`` and the round
    counter rides in the carry, so the in-scan draws equal the host
    pipeline's gather draw round for round.
  * per-round batches come either pre-stacked with a leading R axis, or
    — the fast path — as (R, C, K, b) int32 gather indices into a
    pre-staged device-resident example arena (``arena_gather``): the
    host ships a few hundred KB of indices per block instead of
    re-staging the full (C, K, b, ...) batch every round.
  * callers jit with ``donate_argnums=0`` so the carried flat buffers
    update in place: peak live memory does not grow with R.

The per-local-step kernel schedule is untouched: the scan body traces
the fused kernel pair once (2 launches per local step), and the
executed launch schedule of one R-round block is exactly R times the
single round's — 2·K·R launches, still independent of leaf and client
count.

Composition: everything the flat round engine supports — sharded meshes
(the HLO assertions hold on the scanned computation), heterogeneous K_c
lane masks, FedBuff async buffering, delta compression + EF21 — flows
through unchanged, because the scan body IS the single-round body.
Metrics come back stacked: every leaf gains a leading R axis.

Block-level shard_map (``make_fl_loop(block_sharded=True)``): the
per-round sharded engine re-enters the mesh at every kernel — one
``shard_map`` per local step plus pack/unpack resharding — which at toy
sizes costs ~45x the replicated round in pure dispatch. The block path
instead wraps the ENTIRE R-round ``lax.scan`` in ONE ``shard_map`` over
the mesh's client axes: each device carries its C_loc cohort rows
through all R rounds locally (full-N rows — the client-axes-only
regime, ``federation.flat_shards(mesh) == 1``), and the only
client-crossing collective is the per-round (N,) ``psum`` of the
(compressed) aggregate — so both HLO invariants (no resident f32[C, N],
no full-precision per-client delta across client shards) hold on the
block program, and per-client local math is bit-identical to the
replicated engine (aggregation differs only by psum reassociation).
Scenario draws for all R rounds are made ONCE at jit level, pinned
replicated (partitioned threefry emits different bits per shard), and
fed through the shard_map as replicated (R, C) operands.

Fleet loop (``make_fleet_loop``): the registered-vs-sampled split. A
``repro.federation.arena.ClientArena`` holds per-REGISTERED-client
state (Δ-SGD η carry, EF21 reconstruction, participation history) in
(C_registered, ...) storage; each scanned round draws the cohort ids
on device (the scheduler's Gumbel-top-k over all C_registered
candidates), gathers ONLY those C rows (``arena_take``), runs the same
flat round body on the cohort slab, and scatters the updated rows back
(``arena_update``). Never-sampled clients' rows are never touched, and
with error feedback off no (C_registered, N) buffer ever exists —
machine-checked by ``repro.sharding.hlo
.assert_cohort_only_materialization`` on the compiled loop.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as flatlib
from repro.core.fed_round import FLState, make_fl_round


class FlatFLState(NamedTuple):
    """FLState in persistent flat form — the scan carry of the fused
    loop, and the block-boundary checkpoint payload.

    ``P`` is the packed (N,) f32 global params; ``ef`` (EF21
    compression) is the packed (C, N) f32 reconstruction state.
    ``server_state`` and the async ``buffer`` keep their pytree form —
    the server's per-leaf dtypes are load-bearing for bit-exact
    arithmetic, and the buffer's f32 delta tree is the known-good form
    under SPMD meshes (partitioning a 1-D packed concatenate mis-
    compiles on XLA CPU, see fed_round's ``pack1``).
    """
    P: jax.Array
    server_state: Any
    round: jax.Array
    buffer: Any = None
    ef: Any = None


def flatten_fl_state(state: FLState, layout: flatlib.FlatLayout
                     ) -> FlatFLState:
    """Pack an FLState once per R-round block. Exact: params pack to the
    f32 buffer losslessly (bf16 -> f32 widens), and the ef tree is f32
    already, so pack/unpack round-trips bit-for-bit."""
    ef = state.ef
    if ef is not None:
        ef = flatlib.pack_batched(ef, layout)
    fstate = FlatFLState(flatlib.pack(state.params, layout),
                         state.server_state, state.round, state.buffer, ef)
    # donation hygiene: jax caches scalar constants, so two zero-valued
    # counters (e.g. FLState.round and the async buffer's count) can
    # alias ONE device buffer — a donating Execute rejects duplicate
    # buffers. Copy scalar leaves apart; the big buffers are fresh packs.
    return jax.tree.map(
        lambda x: jnp.array(x, copy=True) if getattr(x, "ndim", 1) == 0
        else x, fstate)


def unflatten_fl_state(fstate: FlatFLState, layout: flatlib.FlatLayout
                       ) -> FLState:
    """Back to pytree form — eval / checkpoint-interop cadence only."""
    ef = fstate.ef
    if ef is not None:
        ef = flatlib.unpack_batched(fstate.ef, layout, cast=False)
    return FLState(flatlib.unpack(fstate.P, layout), fstate.server_state,
                   fstate.round, fstate.buffer, ef)


def arena_gather(arena, idx: jax.Array):
    """Device-side per-round batch gather: ``idx`` (C, K, b) int32 rows
    index the staged example arena (leaves (num_examples, ...)), giving
    (C, K, b, ...) client batches — the on-device equivalent of the host
    pipeline's per-round numpy gather + transfer."""
    return jax.tree.map(lambda a: a[idx], arena)


def make_fl_loop(loss_fn, client_opt, server_opt, *, params_like,
                 num_rounds: int, rounds_per_call: int = 8,
                 weighted: bool = False, flat="xla", mesh=None,
                 federation=None, scenario=None,
                 num_clients: Optional[int] = None, client_sizes=None,
                 compression=None, gather=None,
                 block_sharded: bool = False, telemetry=None):
    """Build the R-round fused loop.

    Returns ``loop_fn(fstate, round_data, client_weights=None,
    arena=None) -> (fstate, metrics)`` where

      * ``fstate`` is a ``FlatFLState`` (``flatten_fl_state``); jit the
        loop with ``donate_argnums=0`` so its buffers update in place;
      * ``round_data`` leaves carry a leading R axis: stacked client
        batches (R, C, K, b, ...), or — with ``gather`` (e.g.
        ``arena_gather``) — per-round gather indices resolved against
        the device-resident ``arena``;
      * ``client_weights`` is an optional (R, C) weight block
        (``weighted`` aggregation);
      * ``metrics`` leaves come back stacked over the R rounds.

    ``params_like`` (a params pytree or its ShapeDtypeStructs) fixes the
    flat layout; the remaining knobs mirror ``make_fl_round`` — the loop
    requires the flat engine (``flat`` False is rejected) and composes
    with mesh sharding, scenarios, and compression exactly like the
    single-round engine, because the scan body IS that engine's round
    body. ``rounds_per_call`` is advisory: the actual R of a call is the
    leading axis of ``round_data`` (the tail block of a training run may
    be shorter).

    State form (``loop_fn.state_form``): without a mesh the carry is the
    persistent ``FlatFLState`` ("flat"). Under ``mesh``/``federation``
    the scan carries the pytree ``FLState`` instead ("tree") and the
    per-round flat conversions cancel inside each iteration: XLA CPU
    SPMD mis-partitions a materialized 1-D packed concatenate
    (jax<=0.4.37, see fed_round), so the (N,) carry cannot cross the
    scan boundary under a mesh — the (C, N) round buffer, where the
    real traffic lives, stays sharded either way (the HLO assertions
    hold on the scanned computation).

    ``block_sharded=True`` (requires ``mesh``/``federation`` in the
    client-axes-only regime, ``federation.flat_shards(mesh) == 1``):
    fold the whole R-round scan inside ONE shard_map instead of
    re-entering the mesh per kernel — see the module docstring. The
    carry is then the persistent ``FlatFLState`` ("flat" state form):
    the (N,) flat params stay a plain replicated operand, so the 1-D
    pack never meets the SPMD partitioner. Fault injection / robust
    aggregation / quorum are not supported on the block path (their
    order-statistic tails need cross-client data movement) — use the
    per-round sharded engine for those.

    ``telemetry`` (None/bool/repro.telemetry.TelemetrySpec): the
    in-scan distribution block rides the scanned metrics — extra
    fixed-shape leaves with a leading R axis, zero host syncs inside a
    block, trajectory bit-exact on vs off. On the block-sharded path
    the per-shard η-histogram counts join the existing packed per-round
    psum (exact integer sums — still 2 collectives per round, and the
    summed histogram equals the replicated engine's bit-for-bit);
    ``loss_deciles`` is skipped there (a cross-client sort has no
    shard-local form).
    """
    if not flat:
        raise ValueError("the round-fused loop requires the flat engine "
                         "(flat='xla'|'pallas'): the carry is the packed "
                         "flat buffer")
    if rounds_per_call < 1:
        raise ValueError(f"rounds_per_call must be >= 1, got "
                         f"{rounds_per_call}")
    if block_sharded:
        if mesh is None or federation is None:
            raise ValueError("block_sharded=True requires mesh= and "
                             "federation=")
        if federation.flat_shards(mesh) != 1:
            raise ValueError(
                "the block-level shard_map shards CLIENTS only — each "
                "device carries full-N rows for its C_loc clients, so "
                "the flat dim must be replicated: use a FederationSpec "
                "whose fsdp/tp axes are absent from the mesh "
                f"(flat_shards == 1, got "
                f"{federation.flat_shards(mesh)})")
        if scenario is not None and (scenario.faulty or scenario.robust
                                     or scenario.quorum > 0):
            raise ValueError(
                "fault injection / robust aggregation / quorum are not "
                "supported on the block-sharded path — their "
                "order-statistic tails need cross-client data movement; "
                "use the per-round sharded engine "
                "(make_fl_loop(mesh=..., block_sharded=False))")
        return _make_block_loop(
            loss_fn, client_opt, server_opt, params_like=params_like,
            num_rounds=num_rounds, rounds_per_call=rounds_per_call,
            weighted=weighted, flat=flat, mesh=mesh,
            federation=federation, scenario=scenario,
            num_clients=num_clients, client_sizes=client_sizes,
            compression=compression, gather=gather, telemetry=telemetry)
    round_fn = make_fl_round(loss_fn, client_opt, server_opt,
                             num_rounds=num_rounds, weighted=weighted,
                             flat=flat, mesh=mesh, federation=federation,
                             scenario=scenario, num_clients=num_clients,
                             client_sizes=client_sizes,
                             compression=compression, telemetry=telemetry)
    body = getattr(round_fn, "flat_body", None)
    if body is None:  # pragma: no cover - make_fl_round always attaches it
        raise ValueError("make_fl_round returned no flat round body")
    shards = federation.flat_shards(mesh) if federation is not None else 1
    layout = flatlib.layout_of(params_like, shards=shards)

    sharded = mesh is not None

    def loop_fn(carry, round_data, client_weights=None, arena=None):
        if gather is not None and arena is None:
            raise ValueError("this loop gathers batches from a staged "
                             "arena: pass arena=")

        def one_round(st, inp):
            data, w_r = inp
            w_r = w_r if has_w else None
            batches = gather(arena, data) if gather is not None else data
            if sharded:
                st, metrics, _ = round_fn(st, batches,
                                          client_weights=w_r)
            else:
                st, metrics, _ = body(st, batches, layout,
                                      client_weights=w_r)
            return st, metrics

        # scan xs must be arrays: a missing weight block rides along as
        # a zero-size per-round placeholder
        R = jax.tree_util.tree_leaves(round_data)[0].shape[0]
        w = (client_weights if client_weights is not None
             else jnp.zeros((R, 0), jnp.float32))
        has_w = client_weights is not None
        return jax.lax.scan(one_round, carry, (round_data, w))

    loop_fn.layout = layout
    loop_fn.rounds_per_call = rounds_per_call
    loop_fn.state_form = "tree" if sharded else "flat"
    return loop_fn


def _make_block_loop(loss_fn, client_opt, server_opt, *, params_like,
                     num_rounds: int, rounds_per_call: int,
                     weighted: bool, flat, mesh, federation,
                     scenario=None, num_clients=None, client_sizes=None,
                     compression=None, gather=None, telemetry=None):
    """One shard_map around the whole R-round scan (client-axes-only
    sharding). Each device runs its C_loc clients' full local math —
    grad eval, the fused Δ-SGD kernel pair, delta compression — on a
    local (C_loc, N) slab; the mesh is entered once per BLOCK, and the
    client-crossing traffic is 2 collectives per round — one packed
    psum carrying the (compressed) aggregate plus every scalar metric
    sum ((N+5,), widening to (N+5+B,) when telemetry appends its B
    η-histogram bin counts), and one (2,) pmin for the η extrema.
    Per-client math is therefore bit-identical
    to the replicated flat engine; the aggregate differs only by psum
    reassociation (<= ~1e-5 at f32, same tolerance the per-round
    sharded parity tests use). Scenario draws for all R rounds happen
    ONCE at jit level, pinned replicated, and enter the shard_map as
    replicated (R, C) operands — every shard sees the full vectors (for
    wire accounting and FedBuff stats) and slices its local columns by
    mesh position. The caller must jit ``loop_fn`` (the replication
    pins need a jit context); donate_argnums=0 works as usual."""
    from jax.sharding import NamedSharding, PartitionSpec as PS

    from repro.core.delta_sgd import (_shard_map, flat_delta_sgd_init,
                                      flat_delta_sgd_step)
    from repro.federation.heterogeneity import active_mask
    from repro.kernels.telemetry import lane_histogram_ref
    from repro.models.common import scan_unroll
    from repro.telemetry.spec import resolve_telemetry

    tele = resolve_telemetry(telemetry)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    hyper = client_opt.hyper
    if (client_opt.name != "delta_sgd" or hyper is None
            or hyper.get("groupwise")):
        raise ValueError("flat engine requires the global-rule delta_sgd "
                         f"client optimizer, got {client_opt.name!r}")
    gamma, delta_h = hyper["gamma"], hyper["delta"]
    eta0, theta0 = hyper["eta0"], hyper["theta0"]
    backend = "xla" if flat == "xla" else "pallas"

    if compression is not None or (
            scenario is not None and scenario.bandwidth_heterogeneous):
        from repro.compression import get_compression
        compression = get_compression(compression)
    hetero = scenario is not None and scenario.heterogeneous
    is_async = scenario is not None and scenario.is_async
    bw_hetero = scenario is not None and scenario.bandwidth_heterogeneous
    comp = compression if (compression is not None
                           and compression.active(scenario)) else None
    use_ef = comp is not None and comp.error_feedback

    # client-axes-only regime: flat_shards == 1 (checked by the caller),
    # so the layout is the REPLICATED layout — bit-compatible with the
    # un-meshed engines and the fused host loop.
    layout = flatlib.layout_of(params_like, shards=1)
    N = layout.padded_size
    ca, _ = federation.flat_axes(mesh)
    centry = ca if ca else None
    n_shards = 1
    for a in ca:
        n_shards *= mesh.shape[a]

    def loop_fn(fstate: FlatFLState, round_data, client_weights=None,
                arena=None):
        if gather is not None and arena is None:
            raise ValueError("this loop gathers batches from a staged "
                             "arena: pass arena=")
        if use_ef and fstate.ef is None:
            raise ValueError("error-feedback compression needs "
                             "FlatFLState.ef (flatten an FLState built "
                             "with init_fl_state(..., compression=spec, "
                             "cohort=C))")
        leaves = jax.tree_util.tree_leaves(round_data)
        R, C, K = leaves[0].shape[0], leaves[0].shape[1], leaves[0].shape[2]
        if C % n_shards:
            raise ValueError(f"cohort C={C} must divide the "
                             f"{n_shards} client shards")
        C_loc = C // n_shards
        has_w = client_weights is not None

        def rep(x):
            # replicated pin: partitioned threefry (the default
            # jax_threefry_partitionable=False) emits different bits per
            # shard, so every scenario draw is forced replicated BEFORE
            # it enters the shard_map
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, PS()))

        # all R rounds' scenario draws, once, at jit level
        r_idx = fstate.round + jnp.arange(R, dtype=jnp.int32)
        draws = {}
        if hetero:
            draws["k"] = rep(jax.vmap(
                lambda t: scenario.draw_step_counts(t, C, K))(r_idx))
        if is_async:
            draws["stale"] = rep(jax.vmap(
                lambda t: scenario.draw_staleness(t, C))(r_idx))
        if bw_hetero:
            draws["lev"] = rep(jax.vmap(
                lambda t: scenario.draw_compression_levels(t, C))(r_idx))
        w = (client_weights if has_w
             else jnp.zeros((R, 0), jnp.float32))

        def block(fst, data, w_all, draws_all, arena_l):
            """Runs on every device with LOCAL shards: data leaves
            (R, C_loc, K, ...); fst/w_all/draws_all/arena_l replicated
            except fst.ef (C_loc, N)."""
            def cpsum(x):
                return jax.lax.psum(x, ca) if ca else x

            def cpmin(x):
                return jax.lax.pmin(x, ca) if ca else x

            # this shard's client offset: axis 0 of a (C, ...) operand
            # partitioned over the tuple ``ca`` is blocked row-major in
            # axis order, so the linear block index is the mixed-radix
            # axis position
            if ca:
                bidx = jnp.int32(0)
                for a in ca:
                    bidx = bidx * mesh.shape[a] + jax.lax.axis_index(a)
                c_off = bidx * C_loc
            else:
                c_off = jnp.int32(0)

            def local_cols(full):
                return jax.lax.dynamic_slice_in_dim(full, c_off, C_loc, 0)

            mask = flatlib.round_mask(layout)
            Cf = jnp.float32(C)

            def one_round(st, xs):
                data_r, w_r, d_r = xs
                batches = (gather(arena_l, data_r) if gather is not None
                           else data_r)
                gp = flatlib.unpack(st.P, layout)
                P = jnp.broadcast_to(st.P[None], (C_loc, N))
                P_start = P if (is_async or comp is not None) else None
                S = flat_delta_sgd_init(C_loc, layout, eta0=eta0,
                                        theta0=theta0)
                k_full = d_r.get("k")
                budget = (local_cols(k_full) if k_full is not None
                          else None)
                batches_t = jax.tree.map(
                    lambda x: jnp.swapaxes(x, 0, 1), batches)

                def step(carry, inp):
                    batch_k, k_idx = inp
                    P, S = carry
                    params_c = flatlib.unpack_batched(P, layout)
                    (l, _), g = jax.vmap(
                        grad_fn, in_axes=(0, 0, None, None)
                    )(params_c, batch_k, gp, None)
                    G = flatlib.pack_batched(g, layout)
                    active = ((k_idx < budget) if budget is not None
                              else None)
                    P, S = flat_delta_sgd_step(
                        P, G, S, gamma=gamma, delta=delta_h, eta0=eta0,
                        mask=mask, active=active, backend=backend)
                    return (P, S), l

                (P, S), losses = jax.lax.scan(
                    step, (P, S),
                    (batches_t, jnp.arange(K, dtype=jnp.int32)),
                    unroll=scan_unroll())
                losses = losses.T       # (C_loc, K)

                # collective budget: every client-crossing SUM rides
                # ONE packed (N+5,) psum together with the round's
                # aggregate, and both η extrema share ONE pmin — 2
                # collectives per round total, which is what keeps the
                # sharded block's per-round cost near the replicated
                # loop's on rendezvous-priced meshes. The concat lives
                # inside the shard_map body (a per-device program, no
                # SPMD partitioner), so the 1-D packed-concat jit
                # gotcha (core/flat.py) does not apply.
                if k_full is not None:
                    am_l = active_mask(budget, K)
                    loss_num = jnp.sum(losses * am_l)
                    loss_den = jnp.sum(active_mask(k_full, K))
                    last_num = jnp.sum(jnp.take_along_axis(
                        losses, (budget - 1)[:, None], axis=1)[:, 0])
                else:
                    loss_num = jnp.sum(losses)
                    loss_den = jnp.float32(C * K)
                    last_num = jnp.sum(losses[:, -1])
                scal = jnp.stack([
                    loss_num, last_num, jnp.sum(S.eta),
                    jnp.sum(S.clips.astype(jnp.float32)),
                    jnp.sum((~S.valid).astype(jnp.float32))])
                if tele.enabled:
                    # per-shard η-histogram counts ride the SAME packed
                    # psum (exact integer sums in f32, so the summed
                    # histogram is bit-identical to the replicated
                    # engine's) — the collective budget stays at 2/round
                    scal = jnp.concatenate([
                        scal,
                        lane_histogram_ref(
                            S.eta, jnp.asarray(tele.eta_edges()))])
                ext = cpmin(jnp.stack([jnp.min(S.eta),
                                       -jnp.max(S.eta)]))
                extra = {}
                if k_full is not None:
                    kf = k_full.astype(jnp.float32)
                    extra.update(k_eff_mean=jnp.mean(kf),
                                 k_eff_min=jnp.min(kf),
                                 k_eff_max=jnp.max(kf))

                new_ef = st.ef
                if comp is not None:
                    from repro.compression.ops import compress_flat
                    lev_full = d_r.get("lev")
                    lev_loc = (local_cols(lev_full)
                               if lev_full is not None else None)
                    delta_c = P - P_start
                    resid = (delta_c - st.ef) if use_ef else delta_c
                    chat = compress_flat(resid, comp, levels=lev_loc,
                                         backend=backend)
                    delta_hat = (st.ef + chat) if use_ef else chat
                    if use_ef:
                        new_ef = delta_hat
                    # wire accounting on the FULL level vector — every
                    # shard reports the identical cohort-total bytes
                    wire = comp.wire_bytes(layout.size, levels=lev_full,
                                           num_clients=C)
                    extra.update(
                        wire_bytes=jnp.sum(wire),
                        comp_ratio=(4.0 * layout.size * C)
                        / jnp.sum(wire))
                    if lev_full is not None:
                        extra["comp_level_mean"] = jnp.mean(
                            lev_full.astype(jnp.float32))
                    P_agg = P_start + delta_hat
                else:
                    delta_hat = None
                    P_agg = P

                if not is_async:
                    if weighted and has_w:
                        wn = w_r.astype(jnp.float32)
                        wn = wn / jnp.sum(wn)
                        agg_local = jnp.tensordot(local_cols(wn), P_agg,
                                                  axes=(0, 0))
                        agg_div = jnp.float32(1.0)
                    else:
                        agg_local = jnp.sum(P_agg, axis=0)
                        agg_div = Cf
                    packed = cpsum(jnp.concatenate([agg_local, scal]))
                    scal_g = packed[N:]
                    agg = flatlib.unpack(packed[:N] / agg_div, layout)
                    new_params, sstate = server_opt.update(
                        gp, agg, st.server_state)
                    new_st = FlatFLState(
                        flatlib.pack(new_params, layout), sstate,
                        st.round + 1, st.buffer, new_ef)
                else:
                    from repro.federation.buffer import (
                        buffer_merge, buffer_step, staleness_weights)
                    stale_full = d_r["stale"]
                    wst = staleness_weights(stale_full,
                                            scenario.staleness_exp)
                    if weighted and has_w:
                        wst = wst * w_r.astype(jnp.float32)
                    agg_local = jnp.tensordot(
                        local_cols(wst),
                        delta_hat if comp is not None else (P - P_start),
                        axes=(0, 0))
                    packed = cpsum(jnp.concatenate([agg_local, scal]))
                    scal_g = packed[N:]
                    delta_tree = flatlib.unpack(packed[:N], layout,
                                                cast=False)
                    # buffer math runs on the full replicated vectors,
                    # so the buffer state stays identical on every shard
                    buf = buffer_merge(st.buffer, delta_tree,
                                       jnp.sum(wst), C, stale_full)
                    new_params, sstate, buf, flushed = buffer_step(
                        gp, st.server_state, buf, server_opt,
                        scenario.buffer_size)
                    sf = stale_full.astype(jnp.float32)
                    extra.update(
                        stale_mean=jnp.mean(sf), stale_max=jnp.max(sf),
                        buffer_fill=buf.count.astype(jnp.float32),
                        flushed=flushed)
                    new_st = FlatFLState(
                        flatlib.pack(new_params, layout), sstate,
                        st.round + 1, buf, new_ef)
                metrics = {
                    "loss": scal_g[0] / loss_den,
                    "loss_last_step": scal_g[1] / Cf,
                    "eta_mean": scal_g[2] / Cf,
                    "eta_min": ext[0], "eta_max": -ext[1],
                    "eta_clip_rate": scal_g[3] / jnp.float32(C * K),
                    "nan_guard_rate": scal_g[4] / Cf}
                if tele.enabled:
                    metrics.update(eta_hist=scal_g[5:],
                                   eta_clip_count=scal_g[3],
                                   nan_guard_count=scal_g[4])
                metrics.update(extra)
                return new_st, metrics

            return jax.lax.scan(one_round, fst,
                                (data, w_all, draws_all))

        fspec = jax.tree.map(lambda _: PS(), fstate)
        if fstate.ef is not None:
            fspec = fspec._replace(ef=PS(centry, None))
        in_specs = (fspec,
                    jax.tree.map(lambda _: PS(None, centry), round_data),
                    jax.tree.map(lambda _: PS(), w),
                    jax.tree.map(lambda _: PS(), draws),
                    jax.tree.map(lambda _: PS(), arena))
        # out_specs: exact state tree + a PS() prefix for the metrics
        # dict (everything psum'd/derived-from-replicated inside)
        blk = _shard_map(block, mesh, in_specs, (fspec, PS()))
        new_fstate, metrics = blk(fstate, round_data, w, draws, arena)

        if num_clients is not None and scenario is not None:
            sch = scenario.make_scheduler(num_clients, C,
                                          sizes=client_sizes)
            metrics["cohort_ids"] = rep(jax.vmap(
                lambda t: sch.sample(jax.random.key(scenario.seed), t)
            )(r_idx))
        return new_fstate, metrics

    loop_fn.layout = layout
    loop_fn.rounds_per_call = rounds_per_call
    loop_fn.state_form = "flat"
    return loop_fn


def make_fleet_loop(loss_fn, client_opt, server_opt, *, params_like,
                    num_rounds: int, num_registered: int,
                    rounds_per_call: int = 8, weighted: bool = False,
                    flat="xla", scenario=None, client_sizes=None,
                    compression=None, gather=None, batch_index_fn=None,
                    eta_carry: bool = False, seed: int = 0,
                    telemetry=None):
    """Fleet-scale fused loop: C_registered clients, only the sampled
    cohort materialized per round.

    Returns ``loop_fn(carry, round_data, client_weights=None,
    arena=None) -> (carry, metrics)`` where ``carry`` is the pair
    ``(FlatFLState, repro.federation.arena.ClientArena)`` — the global
    training state plus the per-REGISTERED-client arena. Per scanned
    round the loop

      1. draws the cohort ids ON DEVICE: ``sch.sample(key, round)`` —
         the scheduler's Gumbel-top-k over all ``num_registered``
         candidates, the SAME (seed, round)-keyed draw the host data
         pipeline uses to gather batches, so data and state stay
         aligned without shipping ids;
      2. gathers the cohort's arena rows (``arena_take``) — EF21 slabs
         and η carry re-enter the round body through ``FLState.ef`` /
         ``eta0_c``;
      3. runs the standard flat round body (bit-identical to
         ``make_fl_loop``'s, because it IS that body);
      4. scatters updated rows back (``arena_update``): round-end η,
         participation count, last-seen round, new EF21 state. Rows of
         clients not in the cohort are untouched — a never-sampled
         client's state is bit-identical after any number of rounds.

    ``round_data`` modes mirror ``make_fl_loop`` — stacked batches
    (R, C, K, b, ...) or (R, C, K, b) gather indices resolved against
    ``arena`` via ``gather`` — plus a third, fleet-native mode:
    ``batch_index_fn(ids, round) -> (C, K, b)`` computes the gather
    indices ON DEVICE from the drawn cohort ids (e.g. id -> data
    partition row ranges), so the host ships nothing per block;
    ``round_data`` is then ignored except for its leading R axis (pass
    e.g. ``jnp.zeros((R, C, K, 0))``).

    ``eta_carry=True`` warm-starts a returning client's η₀ from its
    arena row (round-end η of its LAST participation) instead of the
    scalar η₀ — the locally-adaptive per-client state of Mukherjee et
    al.; the default False keeps Algorithm 1's per-round η reset (and
    bit-exactness against ``make_fl_loop``) intact.

    Memory ceiling: with error feedback off the arena holds only
    O(C_registered) per-client scalars — no (C_registered, N) buffer
    exists in the compiled program (``repro.sharding.hlo
    .assert_cohort_only_materialization``). Un-meshed by design: the
    cohort slab is the same (C, N) buffer the replicated engines run,
    and C (not C_registered) bounds the round's compute.

    ``seed`` keys the cohort draw when ``scenario`` is None (the data
    pipeline's fallback scheduler uses its own data seed there).
    """
    if not flat:
        raise ValueError("the fleet loop requires the flat engine "
                         "(flat='xla'|'pallas')")
    if num_registered < 1:
        raise ValueError(f"num_registered must be >= 1, got "
                         f"{num_registered}")
    from repro.federation.arena import ClientArena, arena_take, arena_update
    from repro.federation.schedulers import make_scheduler

    round_fn = make_fl_round(loss_fn, client_opt, server_opt,
                             num_rounds=num_rounds, weighted=weighted,
                             flat=flat, scenario=scenario,
                             compression=compression, telemetry=telemetry)
    body = round_fn.flat_body
    layout = flatlib.layout_of(params_like, shards=1)
    if compression is not None or (
            scenario is not None and scenario.bandwidth_heterogeneous):
        from repro.compression import get_compression
        compression = get_compression(compression)
    use_ef = (compression is not None and compression.error_feedback
              and compression.active(scenario))
    hyper = client_opt.hyper or {}
    eta0 = hyper.get("eta0", 0.0)

    def loop_fn(carry, round_data, client_weights=None, arena=None):
        fstate, car = carry
        if not isinstance(car, ClientArena):
            raise ValueError("fleet carry is (FlatFLState, ClientArena) "
                             "— build the arena with arena_init()")
        if use_ef and car.ef is None:
            raise ValueError("error-feedback compression needs the "
                             "arena's EF slab: arena_init(..., "
                             "ef_width=layout.padded_size)")
        if (gather is not None or batch_index_fn is not None) \
                and arena is None:
            raise ValueError("this loop gathers batches from a staged "
                             "arena: pass arena=")
        leaves = jax.tree_util.tree_leaves(round_data)
        R, C = leaves[0].shape[0], leaves[0].shape[1]
        sch = (scenario.make_scheduler(num_registered, C,
                                       sizes=client_sizes)
               if scenario is not None
               else make_scheduler("uniform", num_clients=num_registered,
                                   cohort=C))
        root_key = jax.random.key(scenario.seed if scenario is not None
                                  else seed)
        has_w = client_weights is not None

        def one_round(cr, xs):
            fst, ar = cr
            data_r, w_r = xs
            w_r = w_r if has_w else None
            ids = sch.sample(root_key, fst.round)       # (C,) int32
            rows = arena_take(ar, ids)
            if batch_index_fn is not None:
                g = gather if gather is not None else arena_gather
                batches = g(arena, batch_index_fn(ids, fst.round))
            elif gather is not None:
                batches = gather(arena, data_r)
            else:
                batches = data_r
            fst_in = fst._replace(ef=rows.ef if use_ef else None)
            new_fst, metrics, aux = body(
                fst_in, batches, layout, client_weights=w_r,
                eta0_c=rows.eta if eta_carry else None)
            # fleet telemetry from the arena rows (pre-update)
            seen = (rows.last_round >= 0).astype(jnp.float32)
            gap = jnp.where(rows.last_round >= 0,
                            fst.round - rows.last_round, 0
                            ).astype(jnp.float32)
            metrics.update(
                cohort_ids=ids,
                revisit_frac=jnp.mean(seen),
                realized_stale_mean=(jnp.sum(gap)
                                     / jnp.maximum(jnp.sum(seen), 1.0)),
                eta_carry_mean=jnp.mean(rows.eta))
            # scatter: η survives only through valid lanes (a latched
            # NaN guard keeps the previous carry), participation
            # bookkeeping always advances for sampled clients
            new_rows = ClientArena(
                jnp.where(aux.valid, aux.etas, rows.eta),
                rows.rounds_seen + 1,
                jnp.broadcast_to(fst.round, rows.last_round.shape
                                 ).astype(jnp.int32),
                new_fst.ef if use_ef else None)
            ar = arena_update(ar, ids, new_rows)
            # the carry keeps ef=None: per-client EF state lives in the
            # arena between rounds, not in cohort slots
            return (new_fst._replace(ef=None), ar), metrics

        w = (client_weights if has_w
             else jnp.zeros((R, 0), jnp.float32))
        (new_fstate, new_arena), metrics = jax.lax.scan(
            one_round, (fstate._replace(ef=None), car), (round_data, w))
        return (new_fstate, new_arena), metrics

    loop_fn.layout = layout
    loop_fn.rounds_per_call = rounds_per_call
    loop_fn.state_form = "fleet"
    loop_fn.eta0 = eta0
    return loop_fn
