"""Unified client-optimizer interface — everything the paper compares:

    SGD, SGD(↓), SGDM, SGDM(↓), Adam, Adagrad, SPS, Δ-SGD

ClientOpt is a triple of pure pytree functions, vmappable over a leading
client axis and scannable over local steps:

    state = opt.init(params)
    state = opt.reset(state, round_frac)        # start of each round
    params, state = opt.update(params, grads, state, loss)

``round_frac`` = t/T implements the paper's step-wise LR decay (÷10 after
50% and 75% of rounds) for the (↓) variants.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.delta_sgd import (DeltaSGDState, delta_sgd_init,
                                  delta_sgd_reset, delta_sgd_update,
                                  _global_norm)


class ClientOpt(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    reset: Callable[[Any, jax.Array], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]
    hyper: Any = None   # optimizer hyperparams (dict) for engines that
    #                     re-express the rule outside update(), e.g. the
    #                     flat-parameter Δ-SGD engine in fed_round


def _decay_scale(round_frac):
    """Paper's (↓) schedule: ÷10 at 50%, ÷100 at 75% of total rounds."""
    return jnp.where(round_frac >= 0.75, 0.01,
                     jnp.where(round_frac >= 0.5, 0.1, 1.0))


def _sgd_like(name, lr, momentum=0.0, decay=False):
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params) if momentum
                else None,
                "scale": jnp.asarray(1.0, jnp.float32)}

    def reset(state, round_frac):
        state = dict(state)
        state["scale"] = (_decay_scale(round_frac) if decay
                          else jnp.asarray(1.0, jnp.float32))
        return state

    def update(params, grads, state, loss):
        del loss
        eta = lr * state["scale"]
        if momentum:
            m = jax.tree.map(lambda m_, g: momentum * m_ + g,
                             state["m"], grads)
            params = jax.tree.map(
                lambda p, m_: (p.astype(jnp.float32)
                               - eta * m_.astype(jnp.float32)
                               ).astype(p.dtype), params, m)
            return params, {"m": m, "scale": state["scale"]}
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, state

    return ClientOpt(name, init, reset, update)


def _adam_like(name, lr, b1=0.9, b2=0.999, eps=1e-8, adagrad=False):
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.asarray(0, jnp.int32)}

    def reset(state, round_frac):
        del round_frac
        return state

    def update(params, grads, state, loss):
        del loss
        t = state["t"] + 1
        if adagrad:
            v = jax.tree.map(lambda v_, g: v_ + jnp.square(g), state["v"],
                             grads)
            params = jax.tree.map(
                lambda p, g, v_: (p.astype(jnp.float32) - lr * g
                                  / (jnp.sqrt(v_.astype(jnp.float32)) + eps)
                                  ).astype(p.dtype),
                params, grads, v)
            return params, {"m": state["m"], "v": v, "t": t}
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"],
                         grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        tf = t.astype(jnp.float32)
        bc1 = 1 - b1 ** tf
        bc2 = 1 - b2 ** tf
        params = jax.tree.map(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               - lr * (m_.astype(jnp.float32) / bc1)
                               / (jnp.sqrt(v_.astype(jnp.float32) / bc2)
                                  + eps)).astype(p.dtype),
            params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return ClientOpt(name, init, reset, update)


def _sps(name, c=0.5, f_star=0.0, eps=1e-8):
    """Stochastic Polyak step size (Loizou et al. 2021), paper footnote 4:
    η = (f_i(x) − f*) / (c·‖∇f_i(x)‖²) with f* = 0, c = 0.5."""
    def init(params):
        del params
        return {}

    def reset(state, round_frac):
        del round_frac
        return state

    def update(params, grads, state, loss):
        gn2 = jnp.square(_global_norm(grads))
        eta = (loss.astype(jnp.float32) - f_star) / (c * gn2 + eps)
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, state

    return ClientOpt(name, init, reset, update)


def _delta_sgd(name, *, gamma, delta, eta0, theta0, groupwise=False,
               use_pallas=False):
    def init(params):
        return delta_sgd_init(params, eta0=eta0, theta0=theta0,
                              groupwise=groupwise)

    def reset(state, round_frac):
        del round_frac
        return delta_sgd_reset(state, eta0=eta0, theta0=theta0)

    def update(params, grads, state, loss):
        del loss
        return delta_sgd_update(params, grads, state, gamma=gamma,
                                delta=delta, eta0=eta0,
                                use_pallas=use_pallas)

    hyper = dict(gamma=gamma, delta=delta, eta0=eta0, theta0=theta0,
                 groupwise=groupwise)
    return ClientOpt(name, init, reset, update, hyper)


def get_client_opt(name: str, fl_cfg=None, **overrides) -> ClientOpt:
    """Factory. ``fl_cfg`` supplies defaults (FLConfig); overrides win."""
    from repro.configs.base import FLConfig
    cfg = fl_cfg or FLConfig()
    lr = overrides.get("lr", cfg.lr)
    mom = overrides.get("momentum", cfg.momentum)
    if name == "sgd":
        return _sgd_like("sgd", lr)
    if name == "sgd_decay":
        return _sgd_like("sgd_decay", lr, decay=True)
    if name == "sgdm":
        return _sgd_like("sgdm", lr, momentum=mom)
    if name == "sgdm_decay":
        return _sgd_like("sgdm_decay", lr, momentum=mom, decay=True)
    if name == "adam":
        return _adam_like("adam", lr)
    if name == "adagrad":
        return _adam_like("adagrad", lr, adagrad=True)
    if name == "sps":
        return _sps("sps", c=overrides.get("c", 0.5))
    if name == "delta_sgd":
        return _delta_sgd(
            "delta_sgd",
            gamma=overrides.get("gamma", cfg.gamma),
            delta=overrides.get("delta", cfg.delta),
            eta0=overrides.get("eta0", cfg.eta0),
            theta0=overrides.get("theta0", cfg.theta0),
            groupwise=overrides.get("groupwise", False),
            use_pallas=overrides.get("use_pallas", False))
    raise KeyError(f"unknown client optimizer {name!r}")


CLIENT_OPTS = ("sgd", "sgd_decay", "sgdm", "sgdm_decay", "adam", "adagrad",
               "sps", "delta_sgd")
