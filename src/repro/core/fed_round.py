"""One jitted federated round (Algorithm 1, full loop body).

Communication pattern, expressed jax-natively:
  * the |S_t| participating clients form a leading pytree axis C, sharded
    over the mesh's client axes (FederationSpec);
  * each client runs K local steps (lax.scan) of its ClientOpt from the
    common round-start params (vmap over C — params broadcast);
  * server aggregation is a (weighted) mean over C — XLA lowers it to an
    all-reduce over the client mesh axes, i.e. the FedAvg collective;
  * the ServerOpt (FedAvg/FedAdam/...) finishes the round.

Batch layout: every leaf of ``client_batches`` is (C, K, ...) — K per-step
micro-batches of the client's *local* data.

Flat engine (``flat=`` argument, Δ-SGD only): instead of vmapping the
optimizer over C, the param pytree is packed ONCE at round start into a
lane-aligned flat buffer broadcast to (C, N) (repro.core.flat), the
K-step scan runs entirely on flat buffers — per step: one vmapped grad
eval on the unpacked view, then exactly two fused kernel launches
(batched norms + batched apply) for all leaves and all clients —
aggregation is a single mean over the packed C axis, and the result is
unpacked once at round end. ``flat="pallas"``/``True`` uses the batched
Pallas kernels, ``flat="xla"`` the same math as fused jnp ops (for
meshed/pjit callers).

Sharded flat engine (``mesh=`` + ``federation=`` arguments): the packed
(C, N) buffer is mesh-sharded end to end per
``FederationSpec.flat_spec(mesh)`` — clients over the client axes, N over
the fsdp/tp axes, with a per-shard padded layout
(``layout_of(..., shards=...)``) so every device's slab stays
lane-aligned. Pack/unpack run under ``with_sharding_constraint``, the
per-step kernel pair runs inside ``shard_map`` with a psum dual-norm
reduction (repro.core.delta_sgd.flat_delta_sgd_step_sharded), and the
round-end aggregation is a sharded mean over the client axes. The caller
must jit the returned round_fn (sharding constraints require a jit
context).

Scenario engine (``scenario=`` argument, repro.federation): a
``Scenario`` adds the heterogeneity the paper motivates Δ-SGD with —
  * compute heterogeneity: per-client step counts K_c ≤ K_max drawn each
    round (SpeedModel), lowered as per-step lane masks. The flat engine
    folds them into the fused kernel pair as η=0 lanes (scan stays
    fixed-shape, stragglers' dead lanes cost no extra launches); the
    vmap engine applies the same masking per leaf for parity.
  * async buffered aggregation (FedBuff-style, flat engine only): client
    deltas enter a staleness-weighted server buffer
    (repro.federation.buffer) and the ServerOpt only steps when M
    updates have accumulated. The buffer rides in ``FLState.buffer``.
  * cohort reporting: when ``num_clients`` is given the round reports
    the scheduler's cohort ids (the SAME draw the data pipeline used to
    gather the batches) plus staleness / effective-K metrics.
All scenario randomness flows from ``fold_in(key(scenario.seed),
state.round)``, so rounds are reproducible and host/device draws agree.

Delta compression (``compression=`` argument, repro.compression, flat
engine only): each client's round delta Δ_c = x_c^K − x_t is compressed
on the packed (C, N) buffer before ANY aggregation — int8 per-chunk
quantization or magnitude top-k, optionally behind EF21 error feedback
(state in ``FLState.ef``), with per-client bandwidth levels drawn by a
bandwidth-heterogeneous scenario. The sync tail averages
x_t + Δ̂_c, the async tail buffers the staleness-weighted Δ̂ sum, so
compression composes with every ServerOpt and with FedBuff. Under
meshes the compressors are chunk-local and run inside ``shard_map``
strictly before the client-mean psum: no full-precision per-client
delta ever crosses a shard boundary (machine-checked by
``repro.sharding.hlo.assert_no_fullprec_delta_collective``). An inert
spec (kind="none") takes the exact pre-compression code path — bit
exact.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as flatlib
from repro.core.client_opt import ClientOpt
from repro.core.delta_sgd import (DeltaSGDState, flat_delta_sgd_init,
                                  flat_delta_sgd_step,
                                  flat_delta_sgd_step_sharded)
from repro.core.server_opt import ServerOpt


class FLState(NamedTuple):
    params: Any
    server_state: Any
    round: jax.Array
    buffer: Any = None      # AsyncBufferState under async scenarios
    ef: Any = None          # EF21 error-feedback state (compression):
                            # pytree like params with a leading cohort
                            # axis, f32 — each slot's reconstruction g_c


class RoundAux(NamedTuple):
    """Per-client round outputs the flat body hands back NEXT TO the new
    state — what the fleet arena (repro.federation.arena) scatters into
    per-registered-client storage after a round.

    ``P_locals`` (C, N): round-end local params (the flat form of the
    vmap engine's ``new_locals``). ``etas`` (C,): round-end Δ-SGD step
    sizes — the per-client adaptive state that persists across the
    rounds a client sits out when an arena carries it. ``valid`` (C,)
    bool: NaN-guard survivors (all True on fault-free rounds)."""
    P_locals: jax.Array
    etas: jax.Array
    valid: jax.Array


def init_fl_state(params, server_opt: ServerOpt, scenario=None,
                  compression=None, cohort: Optional[int] = None) -> FLState:
    """``scenario`` (repro.federation.Scenario): async scenarios allocate
    the server-side delta buffer; sync scenarios and None leave it out.
    ``compression`` (repro.compression.CompressionSpec) with
    ``error_feedback=True`` allocates the per-cohort-slot EF21
    reconstruction tree — ``cohort`` (= C, clients per round) is then
    required to size its leading axis."""
    buf = None
    if scenario is not None and scenario.is_async:
        from repro.federation.buffer import buffer_init
        buf = buffer_init(params)
    ef = None
    if compression is not None and compression.error_feedback:
        if cohort is None:
            raise ValueError("error-feedback compression needs cohort= "
                             "(clients per round) to size FLState.ef")
        ef = jax.tree.map(
            lambda p: jnp.zeros((cohort,) + p.shape, jnp.float32), params)
    return FLState(params, server_opt.init(params),
                   jnp.asarray(0, jnp.int32), buf, ef)


def _round_metrics(losses, etas, step_counts=None):
    """Shared metric block. ``losses`` is (C, K); ``etas`` is (C,) with
    NaN for clients whose optimizer has no scalar step-size state
    (non-Δ-SGD, groupwise). Under heterogeneous K the per-step losses of
    a finished client are evaluated at frozen params, so they are masked
    out of the mean and "last step" means the client's K_c-th step."""
    if step_counts is None:
        loss = jnp.mean(losses)
        last = jnp.mean(losses[:, -1])
    else:
        from repro.federation.heterogeneity import active_mask
        amask = active_mask(step_counts, losses.shape[1])
        loss = jnp.sum(losses * amask) / jnp.sum(amask)
        last = jnp.mean(jnp.take_along_axis(
            losses, (step_counts - 1)[:, None], axis=1)[:, 0])
    return {"loss": loss, "loss_last_step": last,
            "eta_mean": jnp.mean(etas),
            "eta_min": jnp.min(etas),
            "eta_max": jnp.max(etas)}


def _finish_round(state: FLState, agg, losses, etas,
                  server_opt: ServerOpt, *, step_counts=None, extra=None,
                  ef=None):
    """Shared synchronous round tail: server update + metrics. ``ef`` is
    the rolled EF21 state (compression); None keeps the incoming one."""
    params, sstate = server_opt.update(state.params, agg,
                                       state.server_state)
    metrics = _round_metrics(losses, etas, step_counts)
    if extra:
        metrics.update(extra)
    return FLState(params, sstate, state.round + 1, state.buffer,
                   state.ef if ef is None else ef), metrics


def _scenario_extras(scenario, round_idx, C, num_clients, client_sizes,
                     step_counts, rep=lambda x: x):
    """Cohort / effective-K metrics reported from inside the jitted round.

    ``rep`` pins a draw to REPLICATED sharding under meshes: with
    ``jax_threefry_partitionable=False`` (the default on the pinned jax)
    a partitioned threefry emits different bits per shard, so any
    scenario draw that may be sharded by propagation must be forced
    replicated to agree with the host pipeline's draw."""
    extra = {}
    if scenario is None:
        return extra
    if num_clients is not None:
        sch = scenario.make_scheduler(num_clients, C, sizes=client_sizes)
        extra["cohort_ids"] = rep(sch.sample(
            jax.random.key(scenario.seed), round_idx))
    if step_counts is not None:
        sc = step_counts.astype(jnp.float32)
        extra.update(k_eff_mean=jnp.mean(sc), k_eff_min=jnp.min(sc),
                     k_eff_max=jnp.max(sc))
    return extra


def make_fl_round(loss_fn, client_opt: ClientOpt, server_opt: ServerOpt, *,
                  num_rounds: int, weighted: bool = False,
                  flat=False, mesh=None, federation=None,
                  scenario=None, num_clients: Optional[int] = None,
                  client_sizes=None, compression=None, telemetry=None):
    """loss_fn(params, batch, global_params, prev_params)->(loss, metrics).

    Returns round_fn(state, client_batches, client_weights=None,
                     prev_local_params=None) -> (state, metrics).

    ``flat``: False (vmap engine), True/"pallas", or "xla" — the packed
    flat-buffer Δ-SGD engine (requires client_opt "delta_sgd", global
    rule).

    ``mesh`` + ``federation`` (FederationSpec): flat engine only — keep
    the packed (C, N) buffer sharded per ``federation.flat_spec(mesh)``
    for the whole round (see module docstring). Both or neither.

    ``scenario`` (repro.federation.Scenario): heterogeneous step counts
    (both engines) and async buffered aggregation (flat engine only).
    ``num_clients``/``client_sizes`` let the round also report the
    scheduler's cohort ids (see module docstring).

    ``compression`` (repro.compression.CompressionSpec, or a kind name):
    client->server delta compression on the flat engine — see the
    module docstring. An inert spec (kind="none", no error feedback, no
    bandwidth-heterogeneous scenario) leaves every engine on its exact
    pre-compression code path, so results stay bit-exact.

    ``telemetry`` (None/bool/repro.telemetry.TelemetrySpec): the in-scan
    distribution block — per-round η histogram over client lanes, per-
    client mean-loss deciles, absolute guard hit counts — added to the
    round metrics as fixed-shape device arrays. Strictly read-only over
    round-end values: the trajectory is bit-exact with telemetry on vs
    off (tests/test_telemetry.py).
    """
    from repro.telemetry.spec import resolve_telemetry, round_telemetry
    tele = resolve_telemetry(telemetry)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if (mesh is None) != (federation is None):
        raise ValueError("mesh and federation must be given together")
    if mesh is not None and not flat:
        raise ValueError("mesh/federation sharding requires the flat "
                         "engine (flat=...)")
    if scenario is not None and scenario.is_async and not flat:
        raise ValueError(
            "async buffered aggregation requires the flat engine "
            "(flat=...): the staleness-weighted delta merge is one "
            "reduction over the packed (C, N) buffer")
    if scenario is not None and not flat and (
            scenario.faulty or scenario.robust or scenario.quorum > 0):
        raise ValueError(
            "fault injection / robust aggregation / quorum degradation "
            "require the flat engine (flat=...): faults are lowered as "
            "per-client lanes on the packed (C, N) buffer and the "
            "RobustAgg ladder runs on it (repro.federation.faults)")
    if compression is not None or (
            scenario is not None and scenario.bandwidth_heterogeneous):
        # a bandwidth-heterogeneous scenario implies compression even if
        # the caller passed none: resolve the inert kind="none" spec
        # (level 0 of the ladder) so the per-client level draws actually
        # happen — same resolution as the launch drivers and benchmarks
        from repro.compression import get_compression
        compression = get_compression(compression)
        if compression.active(scenario) and not flat:
            raise ValueError(
                "delta compression requires the flat engine (flat=...): "
                "the compressors operate on the packed (C, N) buffer")

    if flat:
        return _make_flat_round(grad_fn, client_opt, server_opt,
                                num_rounds=num_rounds, weighted=weighted,
                                backend="xla" if flat == "xla" else "pallas",
                                mesh=mesh, federation=federation,
                                scenario=scenario, num_clients=num_clients,
                                client_sizes=client_sizes,
                                compression=compression, telemetry=tele)

    hetero = scenario is not None and scenario.heterogeneous

    def one_client(global_params, round_frac, batch_c, prev_c, k_c):
        ostate = client_opt.reset(client_opt.init(global_params), round_frac)
        K = jax.tree_util.tree_leaves(batch_c)[0].shape[0]

        def step(carry, inp):
            b, k_idx = inp
            p, os = carry
            (l, _), g = grad_fn(p, b, global_params, prev_c)
            p_new, os_new = client_opt.update(p, g, os, l)
            if k_c is not None:
                # heterogeneous K: past this client's K_c budget the
                # candidate update is discarded — params and optimizer
                # state stay frozen (same semantics as the flat engine's
                # η=0 lane mask).
                act = k_idx < k_c
                p_new = jax.tree.map(
                    lambda a, o: jnp.where(act, a, o), p_new, p)
                os_new = jax.tree.map(
                    lambda a, o: jnp.where(act, a, o), os_new, os)
            return (p_new, os_new), l

        from repro.models.common import scan_unroll
        (p, os), losses = jax.lax.scan(
            step, (global_params, ostate),
            (batch_c, jnp.arange(K, dtype=jnp.int32)),
            unroll=scan_unroll())
        eta = (os.eta if isinstance(os, DeltaSGDState)
               and not isinstance(os.eta, dict)
               else jnp.asarray(jnp.nan, jnp.float32))
        return p, losses, eta

    def round_fn(state: FLState, client_batches, client_weights=None,
                 prev_local_params=None):
        """-> (new_state, metrics, new_local_params (C, ...))."""
        round_frac = state.round.astype(jnp.float32) / num_rounds
        gp = state.params
        C = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        K = jax.tree_util.tree_leaves(client_batches)[0].shape[1]
        step_counts = (scenario.draw_step_counts(state.round, C, K)
                       if hetero else None)
        new_locals, losses, etas = jax.vmap(
            one_client, in_axes=(None, None, 0,
                                 0 if prev_local_params is not None
                                 else None,
                                 0 if hetero else None)
        )(gp, round_frac, client_batches, prev_local_params, step_counts)

        if weighted and client_weights is not None:
            w = client_weights / jnp.sum(client_weights)
            agg = jax.tree.map(
                lambda x: jnp.tensordot(w.astype(jnp.float32),
                                        x.astype(jnp.float32), axes=(0, 0)
                                        ).astype(x.dtype), new_locals)
        else:
            agg = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0
                                   ).astype(x.dtype), new_locals)

        extra = _scenario_extras(scenario, state.round, C, num_clients,
                                 client_sizes, step_counts)
        if tele.enabled:
            # η may be NaN for non-Δ-SGD optimizers: NaN counts in no
            # histogram bin, so the eta_hist simply reads all-zero there
            extra.update(round_telemetry(tele, etas, losses))
        new_state, metrics = _finish_round(state, agg, losses, etas,
                                           server_opt,
                                           step_counts=step_counts,
                                           extra=extra)
        return new_state, metrics, new_locals

    return round_fn


def _make_flat_round(grad_fn, client_opt: ClientOpt, server_opt: ServerOpt,
                     *, num_rounds: int, weighted: bool, backend: str,
                     mesh=None, federation=None, scenario=None,
                     num_clients=None, client_sizes=None,
                     compression=None, telemetry=None):
    """Flat-parameter Δ-SGD engine: one packed (C, N) buffer carries every
    leaf of every client's params through the K-step scan; two fused
    kernel launches per local step total. With ``mesh``/``federation``
    the buffer additionally stays sharded per ``federation.flat_spec``
    for the whole round. With a ``scenario`` the K-step scan carries the
    per-client step-count lane mask, and async scenarios route the
    aggregate through the FedBuff delta buffer instead of the direct
    server update.

    Active ``compression`` (repro.compression) reshapes the round tail
    into the delta-communication form: Δ_c = x_c^K − x_t is compressed
    per client (optionally behind EF21 error feedback carried in
    ``FLState.ef``, and per-client bandwidth levels drawn by the
    scenario), and only the reconstructed Δ̂_c enters the aggregation —
    under meshes the compressors run shard-locally BEFORE the
    client-mean psum, so no full-precision per-client delta ever
    crosses a shard boundary. Wire-bytes / compression-ratio telemetry
    rides in the round metrics.

    The round logic lives in a flat-in/flat-out body working on
    ``repro.core.fed_loop.FlatFLState`` — the returned round_fn is a
    thin pack/unpack wrapper around it and additionally exposes it as
    ``round_fn.flat_body``, which is what the round-fused multi-round
    ``lax.scan`` (core/fed_loop.make_fl_loop) chains: fused and
    host-loop rounds are the same computation by construction."""
    from repro.telemetry.spec import resolve_telemetry, round_telemetry
    tele = resolve_telemetry(telemetry)
    hyper = client_opt.hyper
    if (client_opt.name != "delta_sgd" or hyper is None
            or hyper.get("groupwise")):
        raise ValueError("flat engine requires the global-rule delta_sgd "
                         f"client optimizer, got {client_opt.name!r}")
    gamma, delta = hyper["gamma"], hyper["delta"]
    eta0, theta0 = hyper["eta0"], hyper["theta0"]

    hetero = scenario is not None and scenario.heterogeneous
    is_async = scenario is not None and scenario.is_async
    bw_hetero = scenario is not None and scenario.bandwidth_heterogeneous
    comp = compression if (compression is not None
                           and compression.active(scenario)) else None
    use_ef = comp is not None and comp.error_feedback

    # fault / robustness axis (repro.federation.faults). All trace-time
    # flags: with everything off, every branch below is the exact legacy
    # code path, so the fault-free mean configuration stays bit-exact
    # against the golden trajectories by construction.
    fm = scenario.fault_model if scenario is not None else None
    faults_on = fm is not None and fm.active
    ragg = scenario.robust_model if scenario is not None else None
    robust_on = ragg is not None and ragg.robust
    quorum = scenario.quorum if scenario is not None else 0
    guard_tail = faults_on or robust_on or quorum > 0

    sharded = mesh is not None
    if sharded:
        from jax.sharding import NamedSharding, PartitionSpec as PS
        pspec = federation.flat_spec(mesh)          # (C, N) buffers
        cspec = federation.flat_client_spec(mesh)   # (C,) vectors
        nspec = PS(pspec[1])                        # (N,) buffers
        shards = federation.flat_shards(mesh)

        def constrain(x, ps):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, ps))
    else:
        shards = 1

        def constrain(x, ps):
            return x

        pspec = cspec = nspec = None

    def flat_step(P, G, S, mask, active, eta0_step=None):
        """``eta0_step`` optionally overrides the scalar η₀ with a (C,)
        per-client vector (the fleet arena's Δ-SGD warm-start carry);
        the first-step rule broadcasts either form identically."""
        e0 = eta0 if eta0_step is None else eta0_step
        if sharded:
            return flat_delta_sgd_step_sharded(
                P, G, S, gamma=gamma, delta=delta, eta0=eta0, mesh=mesh,
                pspec=pspec, mask=mask, active=active, backend=backend)
        return flat_delta_sgd_step(P, G, S, gamma=gamma, delta=delta,
                                   eta0=e0, mask=mask, active=active,
                                   backend=backend)

    def flat_body(fstate, client_batches, layout, client_weights=None,
                  prev_local_params=None, gp=None, eta0_c=None):
        """One round on flat-form state (core.fed_loop.FlatFLState) ->
        (new_fstate, metrics, RoundAux). ``gp`` optionally passes
        the global params pytree when the caller still has it (the
        per-round wrapper); the fused loop leaves it None and the body
        reconstructs the views from the carried flat buffer. ``eta0_c``
        optionally replaces the scalar round-start η₀ with a (C,)
        per-client vector (the fleet loop's ``eta_carry`` warm start —
        non-sharded engines only)."""
        from repro.core.fed_loop import FlatFLState
        if eta0_c is not None and sharded:
            raise ValueError("per-client eta0 warm start (eta0_c) is not "
                             "supported on the per-round sharded engine — "
                             "the fleet loop runs un-meshed")
        if gp is None:
            gp = flatlib.unpack(fstate.P, layout)

        def pack1(tree):
            """Pytree -> (N,) f32 for the flat carry. The 1-D packed
            concatenate stays UNCONSTRAINED: explicitly constraining it
            (or routing through a batch-1 2-D concat) trips the XLA CPU
            SPMD mis-partitioning (stride-shuffled buffer, jax<=0.4.37)
            the round-start broadcast's comment documents; the plain
            concat round-trips correctly under the mesh."""
            return flatlib.pack(tree, layout)
        mask = flatlib.round_mask(layout)
        if mask is not None:
            mask = constrain(mask, nspec)
        leaves = jax.tree_util.tree_leaves(client_batches)
        C, K = leaves[0].shape[0], leaves[0].shape[1]
        # scenario draws are constrained REPLICATED, not client-sharded:
        # with jax_threefry_partitionable=False a partitioned threefry
        # yields different bits per shard, which would make the sharded
        # round disagree with the replicated engine and the host
        # pipeline. The (C,) vectors are tiny; resharding at the
        # shard_map boundary is free.
        from jax.sharding import PartitionSpec as _PS
        rep = (lambda x: constrain(x, _PS())) if sharded else (lambda x: x)
        step_counts = (rep(scenario.draw_step_counts(fstate.round, C, K))
                       if hetero else None)
        # fault lanes (repro.federation.faults): one deterministic draw
        # per round off axis 4 of the round key, replicated like every
        # other scenario draw. Drops fold into the SAME per-step lane
        # mask heterogeneous K uses — a dropped client simply runs out
        # of budget at its drop step — so the scan stays fixed-shape and
        # the step stays at two kernel launches.
        lanes = (jax.tree.map(rep, scenario.draw_faults(fstate.round, C, K))
                 if faults_on else None)
        drops_on = faults_on and fm.drop_rate > 0.0
        if drops_on:
            budget = (jnp.minimum(step_counts, lanes.drop_step)
                      if hetero else lanes.drop_step)
            # loss metrics mask on the effective budget; clamp ≥ 1 so a
            # step-0 drop (K=1) still indexes a defined "last step"
            mcounts = jnp.maximum(budget, 1)
        else:
            budget = mcounts = step_counts

        # broadcast the round-start params to the client axis; the carry
        # is already flat, so no per-round pytree re-pack happens here
        if sharded:
            # broadcast leaves FIRST, then pack via the 2-D batched
            # concatenate: constraining a 1-D packed concatenate trips an
            # XLA CPU SPMD mis-partitioning (stride-shuffled buffer,
            # jax<=0.4.37); the (C, N) axis-1 concatenate partitions
            # correctly and is what the round materializes anyway.
            bcast = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (C,) + l.shape), gp)
            P = constrain(flatlib.pack_batched(bcast, layout), pspec)
        else:
            P = jnp.broadcast_to(fstate.P[None], (C, layout.padded_size))
        P_start = P if (is_async or comp is not None or guard_tail) \
            else None
        S = flat_delta_sgd_init(C, layout, eta0=eta0, theta0=theta0)
        if sharded:
            S = S._replace(prev_grads=constrain(S.prev_grads, pspec),
                           eta=constrain(S.eta, cspec),
                           theta=constrain(S.theta, cspec),
                           prev_grad_norm=constrain(S.prev_grad_norm,
                                                    cspec),
                           valid=constrain(S.valid, cspec),
                           clips=constrain(S.clips, cspec))

        # scan over local steps: batches (C, K, ...) -> (K, C, ...)
        batches_t = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1),
                                 client_batches)

        def step(carry, inp):
            batch_k, k_idx = inp
            P, S = carry
            params_c = flatlib.unpack_batched(P, layout)
            (l, _), g = jax.vmap(
                grad_fn, in_axes=(0, 0, None,
                                  0 if prev_local_params is not None
                                  else None)
            )(params_c, batch_k, gp, prev_local_params)
            G = constrain(flatlib.pack_batched(g, layout), pspec)
            if faults_on and fm.nan_rate > 0.0:
                # NaN/Inf gradient corruption: from the drawn step on,
                # the client's packed lanes go non-finite. Injected on
                # the WIRE side of the guard — the in-step guard must
                # catch it (valid latches off, η=0, lane sanitized).
                bad = k_idx >= lanes.nan_step
                G = constrain(jnp.where(bad[:, None],
                                        jnp.float32(jnp.nan), G), pspec)
            active = (k_idx < budget) if budget is not None else None
            P, S = flat_step(P, G, S, mask, active, eta0_c)
            return (P, S), l

        from repro.models.common import scan_unroll
        (P, S), losses = jax.lax.scan(
            step, (P, S), (batches_t, jnp.arange(K, dtype=jnp.int32)),
            unroll=scan_unroll())
        losses = losses.T  # (K, C) -> (C, K), same layout as vmap engine

        extra = _scenario_extras(scenario, fstate.round, C, num_clients,
                                 client_sizes, step_counts, rep=rep)
        # numerical-guard telemetry (always on for the flat engines):
        # how often η hit the ETA_CLAMP ceiling, and what fraction of
        # lanes the NaN guard dropped this round
        extra.update(
            eta_clip_rate=(jnp.sum(S.clips.astype(jnp.float32))
                           / jnp.float32(C * K)),
            nan_guard_rate=jnp.mean((~S.valid).astype(jnp.float32)))
        if tele.enabled:
            # in-scan distribution block (repro.telemetry): read-only
            # over round-end values, so the trajectory is unperturbed.
            # The Pallas kernels only run on the un-meshed pallas
            # engine; meshed/pjit rounds use the jnp ref math (sharding
            # constraints inside pallas_call don't compose), and the
            # counts are exact integers either way.
            extra.update(round_telemetry(
                tele, S.eta, losses, S.clips, S.valid, backend=backend,
                use_kernel=(backend == "pallas" and not sharded),
                rep=rep))

        # survivor mask + byzantine factor for the fault/robust tails:
        # a client is excluded when its NaN guard latched, it dropped
        # mid-round, or (async, below) its update arrived over-stale
        byz = valid = None
        if guard_tail:
            valid = S.valid
            if drops_on:
                valid = valid & (lanes.drop_step >= K)
            if faults_on and fm.byzantine_rate > 0.0:
                byz = jnp.where(lanes.byzantine,
                                jnp.float32(fm.byzantine_scale),
                                jnp.float32(1.0))

        # delta compression (repro.compression): compress each client's
        # round delta before ANY aggregation — only the reconstructed
        # Δ̂_c (and, under meshes, the post-mean (N,) aggregate) exists
        # past this point. EF21: the client ships C(Δ_c − g_c) and both
        # sides roll g_c ← g_c + C(Δ_c − g_c), so Δ̂_c = new g_c and the
        # compression error does not accumulate across rounds.
        new_ef = None
        if comp is not None:
            from repro.compression.ops import (compress_flat,
                                               compress_flat_sharded)
            levels = (rep(scenario.draw_compression_levels(fstate.round, C))
                      if bw_hetero else None)
            delta = P - P_start
            if byz is not None:
                # byzantine corruption happens CLIENT-side, before the
                # (honest) compression transport — the server only ever
                # sees the reconstructed corrupted delta
                delta = delta * byz[:, None]
            if use_ef:
                if fstate.ef is None:
                    raise ValueError(
                        "error-feedback compression needs FLState.ef — "
                        "allocate it via init_fl_state(..., compression="
                        "spec, cohort=C)")
                E = fstate.ef
                if sharded:
                    E = constrain(E, pspec)
                resid = delta - E
            else:
                E, resid = None, delta
            if sharded:
                chat = compress_flat_sharded(resid, comp, mesh=mesh,
                                             pspec=pspec, levels=levels,
                                             backend=backend)
            else:
                chat = compress_flat(resid, comp, levels=levels,
                                     backend=backend)
            delta_hat = (E + chat) if E is not None else chat
            if sharded:
                delta_hat = constrain(delta_hat, pspec)
            if use_ef:
                new_ef = delta_hat      # (C, N) flat — the EF21 carry
            # wire accounting over the VALID elements (layout.size):
            # tail padding never ships, so sharded and replicated
            # layouts (different padded_size) report identical bytes
            wire = comp.wire_bytes(layout.size, levels=levels,
                                   num_clients=C)
            extra.update(
                wire_bytes=jnp.sum(wire),
                comp_ratio=(4.0 * layout.size * C) / jnp.sum(wire))
            if levels is not None:
                extra["comp_level_mean"] = jnp.mean(
                    levels.astype(jnp.float32))
            # what the server aggregates: round-start params + the
            # reconstructed deltas (≡ P exactly when the spec is inert —
            # inert specs never reach this branch)
            P_agg = P_start + delta_hat
        else:
            delta_hat = None
            P_agg = P

        if not is_async and not guard_tail:
            # aggregate: single (weighted) mean over the packed client
            # axis — under the sharded engine XLA lowers this to the
            # FedAvg all-reduce over the client mesh axes; the (N,)
            # result keeps the flat-dim sharding.
            if weighted and client_weights is not None:
                w = client_weights / jnp.sum(client_weights)
                agg_flat = jnp.tensordot(w.astype(jnp.float32), P_agg,
                                         axes=(0, 0))
            else:
                agg_flat = jnp.mean(P_agg, axis=0)
            agg = flatlib.unpack(constrain(agg_flat, nspec), layout)
            new_params, sstate = server_opt.update(gp, agg,
                                                   fstate.server_state)
            metrics = _round_metrics(losses, S.eta, step_counts)
            metrics.update(extra)
            new_fstate = FlatFLState(
                pack1(new_params), sstate, fstate.round + 1,
                fstate.buffer, fstate.ef if new_ef is None else new_ef)
        elif not is_async:
            # fault/robust synchronous tail: the server works in DELTA
            # space — the RobustAgg ladder (repro.federation.faults)
            # aggregates the survivors' deltas (clip / trimmed / median /
            # valid-masked mean) and the result re-anchors on the round-
            # start params. Under meshes the ladder runs inside
            # shard_map before/with the client-mean psum, so only (N_loc,)
            # aggregates ever cross the client shard boundary.
            from repro.federation.faults import (robust_aggregate,
                                                 robust_aggregate_sharded)
            delta_eff = delta_hat if comp is not None else (P - P_start)
            if byz is not None and comp is None:
                delta_eff = delta_eff * byz[:, None]
            w_raw = (client_weights.astype(jnp.float32)
                     if weighted and client_weights is not None else None)
            if sharded:
                agg_delta, rinfo = robust_aggregate_sharded(
                    delta_eff, ragg, valid, mesh=mesh, pspec=pspec,
                    weights=w_raw)
            else:
                agg_delta, rinfo = robust_aggregate(
                    delta_eff, ragg, valid, weights=w_raw,
                    backend=backend)
            n_valid = jnp.sum(valid.astype(jnp.float32))
            # round-start flat params: the replicated engines carry them
            # exactly in the flat state; sharded re-derives them from the
            # (identical-row) broadcast buffer to stay on nspec sharding
            P0 = (constrain(jnp.mean(P_start, axis=0), nspec)
                  if sharded else fstate.P)
            agg = flatlib.unpack(constrain(P0 + agg_delta, nspec), layout)

            def do_update(_):
                p, s = server_opt.update(gp, agg, fstate.server_state)
                return pack1(p), s

            def skip_update(_):
                return fstate.P, fstate.server_state

            if quorum > 0:
                # quorum degradation: with < Q valid clients the round
                # is a no-op carrying the previous params/server state
                skipped = n_valid < quorum
                newP, sstate = jax.lax.cond(skipped, skip_update,
                                            do_update, None)
                if new_ef is not None:
                    new_ef = jnp.where(skipped, E, new_ef)
            else:
                skipped = jnp.asarray(False)
                newP, sstate = do_update(None)
            metrics = _round_metrics(losses, S.eta, mcounts)
            extra.update(rinfo)
            extra.update(valid_count=n_valid,
                         round_skipped=skipped.astype(jnp.float32))
            if drops_on:
                extra["drop_frac"] = jnp.mean(
                    (lanes.drop_step < K).astype(jnp.float32))
            if byz is not None:
                extra["byz_frac"] = jnp.mean(
                    lanes.byzantine.astype(jnp.float32))
            metrics.update(extra)
            new_fstate = FlatFLState(
                newP, sstate, fstate.round + 1, fstate.buffer,
                fstate.ef if new_ef is None else new_ef)
        elif not guard_tail:
            # FedBuff-style async aggregation: one staleness-weighted
            # reduction over the packed client axis produces the cohort's
            # delta sum; the server only steps when the buffer holds M
            # updates (repro.federation.buffer). The buffer keeps its
            # param-shaped f32 delta tree (layout-independent, and the
            # known-good form under SPMD meshes); only the params
            # re-enter the flat carry.
            from repro.federation.buffer import (buffer_merge, buffer_step,
                                                 staleness_weights)
            stale = rep(scenario.draw_staleness(fstate.round, C))
            w = staleness_weights(stale, scenario.staleness_exp)
            if weighted and client_weights is not None:
                w = w * client_weights.astype(jnp.float32)
            delta_flat = jnp.tensordot(
                w, delta_hat if comp is not None else (P - P_start),
                axes=(0, 0))
            delta_tree = flatlib.unpack(constrain(delta_flat, nspec),
                                        layout, cast=False)
            buf = buffer_merge(fstate.buffer, delta_tree, jnp.sum(w), C,
                               stale)
            params, sstate, buf, flushed = buffer_step(
                gp, fstate.server_state, buf, server_opt,
                scenario.buffer_size)
            metrics = _round_metrics(losses, S.eta, step_counts)
            sf = stale.astype(jnp.float32)
            extra.update(stale_mean=jnp.mean(sf), stale_max=jnp.max(sf),
                         buffer_fill=buf.count.astype(jnp.float32),
                         flushed=flushed)
            metrics.update(extra)
            new_fstate = FlatFLState(pack1(params), sstate,
                                     fstate.round + 1, buf,
                                     fstate.ef if new_ef is None else new_ef)
        else:
            # fault/robust async tail: over-stale updates are REJECTED
            # by the server (valid &= fresh enough), the RobustAgg
            # ladder aggregates the survivors' deltas, and the buffer
            # accumulates the robust mean scaled back to Σ wΔ form so
            # the flush's Σ wΔ / Σ w recovers it. Quorum failures skip
            # the merge entirely (buffer, params, server state frozen).
            from repro.federation.buffer import (buffer_merge, buffer_step,
                                                 staleness_weights)
            from repro.federation.faults import (robust_aggregate,
                                                 robust_aggregate_sharded)
            stale = rep(scenario.draw_staleness(fstate.round, C))
            if faults_on and fm.overstale_rate > 0.0:
                stale = jnp.where(lanes.overstale,
                                  jnp.int32(fm.overstale), stale)
            valid = valid & (stale <= scenario.staleness_max)
            w = staleness_weights(stale, scenario.staleness_exp)
            if weighted and client_weights is not None:
                w = w * client_weights.astype(jnp.float32)
            d = delta_hat if comp is not None else (P - P_start)
            if byz is not None and comp is None:
                d = d * byz[:, None]
            if sharded:
                rob, rinfo = robust_aggregate_sharded(
                    d, ragg, valid, mesh=mesh, pspec=pspec, weights=w)
            else:
                rob, rinfo = robust_aggregate(d, ragg, valid, weights=w,
                                              backend=backend)
            vf = valid.astype(jnp.float32)
            wsum = jnp.sum(w * vf)
            n_valid = jnp.sum(vf)
            delta_flat = rob * wsum
            delta_tree = flatlib.unpack(constrain(delta_flat, nspec),
                                        layout, cast=False)

            def do_round(_):
                buf = buffer_merge(fstate.buffer, delta_tree, wsum,
                                   n_valid.astype(jnp.int32), stale)
                params, sstate, buf, flushed = buffer_step(
                    gp, fstate.server_state, buf, server_opt,
                    scenario.buffer_size)
                return pack1(params), sstate, buf, flushed

            def skip_round(_):
                return (fstate.P, fstate.server_state, fstate.buffer,
                        jnp.float32(0.0))

            if quorum > 0:
                skipped = n_valid < quorum
                newP, sstate, buf, flushed = jax.lax.cond(
                    skipped, skip_round, do_round, None)
                if new_ef is not None:
                    new_ef = jnp.where(skipped, E, new_ef)
            else:
                skipped = jnp.asarray(False)
                newP, sstate, buf, flushed = do_round(None)
            metrics = _round_metrics(losses, S.eta, mcounts)
            sf = stale.astype(jnp.float32)
            extra.update(stale_mean=jnp.mean(sf), stale_max=jnp.max(sf),
                         buffer_fill=buf.count.astype(jnp.float32),
                         flushed=flushed)
            extra.update(rinfo)
            extra.update(valid_count=n_valid,
                         round_skipped=skipped.astype(jnp.float32))
            if drops_on:
                extra["drop_frac"] = jnp.mean(
                    (lanes.drop_step < K).astype(jnp.float32))
            if byz is not None:
                extra["byz_frac"] = jnp.mean(
                    lanes.byzantine.astype(jnp.float32))
            if faults_on and fm.overstale_rate > 0.0:
                extra["overstale_frac"] = jnp.mean(
                    lanes.overstale.astype(jnp.float32))
            metrics.update(extra)
            new_fstate = FlatFLState(newP, sstate, fstate.round + 1, buf,
                                     fstate.ef if new_ef is None else new_ef)

        return new_fstate, metrics, RoundAux(P, S.eta, S.valid)

    def round_fn(state: FLState, client_batches, client_weights=None,
                 prev_local_params=None):
        """-> (new_state, metrics, new_local_params (C, ...))."""
        from repro.core.fed_loop import (flatten_fl_state,
                                         unflatten_fl_state)
        layout = flatlib.layout_of(state.params, shards=shards)
        fstate = flatten_fl_state(state, layout)
        new_fstate, metrics, aux = flat_body(
            fstate, client_batches, layout, client_weights=client_weights,
            prev_local_params=prev_local_params, gp=state.params)
        new_state = unflatten_fl_state(new_fstate, layout)
        new_locals = flatlib.unpack_batched(aux.P_locals, layout)
        return new_state, metrics, new_locals

    round_fn.flat_body = flat_body
    return round_fn
