"""One jitted federated round (Algorithm 1, full loop body).

Communication pattern, expressed jax-natively:
  * the |S_t| participating clients form a leading pytree axis C, sharded
    over the mesh's client axes (FederationSpec);
  * each client runs K local steps (lax.scan) of its ClientOpt from the
    common round-start params (vmap over C — params broadcast);
  * server aggregation is a (weighted) mean over C — XLA lowers it to an
    all-reduce over the client mesh axes, i.e. the FedAvg collective;
  * the ServerOpt (FedAvg/FedAdam/...) finishes the round.

Batch layout: every leaf of ``client_batches`` is (C, K, ...) — K per-step
micro-batches of the client's *local* data.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.client_opt import ClientOpt
from repro.core.delta_sgd import DeltaSGDState
from repro.core.server_opt import ServerOpt


class FLState(NamedTuple):
    params: Any
    server_state: Any
    round: jax.Array


def init_fl_state(params, server_opt: ServerOpt) -> FLState:
    return FLState(params, server_opt.init(params),
                   jnp.asarray(0, jnp.int32))


def make_fl_round(loss_fn, client_opt: ClientOpt, server_opt: ServerOpt, *,
                  num_rounds: int, weighted: bool = False):
    """loss_fn(params, batch, global_params, prev_params)->(loss, metrics).

    Returns round_fn(state, client_batches, client_weights=None,
                     prev_local_params=None) -> (state, metrics).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_client(global_params, round_frac, batch_c, prev_c):
        ostate = client_opt.reset(client_opt.init(global_params), round_frac)

        def step(carry, b):
            p, os = carry
            (l, _), g = grad_fn(p, b, global_params, prev_c)
            p, os = client_opt.update(p, g, os, l)
            return (p, os), l

        from repro.models.common import scan_unroll
        (p, os), losses = jax.lax.scan(step, (global_params, ostate),
                                       batch_c, unroll=scan_unroll())
        eta = (os.eta if isinstance(os, DeltaSGDState)
               and not isinstance(os.eta, dict) else jnp.asarray(0.0))
        return p, losses, eta

    def round_fn(state: FLState, client_batches, client_weights=None,
                 prev_local_params=None):
        """-> (new_state, metrics, new_local_params (C, ...))."""
        round_frac = state.round.astype(jnp.float32) / num_rounds
        gp = state.params
        new_locals, losses, etas = jax.vmap(
            one_client, in_axes=(None, None, 0,
                                 0 if prev_local_params is not None
                                 else None)
        )(gp, round_frac, client_batches, prev_local_params)

        if weighted and client_weights is not None:
            w = client_weights / jnp.sum(client_weights)
            agg = jax.tree.map(
                lambda x: jnp.tensordot(w.astype(jnp.float32),
                                        x.astype(jnp.float32), axes=(0, 0)
                                        ).astype(x.dtype), new_locals)
        else:
            agg = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0
                                   ).astype(x.dtype), new_locals)

        params, sstate = server_opt.update(gp, agg, state.server_state)
        metrics = {"loss": jnp.mean(losses),
                   "loss_last_step": jnp.mean(losses[:, -1]),
                   "eta_mean": jnp.mean(etas)}
        return FLState(params, sstate, state.round + 1), metrics, new_locals

    return round_fn
