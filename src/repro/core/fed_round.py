"""One jitted federated round (Algorithm 1, full loop body).

Communication pattern, expressed jax-natively:
  * the |S_t| participating clients form a leading pytree axis C, sharded
    over the mesh's client axes (FederationSpec);
  * each client runs K local steps (lax.scan) of its ClientOpt from the
    common round-start params (vmap over C — params broadcast);
  * server aggregation is a (weighted) mean over C — XLA lowers it to an
    all-reduce over the client mesh axes, i.e. the FedAvg collective;
  * the ServerOpt (FedAvg/FedAdam/...) finishes the round.

Batch layout: every leaf of ``client_batches`` is (C, K, ...) — K per-step
micro-batches of the client's *local* data.

Flat engine (``flat=`` argument, Δ-SGD only): instead of vmapping the
optimizer over C, the param pytree is packed ONCE at round start into a
lane-aligned flat buffer broadcast to (C, N) (repro.core.flat), the
K-step scan runs entirely on flat buffers — per step: one vmapped grad
eval on the unpacked view, then exactly two fused kernel launches
(batched norms + batched apply) for all leaves and all clients —
aggregation is a single mean over the packed C axis, and the result is
unpacked once at round end. ``flat="pallas"``/``True`` uses the batched
Pallas kernels, ``flat="xla"`` the same math as fused jnp ops (for
meshed/pjit callers).

Sharded flat engine (``mesh=`` + ``federation=`` arguments): the packed
(C, N) buffer is mesh-sharded end to end per
``FederationSpec.flat_spec(mesh)`` — clients over the client axes, N over
the fsdp/tp axes, with a per-shard padded layout
(``layout_of(..., shards=...)``) so every device's slab stays
lane-aligned. Pack/unpack run under ``with_sharding_constraint``, the
per-step kernel pair runs inside ``shard_map`` with a psum dual-norm
reduction (repro.core.delta_sgd.flat_delta_sgd_step_sharded), and the
round-end aggregation is a sharded mean over the client axes. The caller
must jit the returned round_fn (sharding constraints require a jit
context).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as flatlib
from repro.core.client_opt import ClientOpt
from repro.core.delta_sgd import (DeltaSGDState, flat_delta_sgd_init,
                                  flat_delta_sgd_step,
                                  flat_delta_sgd_step_sharded)
from repro.core.server_opt import ServerOpt


class FLState(NamedTuple):
    params: Any
    server_state: Any
    round: jax.Array


def init_fl_state(params, server_opt: ServerOpt) -> FLState:
    return FLState(params, server_opt.init(params),
                   jnp.asarray(0, jnp.int32))


def _finish_round(state: FLState, agg, losses, etas,
                  server_opt: ServerOpt):
    """Shared round tail for both engines: server update + metrics.

    ``losses`` is (C, K); ``etas`` is (C,) with NaN for clients whose
    optimizer has no scalar step-size state (non-Δ-SGD, groupwise)."""
    params, sstate = server_opt.update(state.params, agg,
                                       state.server_state)
    metrics = {"loss": jnp.mean(losses),
               "loss_last_step": jnp.mean(losses[:, -1]),
               "eta_mean": jnp.mean(etas),
               "eta_min": jnp.min(etas),
               "eta_max": jnp.max(etas)}
    return FLState(params, sstate, state.round + 1), metrics


def make_fl_round(loss_fn, client_opt: ClientOpt, server_opt: ServerOpt, *,
                  num_rounds: int, weighted: bool = False,
                  flat=False, mesh=None, federation=None):
    """loss_fn(params, batch, global_params, prev_params)->(loss, metrics).

    Returns round_fn(state, client_batches, client_weights=None,
                     prev_local_params=None) -> (state, metrics).

    ``flat``: False (vmap engine), True/"pallas", or "xla" — the packed
    flat-buffer Δ-SGD engine (requires client_opt "delta_sgd", global
    rule).

    ``mesh`` + ``federation`` (FederationSpec): flat engine only — keep
    the packed (C, N) buffer sharded per ``federation.flat_spec(mesh)``
    for the whole round (see module docstring). Both or neither.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if (mesh is None) != (federation is None):
        raise ValueError("mesh and federation must be given together")
    if mesh is not None and not flat:
        raise ValueError("mesh/federation sharding requires the flat "
                         "engine (flat=...)")

    if flat:
        return _make_flat_round(grad_fn, client_opt, server_opt,
                                num_rounds=num_rounds, weighted=weighted,
                                backend="xla" if flat == "xla" else "pallas",
                                mesh=mesh, federation=federation)

    def one_client(global_params, round_frac, batch_c, prev_c):
        ostate = client_opt.reset(client_opt.init(global_params), round_frac)

        def step(carry, b):
            p, os = carry
            (l, _), g = grad_fn(p, b, global_params, prev_c)
            p, os = client_opt.update(p, g, os, l)
            return (p, os), l

        from repro.models.common import scan_unroll
        (p, os), losses = jax.lax.scan(step, (global_params, ostate),
                                       batch_c, unroll=scan_unroll())
        eta = (os.eta if isinstance(os, DeltaSGDState)
               and not isinstance(os.eta, dict)
               else jnp.asarray(jnp.nan, jnp.float32))
        return p, losses, eta

    def round_fn(state: FLState, client_batches, client_weights=None,
                 prev_local_params=None):
        """-> (new_state, metrics, new_local_params (C, ...))."""
        round_frac = state.round.astype(jnp.float32) / num_rounds
        gp = state.params
        new_locals, losses, etas = jax.vmap(
            one_client, in_axes=(None, None, 0,
                                 0 if prev_local_params is not None
                                 else None)
        )(gp, round_frac, client_batches, prev_local_params)

        if weighted and client_weights is not None:
            w = client_weights / jnp.sum(client_weights)
            agg = jax.tree.map(
                lambda x: jnp.tensordot(w.astype(jnp.float32),
                                        x.astype(jnp.float32), axes=(0, 0)
                                        ).astype(x.dtype), new_locals)
        else:
            agg = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0
                                   ).astype(x.dtype), new_locals)

        new_state, metrics = _finish_round(state, agg, losses, etas,
                                           server_opt)
        return new_state, metrics, new_locals

    return round_fn


def _make_flat_round(grad_fn, client_opt: ClientOpt, server_opt: ServerOpt,
                     *, num_rounds: int, weighted: bool, backend: str,
                     mesh=None, federation=None):
    """Flat-parameter Δ-SGD engine: one packed (C, N) buffer carries every
    leaf of every client's params through the K-step scan; two fused
    kernel launches per local step total. With ``mesh``/``federation``
    the buffer additionally stays sharded per ``federation.flat_spec``
    for the whole round."""
    hyper = client_opt.hyper
    if (client_opt.name != "delta_sgd" or hyper is None
            or hyper.get("groupwise")):
        raise ValueError("flat engine requires the global-rule delta_sgd "
                         f"client optimizer, got {client_opt.name!r}")
    gamma, delta = hyper["gamma"], hyper["delta"]
    eta0, theta0 = hyper["eta0"], hyper["theta0"]

    sharded = mesh is not None
    if sharded:
        from jax.sharding import NamedSharding, PartitionSpec as PS
        pspec = federation.flat_spec(mesh)          # (C, N) buffers
        cspec = federation.flat_client_spec(mesh)   # (C,) vectors
        nspec = PS(pspec[1])                        # (N,) buffers
        shards = federation.flat_shards(mesh)

        def constrain(x, ps):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, ps))
    else:
        shards = 1

        def constrain(x, ps):
            return x

        pspec = cspec = nspec = None

    def flat_step(P, G, S, mask):
        if sharded:
            return flat_delta_sgd_step_sharded(
                P, G, S, gamma=gamma, delta=delta, eta0=eta0, mesh=mesh,
                pspec=pspec, mask=mask, backend=backend)
        return flat_delta_sgd_step(P, G, S, gamma=gamma, delta=delta,
                                   eta0=eta0, mask=mask, backend=backend)

    def round_fn(state: FLState, client_batches, client_weights=None,
                 prev_local_params=None):
        """-> (new_state, metrics, new_local_params (C, ...))."""
        gp = state.params
        layout = flatlib.layout_of(gp, shards=shards)
        mask = flatlib.round_mask(layout)
        if mask is not None:
            mask = constrain(mask, nspec)
        C = jax.tree_util.tree_leaves(client_batches)[0].shape[0]

        # pack once at round start; clients all start from the global params
        if sharded:
            # broadcast leaves FIRST, then pack via the 2-D batched
            # concatenate: constraining a 1-D packed concatenate trips an
            # XLA CPU SPMD mis-partitioning (stride-shuffled buffer,
            # jax<=0.4.37); the (C, N) axis-1 concatenate partitions
            # correctly and is what the round materializes anyway.
            bcast = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (C,) + l.shape), gp)
            P = constrain(flatlib.pack_batched(bcast, layout), pspec)
        else:
            P = jnp.broadcast_to(flatlib.pack(gp, layout)[None],
                                 (C, layout.padded_size))
        S = flat_delta_sgd_init(C, layout, eta0=eta0, theta0=theta0)
        if sharded:
            S = S._replace(prev_grads=constrain(S.prev_grads, pspec),
                           eta=constrain(S.eta, cspec),
                           theta=constrain(S.theta, cspec),
                           prev_grad_norm=constrain(S.prev_grad_norm,
                                                    cspec))

        # scan over local steps: batches (C, K, ...) -> (K, C, ...)
        batches_t = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1),
                                 client_batches)

        def step(carry, batch_k):
            P, S = carry
            params_c = flatlib.unpack_batched(P, layout)
            (l, _), g = jax.vmap(
                grad_fn, in_axes=(0, 0, None,
                                  0 if prev_local_params is not None
                                  else None)
            )(params_c, batch_k, gp, prev_local_params)
            G = constrain(flatlib.pack_batched(g, layout), pspec)
            P, S = flat_step(P, G, S, mask)
            return (P, S), l

        from repro.models.common import scan_unroll
        (P, S), losses = jax.lax.scan(step, (P, S), batches_t,
                                      unroll=scan_unroll())
        losses = losses.T  # (K, C) -> (C, K), same layout as vmap engine

        # aggregate: single (weighted) mean over the packed client axis —
        # under the sharded engine XLA lowers this to the FedAvg
        # all-reduce over the client mesh axes; the (N,) result keeps the
        # flat-dim sharding.
        if weighted and client_weights is not None:
            w = client_weights / jnp.sum(client_weights)
            agg_flat = jnp.tensordot(w.astype(jnp.float32), P, axes=(0, 0))
        else:
            agg_flat = jnp.mean(P, axis=0)
        agg = flatlib.unpack(constrain(agg_flat, nspec), layout)

        new_state, metrics = _finish_round(state, agg, losses, S.eta,
                                           server_opt)
        new_locals = flatlib.unpack_batched(P, layout)
        return new_state, metrics, new_locals

    return round_fn
