"""Server-side aggregation optimizers (Reddi et al. 2021 meta-algorithm).

update(global_params, client_mean, state) -> (new_params, state)

FedAvg     : x ← mean_i x_i^K                      (paper's main setting)
FedAvgM    : server momentum on Δ = mean − x
FedAdam    : Adam on pseudo-gradient −Δ
FedYogi    : Yogi on pseudo-gradient −Δ

Δ-SGD is orthogonal to all of these (paper §2, Appendix B.4).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class ServerOpt(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def fedavg() -> ServerOpt:
    return ServerOpt("fedavg",
                     lambda params: {},
                     lambda params, mean, state: (mean, state))


def fedavgm(lr: float = 1.0, momentum: float = 0.9) -> ServerOpt:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(params, mean, state):
        delta = jax.tree.map(lambda a, b: a - b, mean, params)
        m = jax.tree.map(lambda m_, d: momentum * m_ + d, state["m"], delta)
        new = jax.tree.map(
            lambda p, m_: (p.astype(jnp.float32)
                           + lr * m_.astype(jnp.float32)).astype(p.dtype),
            params, m)
        return new, {"m": m}

    return ServerOpt("fedavgm", init, update)


def _adaptive(name, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, yogi=False):
    def init(params):
        # moments live in f32 regardless of param dtype: update()
        # computes them from the f32-cast delta, so a zeros_like init
        # on a bf16 leaf would change dtype after the first update —
        # a trace-time type mismatch in every lax.cond/scan carry
        # (async buffer flush, fused loop) on mixed-dtype trees.
        def f32z(p):
            return jnp.zeros(jnp.shape(p), jnp.float32)
        return {"m": jax.tree.map(f32z, params),
                "v": jax.tree.map(f32z, params),
                "t": jnp.asarray(0, jnp.int32)}

    def update(params, mean, state):
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                             mean, params)
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d,
                         state["m"], delta)
        if yogi:
            v = jax.tree.map(
                lambda v_, d: v_ - (1 - b2) * jnp.square(d)
                * jnp.sign(v_ - jnp.square(d)), state["v"], delta)
        else:
            v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * jnp.square(d),
                             state["v"], delta)
        tf = t.astype(jnp.float32)
        bc1, bc2 = 1 - b1 ** tf, 1 - b2 ** tf
        new = jax.tree.map(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               + lr * (m_ / bc1)
                               / (jnp.sqrt(jnp.abs(v_) / bc2) + eps)
                               ).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return ServerOpt(name, init, update)


def fedadam(lr: float = 1e-3) -> ServerOpt:
    return _adaptive("fedadam", lr=lr)


def fedyogi(lr: float = 1e-3) -> ServerOpt:
    return _adaptive("fedyogi", lr=lr, yogi=True)


def get_server_opt(name: str, **kw) -> ServerOpt:
    return {"fedavg": fedavg, "fedavgm": fedavgm, "fedadam": fedadam,
            "fedyogi": fedyogi}[name](**kw)


SERVER_OPTS = ("fedavg", "fedavgm", "fedadam", "fedyogi")
