"""Δ-SGD (DELTA-SGD): the paper's contribution. Eq. (4) + Algorithm 1.

    η_{t,k}^i = min( γ·‖x_k − x_{k−1}‖ / (2‖∇̃f_i(x_k) − ∇̃f_i(x_{k−1})‖),
                     sqrt(1 + δ·θ_{k−1})·η_{k−1} )
    θ_k = η_k / η_{k−1}

Implementation notes:
  * For plain SGD updates, ‖x_k − x_{k−1}‖ = η_{k−1}·‖g_{k−1}‖ exactly, so
    the state carries only the previous gradient (plus η, θ) — one extra
    param-sized buffer, matching the paper's memory claim (vs AdaAlter's 2×).
  * The previous gradient is *reused* for the step-size (paper §3: "we use
    the same batches to prevent additional gradient evaluations").
  * η₀, θ₀ are reset at the start of every round (Alg. 1 line 6).
  * All norms are global over the param pytree, computed in fp32 — under
    pjit these lower to small all-reduces on the client's submesh.
  * ``groupwise=True`` is a beyond-paper extension: one step size per
    top-level param group instead of one per client (ablated in
    EXPERIMENTS.md). Default is the faithful global rule.

The fused Pallas kernel (repro/kernels/delta_sgd) performs the update +
both norm accumulations in a single HBM pass; ``use_pallas`` switches it in.

Flat engine: ``FlatDeltaSGDState`` + ``flat_delta_sgd_step`` run the SAME
rule for all C participating clients at once on packed ``(C, N)`` buffers
(repro.core.flat) — two kernel launches per local step total, independent
of leaf count and client count. ``backend="pallas"`` uses the batched
Pallas kernels (interpret mode off-TPU); ``backend="xla"`` lowers the
identical math through plain jnp on the flat buffers, which is what
meshed/pjit callers use.

Sharded flat engine: ``flat_delta_sgd_step_sharded`` is the mesh-native
variant — the (C, N) buffer stays sharded per
``FederationSpec.flat_spec(mesh)`` (clients over the client axes, N over
fsdp/tp axes) and the kernels run inside ``shard_map`` on each device's
local slab. The dual norm reduction completes with ONE psum of the two
partial sums over the N-shard axes (2·C_local floats on the wire); the
(C, N) buffer itself is never gathered, and the apply is purely
shard-local.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import flat as flatlib

# Numerical guard ceiling on η (flat engines): Eq. (4)'s cand1 can blow
# up when ‖∇̃f(x_k) − ∇̃f(x_{k−1})‖ underflows on a flat local landscape,
# and a non-finite η from a corrupted gradient would poison the packed
# (C, N) buffer irreversibly. η is clamped to this ceiling (counted per
# client in FlatDeltaSGDState.clips) and non-finite norms drop the lane
# to η=0 + latch FlatDeltaSGDState.valid off for the rest of the round.
# fp32 min against a finite ceiling is exact, so healthy trajectories
# are bit-identical with the guard on.
ETA_CLAMP = 1e3


class DeltaSGDState(NamedTuple):
    prev_grads: object      # pytree like params
    eta: jax.Array          # current step size (scalar f32, or per-group)
    theta: jax.Array        # η_k / η_{k-1}
    prev_grad_norm: jax.Array
    k: jax.Array            # local step counter (resets every round)


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _group_norms(tree):
    """One norm per top-level key (beyond-paper groupwise variant)."""
    return {k: _global_norm(v) for k, v in tree.items()}


def delta_sgd_init(params, *, eta0: float, theta0: float,
                   groupwise: bool = False) -> DeltaSGDState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    if groupwise:
        eta = {k: jnp.asarray(eta0, jnp.float32) for k in params}
        theta = {k: jnp.asarray(theta0, jnp.float32) for k in params}
        pgn = {k: jnp.asarray(0.0, jnp.float32) for k in params}
    else:
        eta = jnp.asarray(eta0, jnp.float32)
        theta = jnp.asarray(theta0, jnp.float32)
        pgn = jnp.asarray(0.0, jnp.float32)
    return DeltaSGDState(zeros, eta, theta, pgn, jnp.asarray(0, jnp.int32))


def delta_sgd_reset(state: DeltaSGDState, *, eta0: float,
                    theta0: float) -> DeltaSGDState:
    """Round-start reset (Alg. 1 line 6): η ← η₀, θ ← θ₀, k ← 0."""
    eta = jax.tree.map(lambda e: jnp.full_like(e, eta0), state.eta)
    theta = jax.tree.map(lambda t: jnp.full_like(t, theta0), state.theta)
    pgn = jax.tree.map(lambda n: jnp.zeros_like(n), state.prev_grad_norm)
    return DeltaSGDState(state.prev_grads, eta, theta, pgn,
                         jnp.asarray(0, jnp.int32))


def _eta_rule(eta_prev, theta_prev, dx_norm, dg_norm, gamma, delta):
    """Eq. (4) with the δ-damped growth condition (Appendix B.1)."""
    cand1 = jnp.where(dg_norm > 0.0,
                      gamma * dx_norm / (2.0 * dg_norm),
                      jnp.asarray(jnp.inf, jnp.float32))
    cand2 = jnp.sqrt(1.0 + delta * theta_prev) * eta_prev
    eta = jnp.minimum(cand1, cand2)
    theta = eta / eta_prev
    return eta, theta


def delta_sgd_update(params, grads, state: DeltaSGDState, *, gamma: float,
                     delta: float, eta0: float, use_pallas: bool = False):
    """One local step: compute η via Eq. (4) (η₀ on the first local step),
    apply x ← x − η·g, and roll the state."""
    groupwise = isinstance(state.eta, dict)
    first = (state.k == 0)

    if groupwise:
        dg = {k: _global_norm(jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                grads[k], state.prev_grads[k]))
              for k in params}
        gn = _group_norms(grads)
        new_eta, new_theta = {}, {}
        for k in params:
            dx = state.eta[k] * state.prev_grad_norm[k]
            e, t = _eta_rule(state.eta[k], state.theta[k], dx, dg[k],
                             gamma, delta)
            new_eta[k] = jnp.where(first, jnp.asarray(eta0, jnp.float32), e)
            new_theta[k] = jnp.where(first, state.theta[k], t)
        new_params = {k: jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - new_eta[k] * g.astype(jnp.float32)).astype(p.dtype),
            params[k], grads[k]) for k in params}
        new_state = DeltaSGDState(grads, new_eta, new_theta, gn, state.k + 1)
        return new_params, new_state

    if use_pallas:
        from repro.kernels.delta_sgd import ops as dsgd_ops
        return dsgd_ops.fused_delta_sgd_update(
            params, grads, state, gamma=gamma, delta=delta, eta0=eta0)

    # ‖x_k − x_{k-1}‖ = η_{k-1}·‖g_{k-1}‖ for SGD updates
    dx_norm = state.eta * state.prev_grad_norm
    # difference in f32 (exact for bf16 inputs) — matches the kernel paths
    dg_norm = _global_norm(jax.tree.map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
        grads, state.prev_grads))
    eta, theta = _eta_rule(state.eta, state.theta, dx_norm, dg_norm,
                           gamma, delta)
    eta = jnp.where(first, jnp.asarray(eta0, jnp.float32), eta)
    theta = jnp.where(first, state.theta, theta)
    eta = jnp.minimum(eta, ETA_CLAMP)   # same ceiling as the flat engines
    grad_norm = _global_norm(grads)
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - eta * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_params, DeltaSGDState(grads, eta, theta, grad_norm,
                                     state.k + 1)


# --------------------------------------------------------------------------
# flat engine: all C clients' Δ-SGD state on packed (C, N) buffers
# --------------------------------------------------------------------------

class FlatDeltaSGDState(NamedTuple):
    prev_grads: jax.Array       # (C, N) packed previous gradients, f32
    eta: jax.Array              # (C,) per-client step size
    theta: jax.Array            # (C,) η_k / η_{k-1}
    prev_grad_norm: jax.Array   # (C,)
    k: jax.Array                # local step counter (shared, resets/round)
    # numerical-guard outcomes (None on legacy 5-field constructions):
    valid: Optional[jax.Array] = None   # (C,) bool: lane still healthy —
                                        # LATCHES off on a non-finite
                                        # norm for the rest of the round
    clips: Optional[jax.Array] = None   # (C,) int32: η-clamp hits


def flat_delta_sgd_init(num_clients: int, layout: flatlib.FlatLayout, *,
                        eta0: float, theta0: float) -> FlatDeltaSGDState:
    C, N = num_clients, layout.padded_size
    return FlatDeltaSGDState(
        jnp.zeros((C, N), jnp.float32),
        jnp.full((C,), eta0, jnp.float32),
        jnp.full((C,), theta0, jnp.float32),
        jnp.zeros((C,), jnp.float32),
        jnp.asarray(0, jnp.int32),
        jnp.ones((C,), bool),
        jnp.zeros((C,), jnp.int32))


def _guard(eta, dg_norm, grad_norm, valid_prev):
    """In-step numerical guard: non-finite norms drop the lane (η=0 via
    the activity mask, client excluded this round — ``valid`` latches)
    and runaway η is clamped to ETA_CLAMP. ``jnp.minimum`` against the
    finite ceiling and the all-True masks downstream are bit-exact
    identities on healthy lanes, so the guard is ALWAYS on.

    Returns (eta, valid, clip_hit). NaN η compares False against the
    ceiling, so a poisoned lane counts as a NaN-guard trip, not a clip.
    """
    finite = jnp.isfinite(dg_norm) & jnp.isfinite(grad_norm)
    valid = finite if valid_prev is None else (valid_prev & finite)
    clip_hit = eta > ETA_CLAMP
    return jnp.minimum(eta, ETA_CLAMP), valid, clip_hit


def _mask_inactive(active, eta, theta, grad_norm, state):
    """Heterogeneous-K lane masking (repro.federation.heterogeneity): a
    client past its K_c budget applies η=0 (P untouched — the bf16 round
    mask is idempotent on already-rounded lanes) and keeps its scalar
    state frozen. ``prev_grads`` is NOT re-selected: inactivity is a
    terminal prefix within the round, so a frozen client's stale norm
    state can never reach an applied update — skipping the (C, N) select
    keeps the step at exactly two fused kernel launches.

    Returns (eta_applied, eta, theta, grad_norm)."""
    eta_applied = jnp.where(active, eta, jnp.float32(0.0))
    eta = jnp.where(active, eta, state.eta)
    theta = jnp.where(active, theta, state.theta)
    grad_norm = jnp.where(active, grad_norm, state.prev_grad_norm)
    return eta_applied, eta, theta, grad_norm


def flat_delta_sgd_step(P: jax.Array, G: jax.Array,
                        state: FlatDeltaSGDState, *, gamma: float,
                        delta: float, eta0: float,
                        mask: Optional[jax.Array] = None,
                        active: Optional[jax.Array] = None,
                        backend: str = "pallas",
                        interpret: Optional[bool] = None):
    """One Δ-SGD local step for ALL clients on packed buffers.

    P, G: (C, N) packed params/grads. Exactly two Pallas launches
    (batched_norms + batched_apply) regardless of leaf count and client
    count; ``backend="xla"`` runs the same math as fused jnp ops for
    meshed callers. ``active`` is an optional (C,) bool lane mask for
    heterogeneous step counts: inactive clients apply η=0 and keep their
    state frozen, at no extra launch cost. Returns (new_P, new_state).
    """
    first = (state.k == 0)
    if backend == "pallas":
        from repro.kernels.delta_sgd import delta_sgd as k
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        dg2, gg2 = k.batched_norms(G, state.prev_grads,
                                   interpret=interpret)
    else:
        from repro.kernels.delta_sgd import ref as kref
        dg2, gg2 = kref.batched_norms_ref(G, state.prev_grads)
    dg_norm = jnp.sqrt(dg2)
    grad_norm = jnp.sqrt(gg2)
    dx_norm = state.eta * state.prev_grad_norm
    eta, theta = _eta_rule(state.eta, state.theta, dx_norm, dg_norm,
                           gamma, delta)
    eta = jnp.where(first, jnp.asarray(eta0, jnp.float32), eta)
    theta = jnp.where(first, state.theta, theta)
    eta, valid, clip_hit = _guard(eta, dg_norm, grad_norm, state.valid)
    act = valid if active is None else (active & valid)
    eta_applied, eta, theta, grad_norm = _mask_inactive(
        act, eta, theta, grad_norm, state)
    clips = (jnp.zeros_like(valid, jnp.int32) if state.clips is None
             else state.clips) + (clip_hit & act).astype(jnp.int32)
    # sanitize: η=0 alone can't stop a NaN gradient (0·NaN = NaN in the
    # apply), so invalid lanes are zeroed before both the apply and the
    # prev_grads roll. where(True, G, 0) is G bitwise on healthy lanes,
    # and it is an XLA select — the step stays at two kernel launches.
    G_safe = jnp.where(valid[:, None], G, jnp.float32(0.0))
    if backend == "pallas":
        new_P = k.batched_apply(P, G_safe, eta_applied, mask=mask,
                                interpret=interpret)
    else:
        new_P = kref.batched_apply_ref(P, G_safe, eta_applied, mask)
    return new_P, FlatDeltaSGDState(G_safe, eta, theta, grad_norm,
                                    state.k + 1, valid, clips)


# --------------------------------------------------------------------------
# sharded flat engine: the (C, N) buffer stays mesh-sharded end to end
# --------------------------------------------------------------------------

def _axis_names(entry):
    """Flatten one PartitionSpec entry to a tuple of mesh axis names."""
    if entry is None:
        return ()
    return tuple(entry) if isinstance(entry, tuple) else (entry,)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map >= 0.6, experimental
    before), with replication checking off — the Pallas kernels carry no
    replication rules."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return sm(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


def flat_delta_sgd_step_sharded(P: jax.Array, G: jax.Array,
                                state: FlatDeltaSGDState, *, gamma: float,
                                delta: float, eta0: float, mesh, pspec,
                                mask: Optional[jax.Array] = None,
                                active: Optional[jax.Array] = None,
                                backend: str = "xla",
                                interpret: Optional[bool] = None):
    """One Δ-SGD local step on a mesh-sharded packed (C, N) buffer.

    ``pspec`` is ``FederationSpec.flat_spec(mesh)`` — clients over
    ``pspec[0]``, the flat param dim over ``pspec[1]`` (the layout must
    have been built with ``shards=FederationSpec.flat_shards(mesh)`` so
    each local slab stays lane/row-block aligned). Per device: the kernel
    pair runs on the local (C_loc, N_loc) slab; the per-client dual norms
    finish with a single psum over the N-shard axes, so η is exact while
    N is never gathered. ``active`` is the optional (C,) heterogeneous-K
    lane mask (sharded like the other per-client vectors). Returns
    (new_P, new_state) with unchanged shardings.
    """
    from jax.sharding import PartitionSpec as PS
    ca = pspec[0] if len(pspec) > 0 else None
    na = pspec[1] if len(pspec) > 1 else None
    na_names = _axis_names(na)
    buf, vec, rep = PS(ca, na), PS(ca), PS()
    if backend == "pallas" and interpret is None:
        interpret = jax.default_backend() != "tpu"
    with_mask = mask is not None
    with_active = active is not None

    def local_step(P_l, G_l, Gp_l, eta, theta, pgn, k_ctr, valid_p,
                   clips_p, *rest):
        rest = list(rest)
        mask_l = rest.pop(0) if with_mask else None
        active_l = rest.pop(0) if with_active else None
        if backend == "pallas":
            from repro.kernels.delta_sgd import delta_sgd as k
            dg2, gg2 = k.batched_norms(G_l, Gp_l, interpret=interpret)
        else:
            from repro.kernels.delta_sgd import ref as kref
            dg2, gg2 = kref.batched_norms_ref(G_l, Gp_l)
        if na_names:
            dg2 = jax.lax.psum(dg2, na_names)
            gg2 = jax.lax.psum(gg2, na_names)
        dg_norm = jnp.sqrt(dg2)
        grad_norm = jnp.sqrt(gg2)
        dx_norm = eta * pgn
        eta_n, theta_n = _eta_rule(eta, theta, dx_norm, dg_norm,
                                   gamma, delta)
        first = (k_ctr == 0)
        eta_n = jnp.where(first, jnp.asarray(eta0, jnp.float32), eta_n)
        theta_n = jnp.where(first, theta, theta_n)
        eta_n, valid_n, clip_hit = _guard(eta_n, dg_norm, grad_norm,
                                          valid_p)
        act = valid_n if active_l is None else (active_l & valid_n)
        st = FlatDeltaSGDState(Gp_l, eta, theta, pgn, k_ctr)
        eta_applied, eta_n, theta_n, grad_norm = _mask_inactive(
            act, eta_n, theta_n, grad_norm, st)
        clips_n = clips_p + (clip_hit & act).astype(jnp.int32)
        G_safe = jnp.where(valid_n[:, None], G_l, jnp.float32(0.0))
        if backend == "pallas":
            new_P = k.batched_apply(P_l, G_safe, eta_applied, mask=mask_l,
                                    interpret=interpret)
        else:
            new_P = kref.batched_apply_ref(P_l, G_safe, eta_applied,
                                           mask_l)
        return new_P, G_safe, eta_n, theta_n, grad_norm, valid_n, clips_n

    C = P.shape[0]
    valid = (state.valid if state.valid is not None
             else jnp.ones((C,), bool))
    clips = (state.clips if state.clips is not None
             else jnp.zeros((C,), jnp.int32))
    ins = [P, G, state.prev_grads, state.eta, state.theta,
           state.prev_grad_norm, state.k, valid, clips]
    specs = [buf, buf, buf, vec, vec, vec, rep, vec, vec]
    if with_mask:
        ins.append(mask)
        specs.append(PS(na))
    if with_active:
        ins.append(active)
        specs.append(vec)
    fn = _shard_map(local_step, mesh, tuple(specs),
                    (buf, buf, vec, vec, vec, vec, vec))
    new_P, G_safe, eta, theta, grad_norm, valid, clips = fn(*ins)
    return new_P, FlatDeltaSGDState(G_safe, eta, theta, grad_norm,
                                    state.k + 1, valid, clips)
