"""Client loss functions: CE, FedProx (Li et al. 2020), MOON (Li et al. 2021).

The FL round threads (params, batch, global_params, prev_params) through a
uniform signature; plain CE ignores the extra arguments. Δ-SGD composes with
any of these (paper Tables 2b, 5, 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sq_dist(a, b):
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)
                                  - y.astype(jnp.float32)))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def make_loss(base_loss_fn, *, fedprox_mu: float = 0.0, moon_mu: float = 0.0,
              moon_tau: float = 0.5, repr_fn=None):
    """base_loss_fn(params, batch) -> (loss, metrics).

    Returns loss_fn(params, batch, global_params, prev_params)
    -> (loss, metrics).
    """
    def loss_fn(params, batch, global_params=None, prev_params=None):
        loss, metrics = base_loss_fn(params, batch)
        if fedprox_mu and global_params is not None:
            prox = 0.5 * fedprox_mu * _sq_dist(params, global_params)
            loss = loss + prox
            metrics = {**metrics, "prox": prox}
        if moon_mu and global_params is not None and prev_params is not None:
            assert repr_fn is not None, "MOON needs a representation fn"
            z = repr_fn(params, batch)
            z_glob = jax.lax.stop_gradient(repr_fn(global_params, batch))
            z_prev = jax.lax.stop_gradient(repr_fn(prev_params, batch))

            def cos(a, b):
                a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-8)
                b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-8)
                return jnp.sum(a * b, axis=-1)

            pos = cos(z, z_glob) / moon_tau
            neg = cos(z, z_prev) / moon_tau
            con = -jnp.mean(pos - jnp.logaddexp(pos, neg))
            loss = loss + moon_mu * con
            metrics = {**metrics, "moon": con}
        return loss, metrics

    return loss_fn
