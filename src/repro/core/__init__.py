"""The paper's primary contribution: Δ-SGD client-adaptive federated
optimization, plus every optimizer/loss it is compared against."""
from repro.core import flat
from repro.core.client_opt import CLIENT_OPTS, ClientOpt, get_client_opt
from repro.core.delta_sgd import (DeltaSGDState, FlatDeltaSGDState,
                                  delta_sgd_init, delta_sgd_reset,
                                  delta_sgd_update, flat_delta_sgd_init,
                                  flat_delta_sgd_step)
from repro.core.fed_round import (FLState, RoundAux, init_fl_state,
                                  make_fl_round)
from repro.core.fed_loop import (FlatFLState, arena_gather,
                                 flatten_fl_state, make_fl_loop,
                                 make_fleet_loop, unflatten_fl_state)
from repro.core.losses import make_loss
from repro.core.server_opt import SERVER_OPTS, ServerOpt, get_server_opt

__all__ = ["CLIENT_OPTS", "ClientOpt", "get_client_opt", "DeltaSGDState",
           "FlatDeltaSGDState", "delta_sgd_init", "delta_sgd_reset",
           "delta_sgd_update", "flat_delta_sgd_init", "flat_delta_sgd_step",
           "FLState", "RoundAux", "init_fl_state", "make_fl_round",
           "make_loss", "FlatFLState", "arena_gather", "flatten_fl_state",
           "make_fl_loop", "make_fleet_loop", "unflatten_fl_state",
           "SERVER_OPTS", "ServerOpt", "get_server_opt", "flat"]
