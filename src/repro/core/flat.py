"""FlatParams: pack a param/grad pytree into ONE lane-aligned flat buffer.

The Δ-SGD local step is two global reductions plus an axpy (Eq. (4),
Alg. 1) — work that is bandwidth-bound and identical for every leaf and
every client. Launching it per leaf (and vmapping per client) pays
kernel-launch and padding overhead proportional to ``num_leaves ×
num_clients``. ``FlatLayout`` collapses both axes: the pytree becomes a
single ``(N,)`` f32 buffer (``N`` padded so the Pallas kernels never
re-pad), and the client axis becomes the leading dim of a dense ``(C, N)``
buffer that one 2-D-grid kernel sweeps in a single launch.

Layout is computed once per (treedef, shapes, dtypes) and cached; packing
is one concatenate, unpacking is slice + reshape + cast views. Tail
padding is zero-filled so global norm reductions over the padded buffer
are exact.

Mixed precision: the buffer is always f32. Leaves whose dtype is narrower
(bf16) are tracked by ``round_mask`` — a per-element mask the fused apply
kernel uses to reproduce the reference path's per-step
``(p32 − η·g32).astype(bf16)`` rounding bit-for-bit, so a flat K-step
scan matches the per-leaf pytree path.

Sharded layouts: under an SPMD mesh the N dim of the (C, N) buffer is
sharded over the fsdp/tp axes (``FederationSpec.flat_spec``). A layout
built with ``shards=S`` pads N so that N/S is itself lane- and
row-block-aligned — each device's contiguous slab is directly kernel-
ready, no re-padding inside ``shard_map``. All padding still lives in the
global tail (zero-filled), so global norm reductions stay exact. The
layout cache key includes ``shards``: switching meshes in one process can
never reuse a stale padded layout.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LANES = 128          # TPU lane width; every buffer is a (M, LANES) grid
BLOCK_ROWS = 1024    # kernel row-block; kernels/delta_sgd imports these


class LeafSpec(NamedTuple):
    offset: int                # element offset into the flat buffer
    size: int                  # number of valid elements
    shape: Tuple[int, ...]     # original leaf shape (per client)
    dtype: Any                 # original leaf dtype


class FlatLayout(NamedTuple):
    treedef: Any
    leaves: Tuple[LeafSpec, ...]
    size: int                  # total valid elements
    padded_size: int           # N: multiple of shards*rows*LANES
    shards: int = 1            # N-dim shard count the padding aligns to


_LAYOUT_CACHE: dict = {}


def _padded(total: int, shards: int = 1) -> int:
    """Round ``total`` up so that each of ``shards`` equal contiguous
    slabs splits evenly into (rows, LANES) row blocks."""
    per = max(1, -(-total // shards))
    m0 = max(1, -(-per // LANES))
    rows = min(BLOCK_ROWS, m0)
    m = -(-m0 // rows) * rows
    return m * LANES * shards


def layout_of(tree, *, batched: bool = False, shards: int = 1) -> FlatLayout:
    """Flat layout for ``tree`` (cached). With ``batched=True`` the leaves
    carry a leading client axis which is excluded from the layout.
    ``shards`` is the N-dim shard count of the target mesh
    (``FederationSpec.flat_shards``); it is part of the cache key, so two
    meshes with different shard counts never share a padded layout."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape[1:] if batched else l.shape)
                   for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes, int(shards))
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    specs, off = [], 0
    for shape, dtype in zip(shapes, dtypes):
        if dtype not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
            raise TypeError(f"FlatLayout supports f32/bf16 leaves, got "
                            f"{dtype}")
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        specs.append(LeafSpec(off, size, shape, dtype))
        off += size
    layout = FlatLayout(treedef, tuple(specs), off, _padded(off, shards),
                        int(shards))
    _LAYOUT_CACHE[key] = layout
    return layout


def round_mask(layout: FlatLayout) -> Optional[jax.Array]:
    """(N,) f32 mask, 1.0 where the element belongs to a sub-f32 leaf and
    must be rounded to that dtype after every update; None if all-f32."""
    if all(s.dtype == jnp.dtype(jnp.float32) for s in layout.leaves):
        return None
    m = np.zeros((layout.padded_size,), np.float32)
    for s in layout.leaves:
        if s.dtype != jnp.dtype(jnp.float32):
            m[s.offset:s.offset + s.size] = 1.0
    return jnp.asarray(m)


def pack(tree, layout: Optional[FlatLayout] = None) -> jax.Array:
    """Pytree -> (N,) f32 buffer (zero tail padding). One concatenate."""
    layout = layout or layout_of(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    pad = layout.padded_size - layout.size
    if pad:
        parts.append(jnp.zeros((pad,), jnp.float32))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack(buf: jax.Array, layout: FlatLayout, *, cast: bool = True):
    """(N,) buffer -> pytree with original shapes/dtypes (slice views).

    ``cast=False`` keeps every leaf in the buffer's f32 — used by the
    async aggregation buffer, whose delta accumulator must not lose the
    sub-bf16 bits of a weighted delta sum."""
    leaves = [buf[s.offset:s.offset + s.size].reshape(s.shape)
              for s in layout.leaves]
    if cast:
        leaves = [l.astype(s.dtype)
                  for l, s in zip(leaves, layout.leaves)]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def pack_batched(tree, layout: Optional[FlatLayout] = None) -> jax.Array:
    """Pytree with leading client axis C on every leaf -> (C, N) f32."""
    layout = layout or layout_of(tree, batched=True)
    leaves = jax.tree_util.tree_leaves(tree)
    C = leaves[0].shape[0]
    parts = [l.reshape(C, -1).astype(jnp.float32) for l in leaves]
    pad = layout.padded_size - layout.size
    if pad:
        parts.append(jnp.zeros((C, pad), jnp.float32))
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def unpack_batched(buf: jax.Array, layout: FlatLayout, *,
                   cast: bool = True):
    """(C, N) buffer -> pytree with (C, *shape) leaves, original dtypes.

    ``cast=False`` keeps every leaf in the buffer's f32 — used for the
    per-client EF21 error-feedback state (repro.compression), whose
    reconstruction tree must not lose sub-bf16 bits between rounds."""
    C = buf.shape[0]
    leaves = [buf[:, s.offset:s.offset + s.size].reshape((C,) + s.shape)
              for s in layout.leaves]
    if cast:
        leaves = [l.astype(s.dtype) for l, s in zip(leaves, layout.leaves)]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)
