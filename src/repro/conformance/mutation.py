"""Deliberate engine perturbations ("mutations") for fuzzer-teeth
testing: a named mutation monkeypatches one engine with an epsilon-size
numerical defect, so the differential oracles MUST flag it — proving
the conformance plane detects real divergence, not just agreeing with
itself.

Mutations are data, not code state: the active mutation's name is
recorded in every violation artifact, and ``replay.py`` re-installs it
before re-running the shrunk config, so a mutation-induced failure is
reproducible from the JSON artifact alone (in a fresh process — an
in-memory monkeypatch would not survive the subprocess boundary).
The ``REPRO_CONFORMANCE_MUTATION`` env var provides the same hook for
CI legs that want to smoke-test the teeth end to end.

The patch target matters: ``core.delta_sgd.flat_delta_sgd_step``
resolves ``k.batched_apply`` through the kernel MODULE at trace time,
so patching the module attribute perturbs the pallas backend (and only
it) in every engine built afterwards — the harness builds fresh
closures per run, so the mutation is picked up without cache games.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

MUTATIONS: Dict[str, Callable[[], Callable[[], None]]] = {}


def register(name: str):
    def deco(installer):
        MUTATIONS[name] = installer
        return installer
    return deco


@register("delta_sgd.pallas_apply:1e-3")
def _pallas_apply_eps():
    """Shift the pallas batched_apply output by 1e-3: the pallas flat
    engine drifts off the xla engine by ~1e-3/step — far outside the
    1e-5 engine-parity tolerance, and outside the delta_sgd kernel
    matrix tolerance too."""
    from repro.kernels.delta_sgd import delta_sgd as dk
    orig = dk.batched_apply

    def perturbed(p, g, eta, *, mask=None, interpret=False):
        out = orig(p, g, eta, mask=mask, interpret=interpret)
        return out + 1e-3

    dk.batched_apply = perturbed

    def undo():
        dk.batched_apply = orig
    return undo


@register("telemetry.hist_offbyone")
def _hist_off_by_one():
    """Add one phantom count to the first histogram bin: invisible to
    trajectories, caught only by the kernel:telemetry parity cells."""
    from repro.kernels import telemetry as tns
    from repro.kernels.telemetry import telemetry as tk
    orig = tk.lane_histogram

    def perturbed(x, edges, *, interpret=None):
        h = orig(x, edges, interpret=interpret)
        return h.at[0].add(1.0)

    tk.lane_histogram = perturbed
    tns.lane_histogram = perturbed

    def undo():
        tk.lane_histogram = orig
        tns.lane_histogram = orig
    return undo


class active_mutation:
    """Context manager: install a named mutation (or none for name in
    (None, "", "none")) and restore the pristine engine on exit."""

    def __init__(self, name: Optional[str]):
        self.name = name if name not in (None, "", "none") else None
        self._undo = None

    def __enter__(self):
        if self.name is not None:
            try:
                installer = MUTATIONS[self.name]
            except KeyError:
                raise KeyError(
                    f"unknown mutation {self.name!r}; "
                    f"registered: {sorted(MUTATIONS)}") from None
            self._undo = installer()
        return self

    def __exit__(self, *exc):
        if self._undo is not None:
            self._undo()
            self._undo = None
        return False
