"""Budgeted config-space fuzzer CLI.

    python -m repro.conformance.fuzz --seeds 10 --out artifacts/

Samples one valid config per seed, runs every applicable oracle,
shrinks violations to minimal repros, and writes one JSON artifact per
violation plus a ``summary.json``. Exit status 1 iff any violation was
found — the CI fuzz leg keys on this and uploads the artifact dir.

``--mutation NAME`` (or env ``REPRO_CONFORMANCE_MUTATION``) installs a
registered engine perturbation first — the teeth-test hook: with a
mutation planted, the fuzzer MUST fail and its artifact MUST replay.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .mutation import active_mutation
from .runner import check_config, write_artifact
from .space import sample


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.conformance.fuzz",
        description="config-space differential fuzzer")
    p.add_argument("--seeds", type=int, default=10,
                   help="number of fuzz seeds (configs) to run")
    p.add_argument("--start", type=int, default=0,
                   help="first seed (seeds are start..start+seeds-1)")
    p.add_argument("--out", default="conformance-artifacts",
                   help="directory for violation artifacts + summary")
    p.add_argument("--oracles", default=None,
                   help="comma-separated oracle subset (default: all "
                        "applicable)")
    p.add_argument("--mutation", default=None,
                   help="plant a registered engine mutation (teeth "
                        "testing); env REPRO_CONFORMANCE_MUTATION")
    p.add_argument("--no-shrink", action="store_true",
                   help="report raw violating configs without shrinking")
    p.add_argument("--shrink-budget", type=int, default=40,
                   help="max differential evals per shrink")
    p.add_argument("--no-mesh", action="store_true",
                   help="never sample mesh configs")
    p.add_argument("--no-serve", action="store_true",
                   help="never sample serving configs")
    return p


def run(argv=None) -> int:
    args = build_parser().parse_args(argv)
    mutation = args.mutation or os.environ.get(
        "REPRO_CONFORMANCE_MUTATION") or None
    names = args.oracles.split(",") if args.oracles else None
    seeds = range(args.start, args.start + args.seeds)
    summary = {"seeds": list(seeds), "mutation": mutation,
               "violations": [], "configs": {}}
    n_viol = 0
    with active_mutation(mutation):
        for seed in seeds:
            cfg = sample(seed, allow_mesh=not args.no_mesh,
                         allow_serve=not args.no_serve)
            summary["configs"][seed] = cfg.label()
            violations = check_config(
                cfg, oracle_names=names, do_shrink=not args.no_shrink,
                shrink_budget=args.shrink_budget, mutation=mutation)
            for v in violations:
                n_viol += 1
                path = write_artifact(args.out, v)
                summary["violations"].append(
                    {"seed": seed, "oracle": v.oracle,
                     "artifact": path, "config": v.config.label(),
                     "messages": v.messages[:3]})
                print(f"VIOLATION seed={seed} oracle={v.oracle} "
                      f"minimal={v.config.label()} -> {path}",
                      file=sys.stderr)
                for m in v.messages[:3]:
                    print(f"  {m}", file=sys.stderr)
            ok = "FAIL" if violations else "ok"
            print(f"seed {seed}: {cfg.label()} ... {ok}")
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True, default=str)
    print(f"{len(list(seeds))} configs, {n_viol} violation(s)"
          + (f" [mutation={mutation}]" if mutation else ""))
    return 1 if n_viol else 0


if __name__ == "__main__":
    raise SystemExit(run())
