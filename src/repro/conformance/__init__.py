"""Conformance plane: the oracle registry, config-space differential
fuzzer, greedy shrinker, replayable violation artifacts, and the
checked-in regression corpus. See docs/TESTING.md for the workflow.

    python -m repro.conformance.fuzz --seeds 10 --out artifacts/
    python -m repro.conformance.replay artifacts/<violation>.json
    python -m repro.conformance.corpus --regen
"""
from .harness import Harness, diff_trajectories
from .kernels import KERNEL_MATRIX, KernelCell, cells_for, check_cell
from .mutation import MUTATIONS, active_mutation
from .oracles import ORACLES, Oracle, applicable
from .runner import Violation, check_config, read_artifact, write_artifact
from .shrink import shrink
from .space import (DEFAULT, ConfPoint, ServePoint, invalid_reason,
                    sample, shrink_candidates)

__all__ = [
    "ConfPoint", "ServePoint", "DEFAULT", "sample", "invalid_reason",
    "shrink_candidates", "Harness", "diff_trajectories", "Oracle",
    "ORACLES", "applicable", "KERNEL_MATRIX", "KernelCell", "cells_for",
    "check_cell", "MUTATIONS", "active_mutation", "Violation",
    "check_config", "write_artifact", "read_artifact", "shrink",
]
