"""Differential runner: execute a config through every applicable
oracle, shrink violations to minimal repros, and emit them as JSON
artifacts that ``python -m repro.conformance.replay`` re-runs from a
fresh process. This is the engine under both the regression-corpus
tier-1 test and the budgeted fuzz CI leg (repro.conformance.fuzz).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import List, Optional

from .oracles import ORACLES, applicable
from .shrink import shrink as _shrink
from .space import ConfPoint

ARTIFACT_VERSION = 1


@dataclass
class Violation:
    oracle: str
    messages: List[str]
    config: ConfPoint                   # minimal (shrunk) config
    shrunk_from: ConfPoint              # the originally sampled config
    shrink_evals: int = 0
    mutation: Optional[str] = None
    error: Optional[str] = None         # set when the run CRASHED

    def to_artifact(self) -> dict:
        o = ORACLES[self.oracle]
        return {
            "version": ARTIFACT_VERSION,
            "oracle": self.oracle,
            "relation": o.relation,
            "tol": o.tol,
            "messages": self.messages,
            "config": self.config.to_dict(),
            "shrunk_from": self.shrunk_from.to_dict(),
            "shrink_evals": self.shrink_evals,
            "mutation": self.mutation,
            "error": self.error,
        }

    @classmethod
    def from_artifact(cls, d: dict) -> "Violation":
        return cls(oracle=d["oracle"], messages=list(d["messages"]),
                   config=ConfPoint.from_dict(d["config"]),
                   shrunk_from=ConfPoint.from_dict(d["shrunk_from"]),
                   shrink_evals=int(d.get("shrink_evals", 0)),
                   mutation=d.get("mutation"), error=d.get("error"))


def check_config(cfg: ConfPoint, *, oracle_names=None,
                 do_shrink: bool = True, shrink_budget: int = 40,
                 mutation: Optional[str] = None) -> List[Violation]:
    """All oracle violations for one config. Harness runs are memoised
    per config, so the N applicable oracles share the baseline engine
    runs. A crashing oracle is itself a finding (engines must RUN on
    every valid config), reported with the exception text and not
    shrunk."""
    from .harness import Harness
    harness = Harness(cfg)
    out: List[Violation] = []
    for oracle in applicable(cfg, oracle_names):
        try:
            messages = oracle.check(harness)
            error = None
        except Exception as e:  # noqa: BLE001 - crash IS the finding
            messages = [f"[{oracle.name}] crashed: {type(e).__name__}: "
                        f"{e}"]
            error = f"{type(e).__name__}: {e}"
        if not messages:
            continue
        minimal, evals = cfg, 0
        if do_shrink and error is None:
            minimal, evals = _shrink(cfg, oracle, budget=shrink_budget)
            if minimal != cfg:
                # re-run on the minimal config for its own messages
                try:
                    from .harness import Harness as H
                    messages = oracle.check(H(minimal)) or messages
                except Exception:
                    pass
        out.append(Violation(oracle=oracle.name, messages=messages,
                             config=minimal, shrunk_from=cfg,
                             shrink_evals=evals, mutation=mutation,
                             error=error))
    return out


def write_artifact(out_dir: str, v: Violation) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{v.oracle.replace(':', '_')}-{v.config.label()}.json"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(v.to_artifact(), f, indent=2, sort_keys=True)
    return path


def read_artifact(path: str) -> Violation:
    with open(path) as f:
        return Violation.from_artifact(json.load(f))
