"""Differential-run harness: one ``Harness`` per ConfPoint builds the
shared quadratic FL problem (mixed f32/bf16 tree, stacked (R, C, K, b)
batches — the tests' canonical fixture at conformance scale) and knows
how to run it through every engine the oracles compare:

  host(backend)          R host-loop make_fl_round calls
  fused(backend)         one make_fl_loop scan block
  tree()                 the legacy per-client (vmapped) engine
  resume(backend)        host loop with a checkpoint save/restore at R//2
  replicated() / block() un-meshed vs block-level shard_map fused loops
  serve_pool/_isolated   continuous-batching vs one-at-a-time decode

Every run is memoised on the harness, so a config evaluated by many
oracles pays for each (engine, knobs) variant once — the xla host run is
the baseline of most oracles and runs exactly once per config. Runs
return flat ``{name: np.float32 array}`` trajectories (final-state
leaves + per-round metric rows) that ``diff_trajectories`` compares.

Engines are rebuilt from scratch per call (fresh closures, fresh jit
cache entries) so a mutation installed via repro.conformance.mutation
is picked up at trace time — that is what gives the fuzzer teeth.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from .space import ConfPoint

_SEN = "__cfg__"       # "use the ConfPoint's own value" sentinel


# ------------------------------------------------------------ trajectories
def _flat_tree(prefix: str, tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[prefix + jax.tree_util.keystr(path)] = np.asarray(
            leaf, np.float32)
    return out


def _stack_metrics(mets) -> dict:
    """Per-round metric dicts -> {'met.<k>': (R, ...)} rows."""
    if not mets:
        return {}
    keys = set(mets[0])
    for m in mets[1:]:
        keys &= set(m)
    return {f"met.{k}": np.stack([np.asarray(m[k], np.float32)
                                  for m in mets]) for k in sorted(keys)}


def _stacked_metrics(fmets) -> dict:
    """Already-stacked fused-loop metrics -> the same naming."""
    return {f"met.{k}": np.asarray(v, np.float32)
            for k, v in dict(fmets).items()}


def diff_trajectories(a: dict, b: dict, *, bitexact: bool,
                      tol: float = 0.0, keys=None, max_report: int = 6):
    """Violation strings for every differing entry. State entries must
    exist on both sides; ``met.*`` entries are compared on the key
    intersection (engines legitimately report different extras)."""
    if keys is None:
        keys = sorted(set(a) | set(b))
    out = []
    for k in keys:
        if k not in a or k not in b:
            if not k.startswith("met."):
                out.append(f"{k}: missing on one side "
                           f"(a={k in a} b={k in b})")
            continue
        x, y = a[k], b[k]
        if x.shape != y.shape:
            out.append(f"{k}: shape {x.shape} vs {y.shape}")
            continue
        if bitexact:
            ok = np.array_equal(x, y, equal_nan=True)
        else:
            ok = np.allclose(x, y, rtol=tol, atol=tol, equal_nan=True)
        if not ok:
            err = float(np.nanmax(np.abs(x - y))) if x.size else 0.0
            out.append(f"{k}: max|Δ|={err:.3e} "
                       f"({'bit-exact' if bitexact else f'tol={tol:g}'})")
        if len(out) >= max_report:
            out.append("... (report truncated)")
            break
    return out


# ---------------------------------------------------------------- harness
class Harness:
    def __init__(self, cfg: ConfPoint):
        from repro.core import get_client_opt, get_server_opt, make_loss
        self.cfg = cfg
        c = cfg
        rng = np.random.default_rng(np.uint64(c.seed) + 17)
        R, C, K, B, D, E = (c.rounds, c.clients, c.local_steps, c.batch,
                            c.dim, c.bf16_dim)
        self.params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
        if E:
            self.params["e"] = jnp.asarray(rng.normal(size=E) * 0.5,
                                           jnp.bfloat16)
        self.batches = {
            "A": jnp.asarray(rng.normal(size=(R, C, K, B, D)),
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(R, C, K, B)), jnp.float32)}
        self.weights = (jnp.asarray(rng.uniform(0.5, 1.5, size=(R, C)),
                                    jnp.float32) if c.weighted else None)
        has_e = E > 0

        def quad(params, batch):
            x32 = params["x"].astype(jnp.float32)
            r = batch["A"] @ x32 - batch["b"]
            if has_e:
                e32 = params["e"].astype(jnp.float32)
                r = r + jnp.sum(e32) * 0.01
                return (0.5 * jnp.mean(r * r)
                        + 0.05 * jnp.mean(e32 * e32), {})
            return 0.5 * jnp.mean(r * r), {}

        self.loss = make_loss(quad)
        self.copt = get_client_opt("delta_sgd")
        self.sopt = get_server_opt(c.server_opt)
        self.num_clients = 2 * C          # registered pool for schedulers
        self.num_rounds = max(8, R)       # scheduler horizon (shared)
        self._cache = {}

    # ---- config resolution ----------------------------------------------
    def scenario(self, name=_SEN):
        from repro.federation import get_scenario
        c = self.cfg
        if name is _SEN:
            name = c.scenario
        if name is None:
            return None
        ov = {"seed": c.seed % 1013}
        if name == c.scenario:
            if c.robust_agg is not None:
                ov["robust_agg"] = c.robust_agg
            if c.quorum is not None:
                ov["quorum"] = c.quorum
        return get_scenario(name, **ov)

    def compression(self, kind=_SEN):
        from repro.compression import CompressionSpec
        c = self.cfg
        if kind is not _SEN:
            return (CompressionSpec(kind=kind) if kind is not None
                    else None)
        if c.compression == "none" and not c.error_feedback:
            return None
        return CompressionSpec(kind=c.compression, k_frac=c.k_frac,
                               error_feedback=c.error_feedback)

    # ---- train engines ---------------------------------------------------
    def _round_fn(self, backend, scn, comp, telemetry):
        from repro.core import make_fl_round
        return jax.jit(make_fl_round(
            self.loss, self.copt, self.sopt, num_rounds=self.num_rounds,
            weighted=self.cfg.weighted, flat=backend, scenario=scn,
            num_clients=self.num_clients, compression=comp,
            telemetry=telemetry))

    def _init(self, scn, comp):
        from repro.core import init_fl_state
        return init_fl_state(self.params, self.sopt, scn,
                             compression=comp, cohort=self.cfg.clients)

    def _host_rounds(self, rnd, st, restore_at=None):
        from repro.checkpoint import restore, save
        mets = []
        for r in range(self.cfg.rounds):
            if restore_at is not None and r == restore_at:
                with tempfile.TemporaryDirectory() as d:
                    save(d, st, step=r)
                    st, _ = restore(d, jax.tree.map(jnp.zeros_like, st),
                                    step=r)
            b_r = jax.tree.map(lambda x, r=r: x[r], self.batches)
            kw = ({"client_weights": self.weights[r]}
                  if self.weights is not None else {})
            st, m, _ = rnd(st, b_r, **kw)
            mets.append(m)
        return st, mets

    def host(self, backend="xla", *, telemetry=None, scenario=_SEN,
             compression=_SEN):
        key = ("host", backend, bool(telemetry), scenario,
               "cfg" if compression is _SEN else compression)
        if key not in self._cache:
            scn = self.scenario(scenario)
            comp = self.compression(compression)
            rnd = self._round_fn(backend, scn, comp, telemetry)
            st, mets = self._host_rounds(rnd, self._init(scn, comp))
            self._cache[key] = (_flat_tree("state", st)
                                | _stack_metrics(mets))
        return self._cache[key]

    def tree_engine(self):
        """Legacy per-client engine (flat=False): sync, uncompressed."""
        key = ("tree",)
        if key not in self._cache:
            rnd = self._round_fn(False, None, None, None)
            st, mets = self._host_rounds(rnd, self._init(None, None))
            self._cache[key] = (_flat_tree("state", st)
                                | _stack_metrics(mets))
        return self._cache[key]

    def resume(self, backend="xla"):
        key = ("resume", backend)
        if key not in self._cache:
            scn, comp = self.scenario(), self.compression()
            rnd = self._round_fn(backend, scn, comp, None)
            st, mets = self._host_rounds(rnd, self._init(scn, comp),
                                         restore_at=self.cfg.rounds // 2)
            self._cache[key] = (_flat_tree("state", st)
                                | _stack_metrics(mets))
        return self._cache[key]

    def fused(self, backend="xla", *, telemetry=None):
        from repro.core import (flatten_fl_state, make_fl_loop,
                                unflatten_fl_state)
        key = ("fused", backend, bool(telemetry))
        if key not in self._cache:
            scn, comp = self.scenario(), self.compression()
            loop = make_fl_loop(
                self.loss, self.copt, self.sopt, params_like=self.params,
                num_rounds=self.num_rounds,
                rounds_per_call=self.cfg.rounds,
                weighted=self.cfg.weighted, flat=backend, scenario=scn,
                num_clients=self.num_clients, compression=comp,
                telemetry=telemetry)
            fst = flatten_fl_state(self._init(scn, comp), loop.layout)
            if self.weights is not None:
                fst, fmets = jax.jit(loop)(fst, self.batches,
                                           client_weights=self.weights)
            else:
                fst, fmets = jax.jit(loop)(fst, self.batches)
            st = unflatten_fl_state(fst, loop.layout)
            self._cache[key] = (_flat_tree("state", st)
                                | _stacked_metrics(fmets))
        return self._cache[key]

    # ---- mesh engines (8 virtual devices) --------------------------------
    def _mesh_loops(self):
        from repro.core import make_fl_loop
        from repro.sharding.spec import FederationSpec
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        fed = FederationSpec(client_axes=("data",), fsdp_axes=(),
                             tp_axes=())
        kw = dict(params_like=self.params, num_rounds=self.num_rounds,
                  rounds_per_call=self.cfg.rounds, flat="xla",
                  weighted=self.cfg.weighted, scenario=self.scenario(),
                  num_clients=self.num_clients)
        rep = make_fl_loop(self.loss, self.copt, self.sopt, **kw)
        blk = make_fl_loop(self.loss, self.copt, self.sopt, mesh=mesh,
                           federation=fed, block_sharded=True, **kw)
        return rep, blk

    def _run_mesh(self, which):
        from repro.core import flatten_fl_state
        key = ("mesh", which)
        if key not in self._cache:
            rep, blk = self._mesh_loops()
            loop = rep if which == "replicated" else blk
            fst = flatten_fl_state(self._init(self.scenario(), None),
                                   loop.layout)
            if self.weights is not None:
                fst, mets = jax.jit(loop)(fst, self.batches,
                                          client_weights=self.weights)
            else:
                fst, mets = jax.jit(loop)(fst, self.batches)
            self._cache[key] = ({"state.P": np.asarray(fst.P, np.float32)}
                                | _stacked_metrics(mets))
        return self._cache[key]

    def replicated(self):
        return self._run_mesh("replicated")

    def block(self):
        return self._run_mesh("block")

    # ---- serving ---------------------------------------------------------
    def _serve_setup(self):
        from repro.configs import get_config
        from repro.models import build_model
        key = ("serve_setup",)
        if key not in self._cache:
            s = self.cfg.serve
            cfg = get_config(s.arch).reduced()
            model = build_model(cfg, jnp.float32)
            params = model.init(jax.random.key(s.seed))
            rng = np.random.default_rng(np.uint64(s.seed) + 3)
            prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(
                np.int32) for n in s.prompt_lens]
            self._cache[key] = (model, params, prompts)
        return self._cache[key]

    def serve_pool(self):
        from repro.serving import DecodeEngine
        key = ("serve_pool",)
        if key not in self._cache:
            s = self.cfg.serve
            model, params, prompts = self._serve_setup()
            eng = DecodeEngine(model, params, slots=s.slots,
                               cache_len=s.cache_len,
                               flush_tokens=s.flush_tokens)
            # staggered admission: half up front, the rest interleaved
            # with steps so freed slots get reused
            rids, done = [], []
            up_front = max(1, len(prompts) // 2)
            for p, g in zip(prompts[:up_front], s.gens[:up_front]):
                rids.append(eng.submit(p, g))
            for p, g in zip(prompts[up_front:], s.gens[up_front:]):
                done += eng.step()
                rids.append(eng.submit(p, g))
            done += eng.run_until_idle()
            got = {c.request_id: c.tokens for c in done}
            self._cache[key] = {
                f"tokens[{i}]": np.asarray(got[rid], np.float32)
                for i, rid in enumerate(rids)}
        return self._cache[key]

    def serve_isolated(self):
        from repro.serving import greedy_decode
        key = ("serve_iso",)
        if key not in self._cache:
            s = self.cfg.serve
            model, params, prompts = self._serve_setup()
            out = {}
            for i, (p, g) in enumerate(zip(prompts, s.gens)):
                logits, cache = jax.jit(
                    lambda pr, b: model.prefill(
                        pr, b, cache_len=s.cache_len))(
                    params, {"tokens": jnp.asarray(np.asarray(p)[None])})
                tok0 = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
                toks, _, _ = greedy_decode(model, params, cache, tok0,
                                           g - 1)
                out[f"tokens[{i}]"] = np.concatenate(
                    [np.asarray(tok0)[0], np.asarray(toks)[0]]).astype(
                    np.float32)
            self._cache[key] = out
        return self._cache[key]
