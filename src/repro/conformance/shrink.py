"""Greedy config shrinking: given a config that violates an oracle,
walk ``space.shrink_candidates`` (one field toward the default point
per candidate, structural axes first) and accept the FIRST candidate
that still violates; restart from it until no candidate violates or
the eval budget runs out. First-improvement greedy is the right trade
here: every candidate evaluation is a full differential run, so we buy
progress per eval rather than scanning the whole neighbourhood.

A candidate counts only if it is valid AND the oracle still applies to
it (shrinking must not escape the oracle's domain — dropping the mesh
axis "fixes" a block-sharding violation vacuously). A candidate whose
differential run CRASHES with a different outcome is skipped: we shrink
the divergence we found, not whatever else small configs can break.
"""
from __future__ import annotations

from typing import Tuple

from .space import ConfPoint, invalid_reason, shrink_candidates


def _violates(oracle, cfg: ConfPoint) -> bool:
    from .harness import Harness
    try:
        return bool(oracle.check(Harness(cfg)))
    except Exception:
        return False


def shrink(cfg: ConfPoint, oracle, *,
           budget: int = 40) -> Tuple[ConfPoint, int]:
    """Minimal violating config for ``oracle``, starting from ``cfg``
    (assumed violating). Returns ``(minimal, evals_spent)``."""
    current = cfg
    evals = 0
    improved = True
    while improved and evals < budget:
        improved = False
        for cand in shrink_candidates(current):
            if evals >= budget:
                break
            if cand == current or invalid_reason(cand) is not None:
                continue
            if oracle.applies(cand) is not None:
                continue
            evals += 1
            if _violates(oracle, cand):
                current = cand
                improved = True
                break
    return current, evals
