"""The oracle registry: every engine-equivalence invariant the repo's
hand-written tests assert, lifted into a named ``Oracle`` with an
explicit equivalence relation and an applicability predicate over
``ConfPoint``s. The differential runner executes a config through every
applicable oracle; the docs/ARCHITECTURE.md invariants table and this
registry must stay in sync (docs/TESTING.md describes the workflow).

| oracle                  | engines compared                 | relation |
|-------------------------|----------------------------------|----------|
| fused_vs_host           | make_fl_loop scan vs host rounds | bit-exact|
| pallas_vs_xla           | flat pallas vs flat xla backend  | ≤1e-5    |
| vmap_vs_flat            | legacy per-client vs flat engine | ≤1e-5    |
| telemetry_on_off        | telemetry=True vs None           | bit-exact|
| compression_none_inert  | inert spec vs no spec            | bit-exact|
| fault_free_tail         | sync_iid preset vs scenario=None | bit-exact|
| resume_vs_uninterrupted | ckpt save/restore mid-run vs not | bit-exact|
| block_vs_replicated     | block shard_map vs un-meshed     | ≤1e-5    |
| serve_pool_vs_isolated  | continuous batching vs isolated  | tokens ==|
| kernel:<ns>             | pallas-interpret vs jnp ref      | per-cell |
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from .harness import Harness, diff_trajectories
from .kernels import NAMESPACES, cells_for, check_cell
from .space import ConfPoint


@dataclass(frozen=True)
class Oracle:
    name: str
    description: str
    relation: str                 # "bitexact" | "allclose" | "per-cell"
    tol: float
    applies: Callable[[ConfPoint], Optional[str]]  # None = applicable
    run: Callable[[Harness], List[str]]            # violation strings

    def check(self, harness: Harness) -> List[str]:
        return [f"[{self.name}] {v}" for v in self.run(harness)]


def _scn_of(cfg: ConfPoint):
    from repro.federation import get_scenario
    if cfg.scenario is None:
        return None
    ov = {}
    if cfg.robust_agg is not None:
        ov["robust_agg"] = cfg.robust_agg
    if cfg.quorum is not None:
        ov["quorum"] = cfg.quorum
    return get_scenario(cfg.scenario, **ov)


def _always(cfg: ConfPoint) -> Optional[str]:
    return None


def _needs_plain_sync(cfg: ConfPoint) -> Optional[str]:
    if cfg.scenario is not None:
        return "legacy per-client engine only covers scenario=None"
    if cfg.compression != "none" or cfg.error_feedback:
        return "compression requires the flat engine"
    return None


def _inert_compression_only(cfg: ConfPoint) -> Optional[str]:
    if cfg.compression != "none" or cfg.error_feedback:
        return "config's compression is already active"
    return None


def _scenario_free_only(cfg: ConfPoint) -> Optional[str]:
    if cfg.scenario is not None:
        return "legacy-tail comparison needs scenario=None as baseline"
    return None


def _multi_round_only(cfg: ConfPoint) -> Optional[str]:
    if cfg.rounds < 2:
        return "resume needs rounds >= 2"
    return None


def _mesh_ok(cfg: ConfPoint) -> Optional[str]:
    import jax
    if not cfg.mesh:
        return "config has no mesh axis"
    if jax.device_count() < 8:
        return "needs >= 8 devices"
    if cfg.compression != "none" or cfg.error_feedback:
        return "block path compared uncompressed only (int8 tie-flips)"
    scn = _scn_of(cfg)
    if scn is not None:
        if scn.faulty or scn.robust or scn.quorum > 0:
            return "block_sharded rejects faults/robust/quorum"
        if scn.bandwidth_heterogeneous:
            return "bandwidth ladder excluded from the block oracle"
    return None


def _serve_only(cfg: ConfPoint) -> Optional[str]:
    if cfg.serve is None:
        return "config has no serve section"
    return None


def _fused_run(h: Harness) -> List[str]:
    # scenario-free, fused and host rounds lower to the identical
    # program (shared flat_body) — bit for bit. Scenario machinery
    # (fault masks, async buffer conds) re-fuse differently inside a
    # scan than in a per-round jit, drifting reductions at f32 eps.
    bit = h.cfg.scenario is None
    return diff_trajectories(h.host("xla"), h.fused("xla"),
                             bitexact=bit, tol=0.0 if bit else 1e-5)


def _telemetry_run(h: Harness) -> List[str]:
    a, b = h.host("xla"), h.host("xla", telemetry=True)
    state_keys = sorted(k for k in set(a) | set(b)
                        if not k.startswith("met."))
    met_keys = sorted(k for k in set(a) & set(b)
                      if k.startswith("met."))
    return (diff_trajectories(a, b, bitexact=True, keys=state_keys)
            + diff_trajectories(a, b, bitexact=False, tol=1e-5,
                                keys=met_keys))


def _kernel_oracle(ns: str) -> Oracle:
    cells = cells_for(ns)

    def run(h: Harness) -> List[str]:
        # one seed-selected cell per config: cheap per run, full matrix
        # coverage across fuzz seeds (the parametrized test sweeps all)
        cell = cells[h.cfg.seed % len(cells)]
        return check_cell(cell, seed=h.cfg.seed)

    return Oracle(
        name=f"kernel:{ns}",
        description=f"{ns} pallas-interpret == jnp ref on one "
                    f"seed-selected matrix cell",
        relation="per-cell", tol=0.0, applies=_always, run=run)


ORACLES: Dict[str, Oracle] = {o.name: o for o in [
    Oracle("fused_vs_host",
           "R-round fused lax.scan == R host-loop rounds: bit for bit "
           "scenario-free; ≤1e-5 under scenario machinery (fault/async "
           "branches re-fuse differently inside the scan)",
           "bitexact", 0.0, _always, _fused_run),
    Oracle("pallas_vs_xla",
           "flat engine, pallas-interpret kernels vs pure-XLA math",
           "allclose", 1e-5, _always,
           lambda h: diff_trajectories(h.host("xla"), h.host("pallas"),
                                       bitexact=False, tol=1e-5)),
    Oracle("vmap_vs_flat",
           "legacy per-client (vmapped tree) engine vs packed flat "
           "engine",
           "allclose", 1e-5, _needs_plain_sync,
           lambda h: diff_trajectories(h.tree_engine(), h.host("xla"),
                                       bitexact=False, tol=1e-5)),
    Oracle("telemetry_on_off",
           "in-scan telemetry reads the trajectory, never perturbs it: "
           "state bit-exact; shared metric rows ≤1e-5 (extra telemetry "
           "ops can reorder XLA fusions of the metric reductions)",
           "bitexact", 0.0, _always, _telemetry_run),
    Oracle("compression_none_inert",
           "an inert CompressionSpec (kind=none, no EF) lowers to the "
           "exact no-compression program",
           "bitexact", 0.0, _inert_compression_only,
           lambda h: diff_trajectories(h.host("xla"),
                                       h.host("xla",
                                              compression="none"),
                                       bitexact=True)),
    Oracle("fault_free_tail",
           "the sync_iid preset (zero fault rates, mean agg, no "
           "quorum) takes the exact legacy round tail",
           "bitexact", 0.0, _scenario_free_only,
           lambda h: diff_trajectories(h.host("xla"),
                                       h.host("xla",
                                              scenario="sync_iid"),
                                       bitexact=True)),
    Oracle("resume_vs_uninterrupted",
           "checkpoint save/restore at R//2 continues the exact "
           "uninterrupted trajectory",
           "bitexact", 0.0, _multi_round_only,
           lambda h: diff_trajectories(h.host("xla"), h.resume("xla"),
                                       bitexact=True)),
    Oracle("block_vs_replicated",
           "block-level shard_map fused loop vs the un-meshed fused "
           "loop (final packed params)",
           "allclose", 1e-5, _mesh_ok,
           lambda h: diff_trajectories(h.replicated(), h.block(),
                                       bitexact=False, tol=1e-5,
                                       keys=["state.P"])),
    Oracle("serve_pool_vs_isolated",
           "continuous-batching decode == one-request-at-a-time greedy "
           "decode, token for token",
           "bitexact", 0.0, _serve_only,
           lambda h: diff_trajectories(h.serve_pool(),
                                       h.serve_isolated(),
                                       bitexact=True)),
] + [_kernel_oracle(ns) for ns in NAMESPACES]}


def applicable(cfg: ConfPoint, names=None) -> List[Oracle]:
    """The oracles a config must satisfy (optionally filtered by
    name)."""
    pool = ([ORACLES[n] for n in names] if names
            else list(ORACLES.values()))
    return [o for o in pool if o.applies(cfg) is None]
