"""The checked-in regression corpus: a fixed set of seeded configs
(``corpus.json`` next to this module) that runs green through every
applicable oracle as a tier-1 test (tests/test_conformance.py). The
corpus is the conformance plane's memory — any engine change that
breaks an equivalence on ANY of these configs fails CI deterministically
without needing a lucky fuzz seed.

Regenerate (after deliberately widening the space) with:

    python -m repro.conformance.corpus --regen

which re-samples the standard seed block and re-appends the hand-picked
structural entries (mesh, serving, resume-heavy) that random sampling
only hits occasionally. The file is committed; regeneration must be a
reviewed change, not a CI side effect.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Tuple

from .space import ConfPoint, ServePoint, invalid_reason, sample

CORPUS_PATH = os.path.join(os.path.dirname(__file__), "corpus.json")

# seeds sampled into the corpus (mesh/serve axes off: those engines get
# dedicated hand-picked entries below so corpus cost stays bounded)
_SAMPLED_SEEDS = tuple(range(22))

# hand-picked structural entries the sampler only hits by luck
_PINNED: Tuple[ConfPoint, ...] = (
    # 8-device mesh: block shard_map vs replicated (+ all train oracles)
    ConfPoint(seed=101, rounds=2, clients=4, local_steps=2, batch=2,
              dim=24, bf16_dim=6, mesh=True),
    ConfPoint(seed=102, rounds=2, clients=8, local_steps=1, batch=1,
              dim=33, scenario="dirichlet_stragglers", mesh=True),
    # serving: continuous batching vs isolated decode
    ConfPoint(seed=103, serve=ServePoint(prompt_lens=(8, 5),
                                         gens=(4, 6), slots=2,
                                         cache_len=32, flush_tokens=4,
                                         seed=7)),
    ConfPoint(seed=104, serve=ServePoint(prompt_lens=(12, 7, 3),
                                         gens=(5, 3, 6), slots=2,
                                         cache_len=24, flush_tokens=3,
                                         seed=11)),
    # resume + adaptive server opt + EF compression, multi-round
    ConfPoint(seed=105, rounds=4, clients=3, local_steps=2, batch=2,
              dim=33, bf16_dim=18, server_opt="fedyogi",
              scenario="zipf_async", compression="int8",
              error_feedback=True),
    ConfPoint(seed=106, rounds=3, clients=4, local_steps=3, batch=1,
              dim=5, scenario="byzantine_async", robust_agg="trimmed",
              quorum=2, compression="topk", k_frac=0.5,
              error_feedback=True),
)


def generate() -> List[ConfPoint]:
    cfgs = [sample(s, allow_mesh=False, allow_serve=False)
            for s in _SAMPLED_SEEDS]
    cfgs += list(_PINNED)
    for c in cfgs:
        bad = invalid_reason(c)
        assert bad is None, f"corpus entry {c.label()} invalid: {bad}"
    return cfgs


def load() -> List[ConfPoint]:
    with open(CORPUS_PATH) as f:
        data = json.load(f)
    return [ConfPoint.from_dict(d) for d in data["configs"]]


def write(cfgs: List[ConfPoint], path: str = CORPUS_PATH) -> None:
    data = {"version": 1, "configs": [c.to_dict() for c in cfgs]}
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.conformance.corpus")
    p.add_argument("--regen", action="store_true",
                   help="rewrite corpus.json from the generator")
    args = p.parse_args(argv)
    if args.regen:
        cfgs = generate()
        write(cfgs)
        print(f"wrote {len(cfgs)} configs to {CORPUS_PATH}")
        return 0
    cfgs = load()
    for c in cfgs:
        print(c.label(), json.dumps(dataclasses.asdict(c), default=str))
    print(f"{len(cfgs)} configs")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
