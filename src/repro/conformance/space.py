"""Config space for the conformance plane: the typed point, a seeded
sampler over the FLConfig × Scenario × compression × faults × mesh ×
engine cross-product, validity constraints, and the shrink ordering.

A ``ConfPoint`` is the *entire* input of a differential run — problem
shapes (quadratic dim, bf16 tail leaf, clients, local steps, rounds),
the federation scenario and its fault/robust overrides, the delta-
compression spec, the mesh axis, and an optional serving section
(``ServePoint``). It is frozen, hashable, and JSON-round-trippable
(``to_dict``/``from_dict``), which is what makes fuzz failures
replayable artifacts (repro.conformance.replay).

Everything here deliberately stays *small*: the oracles assert
equivalences (bit-exact or ≤tol) between engines, which tiny shapes
already witness — divergence amplitude is not the point, divergence
EXISTENCE is. The pools include lane-unaligned dims (5, 33, 257-ish)
on purpose: padding/tail-mask handling is where flat-buffer engines
historically break.

The shrink ordering (``shrink_candidates``) moves one field at a time
toward ``DEFAULT`` — fewer rounds, fewer clients, fewer steps, smaller
dims, then axis-by-axis config simplification — which is what the
greedy shrinker (repro.conformance.shrink) walks to produce a minimal
repro.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# sampler pools — every value must keep a single oracle run in the
# sub-second-compile regime on CPU
ROUNDS_POOL = (1, 2, 3, 4)
CLIENTS_POOL = (2, 3, 4, 8)
STEPS_POOL = (1, 2, 3)
BATCH_POOL = (1, 2, 4)
DIM_POOL = (5, 8, 24, 33)          # incl. lane-unaligned dims
BF16_POOL = (0, 6, 18)             # extra bf16 leaf width (0 = f32-only)
SERVER_OPTS_POOL = ("fedavg", "fedavg", "fedavg", "fedavgm", "fedadam",
                    "fedyogi")
SCENARIO_POOL = (None, None, None, "sync_iid", "sync_dirichlet",
                 "size_weighted", "dirichlet_stragglers", "cyclic_hetero",
                 "zipf_async", "bandwidth_tiered", "dirichlet_dropouts",
                 "byzantine_async")
COMPRESSION_POOL = ("none", "none", "none", "int8", "topk")
ROBUST_POOL = (None, None, None, "clip", "trimmed", "median")
SERVE_PROMPTS_POOL = ((8, 5), (12, 7, 3), (6,))


@dataclass(frozen=True)
class ServePoint:
    """Optional serving section: a continuous-batching decode workload
    checked against one-request-at-a-time isolated decode (token
    equality). ``arch`` is always reduced() to smoke scale."""
    arch: str = "tinyllama-1.1b"
    slots: int = 2
    cache_len: int = 32
    flush_tokens: int = 4
    prompt_lens: Tuple[int, ...] = (8, 5)
    gens: Tuple[int, ...] = (4, 6)
    seed: int = 0


@dataclass(frozen=True)
class ConfPoint:
    """One sampled configuration. Field defaults ARE the shrink target:
    ``ConfPoint()`` is the smallest, most vanilla config the space
    contains."""
    seed: int = 0                  # data/init seed
    rounds: int = 1                # R
    clients: int = 2               # C (cohort per round)
    local_steps: int = 1           # K
    batch: int = 1                 # rows per micro-batch
    dim: int = 5                   # quadratic dim D
    bf16_dim: int = 0              # width of the extra bf16 leaf
    server_opt: str = "fedavg"
    weighted: bool = False
    scenario: Optional[str] = None          # preset name
    robust_agg: Optional[str] = None        # override onto the scenario
    quorum: Optional[int] = None            # override onto the scenario
    compression: str = "none"
    k_frac: float = 0.25
    error_feedback: bool = False
    mesh: bool = False             # 8-device (4, 2) mesh oracles
    serve: Optional[ServePoint] = None

    # ---- JSON round trip -------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.serve is not None:
            d["serve"] = dataclasses.asdict(self.serve)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ConfPoint":
        d = dict(d)
        sv = d.get("serve")
        if sv is not None:
            sv = dict(sv)
            for k in ("prompt_lens", "gens"):
                if k in sv:
                    sv[k] = tuple(sv[k])
            d["serve"] = ServePoint(**sv)
        return cls(**d)

    def label(self) -> str:
        """Short human id for logs/artifact filenames."""
        bits = [f"s{self.seed}", f"R{self.rounds}", f"C{self.clients}",
                f"K{self.local_steps}", f"D{self.dim}"]
        if self.scenario:
            bits.append(self.scenario)
        if self.compression != "none" or self.error_feedback:
            bits.append(self.compression + ("+ef" if self.error_feedback
                                            else ""))
        if self.robust_agg:
            bits.append(self.robust_agg)
        if self.mesh:
            bits.append("mesh")
        if self.serve is not None:
            bits.append("serve")
        return "-".join(bits)


DEFAULT = ConfPoint()


# --------------------------------------------------------------- validity
def invalid_reason(cfg: ConfPoint) -> Optional[str]:
    """None if ``cfg`` is a runnable point; else why not. The sampler
    resamples invalid draws; the shrinker discards invalid candidates."""
    if cfg.rounds < 1 or cfg.clients < 2 or cfg.local_steps < 1 \
            or cfg.batch < 1 or cfg.dim < 2 or cfg.bf16_dim < 0:
        return "degenerate shapes"
    if cfg.rounds > 8 or cfg.clients > 16 or cfg.local_steps > 8 \
            or cfg.dim > 128 or cfg.bf16_dim > 64:
        return "shapes above the conformance budget"
    if cfg.compression not in ("none", "int8", "topk"):
        return f"unknown compression {cfg.compression!r}"
    if not 0.0 < cfg.k_frac <= 1.0:
        return f"k_frac {cfg.k_frac} outside (0, 1]"
    if cfg.scenario is not None:
        from repro.federation import SCENARIOS
        if cfg.scenario not in SCENARIOS:
            return f"unknown scenario {cfg.scenario!r}"
        if SCENARIOS[cfg.scenario].registered_hint is not None:
            return "fleet presets are out of conformance scope"
    if cfg.robust_agg is not None and cfg.robust_agg not in (
            "mean", "clip", "trimmed", "median"):
        return f"unknown robust_agg {cfg.robust_agg!r}"
    if cfg.scenario is None and (cfg.robust_agg is not None
                                 or cfg.quorum is not None):
        return "robust_agg/quorum overrides require a scenario"
    if cfg.quorum is not None and not 0 <= cfg.quorum <= cfg.clients:
        return "quorum outside [0, clients]"
    if cfg.server_opt not in ("fedavg", "fedavgm", "fedadam", "fedyogi"):
        return f"unknown server_opt {cfg.server_opt!r}"
    if cfg.mesh and cfg.clients % 4:
        return "mesh oracles shard clients 4-way: clients % 4 != 0"
    if cfg.serve is not None:
        s = cfg.serve
        if len(s.prompt_lens) != len(s.gens) or not s.prompt_lens:
            return "serve prompt_lens/gens length mismatch"
        if s.cache_len < max(s.prompt_lens) + max(s.gens):
            return "serve cache_len too small for prompt+gen"
        if s.slots < 1 or s.flush_tokens < 1:
            return "serve slots/flush_tokens < 1"
    return None


# ---------------------------------------------------------------- sampler
def sample(seed: int, *, allow_mesh: bool = True,
           allow_serve: bool = True) -> ConfPoint:
    """Deterministic draw: seed -> one VALID ConfPoint. The draw seed is
    recorded in ``ConfPoint.seed`` so the data/init randomness of the
    differential runs varies with the fuzz seed too."""
    rng = np.random.default_rng(np.uint64(seed))
    for attempt in range(64):
        cfg = _draw(rng, seed, allow_mesh=allow_mesh,
                    allow_serve=allow_serve)
        if invalid_reason(cfg) is None:
            return cfg
    # the pools make an invalid draw rare; fall back to the default point
    return dataclasses.replace(DEFAULT, seed=seed)


def _draw(rng: np.random.Generator, seed: int, *, allow_mesh: bool,
          allow_serve: bool) -> ConfPoint:
    def pick(pool):
        return pool[int(rng.integers(len(pool)))]

    compression = pick(COMPRESSION_POOL)
    scenario = pick(SCENARIO_POOL)
    serve = None
    if allow_serve and rng.random() < 0.15:
        pl = pick(SERVE_PROMPTS_POOL)
        gens = tuple(int(g) for g in rng.integers(3, 8, len(pl)))
        serve = ServePoint(prompt_lens=pl, gens=gens,
                           cache_len=max(pl) + max(gens) + 8,
                           slots=int(rng.integers(1, 4)),
                           flush_tokens=int(rng.integers(2, 6)),
                           seed=seed % 1009)
    return ConfPoint(
        seed=seed,
        rounds=pick(ROUNDS_POOL),
        clients=pick(CLIENTS_POOL),
        local_steps=pick(STEPS_POOL),
        batch=pick(BATCH_POOL),
        dim=pick(DIM_POOL),
        bf16_dim=pick(BF16_POOL),
        server_opt=pick(SERVER_OPTS_POOL),
        weighted=bool(rng.random() < 0.2),
        scenario=scenario,
        robust_agg=(pick(ROBUST_POOL) if scenario is not None else None),
        quorum=(2 if (scenario is not None and rng.random() < 0.15)
                else None),
        compression=compression,
        k_frac=float(pick((0.25, 0.25, 0.5, 1.0))),
        error_feedback=bool(compression != "none" and rng.random() < 0.4),
        mesh=bool(allow_mesh and rng.random() < 0.12),
        serve=serve,
    )


# ---------------------------------------------------------------- shrink
def shrink_candidates(cfg: ConfPoint):
    """Yield one-field-toward-default neighbours, most-aggressive first
    per field. The greedy shrinker accepts the first candidate that
    still violates the oracle and restarts, so ordering = priority:
    structural axes (serve/mesh/scenario/compression) first — removing a
    whole axis shrinks the repro most — then the integer shape ladder.
    """
    def rep(**kw):
        return dataclasses.replace(cfg, **kw)

    if cfg.serve is not None:
        s = cfg.serve
        if len(s.prompt_lens) > 1:
            yield rep(serve=dataclasses.replace(
                s, prompt_lens=s.prompt_lens[:1], gens=s.gens[:1]))
        if s.slots > 1:
            yield rep(serve=dataclasses.replace(s, slots=1))
        if s.gens and max(s.gens) > 3:
            yield rep(serve=dataclasses.replace(
                s, gens=tuple(min(g, 3) for g in s.gens)))
    if cfg.mesh:
        yield rep(mesh=False)
    if cfg.error_feedback:
        yield rep(error_feedback=False)
    if cfg.compression != DEFAULT.compression:
        yield rep(compression="none", error_feedback=False)
    if cfg.k_frac != DEFAULT.k_frac:
        yield rep(k_frac=DEFAULT.k_frac)
    if cfg.robust_agg is not None:
        yield rep(robust_agg=None)
    if cfg.quorum is not None:
        yield rep(quorum=None)
    if cfg.scenario is not None:
        yield rep(scenario=None, robust_agg=None, quorum=None)
        if cfg.scenario != "sync_iid":
            yield rep(scenario="sync_iid")
    if cfg.weighted:
        yield rep(weighted=False)
    if cfg.server_opt != DEFAULT.server_opt:
        yield rep(server_opt=DEFAULT.server_opt)
    for field, pool in (("rounds", ROUNDS_POOL),
                        ("clients", CLIENTS_POOL),
                        ("local_steps", STEPS_POOL),
                        ("batch", BATCH_POOL),
                        ("dim", DIM_POOL),
                        ("bf16_dim", BF16_POOL)):
        cur = getattr(cfg, field)
        lo = getattr(DEFAULT, field)
        for v in sorted({v for v in pool if lo <= v < cur}):
            yield rep(**{field: v})

    if cfg.serve is not None and cfg.rounds == DEFAULT.rounds:
        # last resort for train-oracle failures that kept a serve
        # section around: drop it entirely (serve-oracle failures keep
        # it — the shrinker filters candidates by oracle applicability)
        yield rep(serve=None)
