"""Table-driven kernel parity matrix: every Pallas kernel namespace
swept pallas-interpret vs its pure-jnp reference across a dtype × shape
grid. One ``KernelCell`` = one (kernel, dtype, shape) point returning
``(got, want, rtol, atol)``; the same table backs both the parametrized
test (tests/test_conformance_kernels.py) and the per-namespace
conformance oracles (``kernel:<ns>`` in repro.conformance.oracles), so
a planted kernel perturbation trips the fuzzer through exactly the gate
the ROADMAP's XLA-fallback parity item describes.

Tolerances mirror the hand-written sweeps in tests/test_kernels.py,
test_compression.py and test_telemetry.py — the matrix widens their
coverage, it does not relax it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np

NAMESPACES = ("delta_sgd", "compress", "robust_agg", "telemetry",
              "flash_attention", "mamba2_scan")


@dataclass(frozen=True)
class KernelCell:
    ns: str                       # kernel namespace
    cid: str                      # cell id, unique within the namespace
    run: Callable[[int], Tuple]   # seed -> (got, want, rtol, atol)

    @property
    def key(self) -> str:
        return f"{self.ns}:{self.cid}"


def _rng(seed):
    return np.random.default_rng(np.uint64(seed) + 101)


# ---------------------------------------------------------------- delta_sgd
def _delta_norms(shape, dtype):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.delta_sgd import delta_sgd as dk
        from repro.kernels.delta_sgd import ref as dref
        r = _rng(seed)
        g = jnp.asarray(r.normal(size=shape), dtype)
        gp = jnp.asarray(r.normal(size=shape), dtype)
        got = jnp.stack(dk.norms(g, gp, interpret=True))
        want = jnp.stack(dref.norms_ref(g, gp))
        return got, want, 3e-3, 0.0
    return run


def _delta_apply(shape, dtype):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.delta_sgd import delta_sgd as dk
        from repro.kernels.delta_sgd import ref as dref
        r = _rng(seed)
        p = jnp.asarray(r.normal(size=shape), dtype)
        g = jnp.asarray(r.normal(size=shape), dtype)
        return (dk.apply_update(p, g, 0.37, interpret=True),
                dref.apply_ref(p, g, 0.37), 2e-2, 2e-2)
    return run


def _delta_batched_norms(C, N):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.delta_sgd import delta_sgd as dk
        from repro.kernels.delta_sgd import ref as dref
        r = _rng(seed)
        g = jnp.asarray(r.normal(size=(C, N)), jnp.float32)
        gp = g * -0.3 + 0.1
        got = jnp.stack(dk.batched_norms(g, gp, interpret=True))
        want = jnp.stack(dref.batched_norms_ref(g, gp))
        return got, want, 1e-5, 0.0
    return run


def _delta_batched_apply(C, N, masked):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.delta_sgd import delta_sgd as dk
        from repro.kernels.delta_sgd import ref as dref
        r = _rng(seed)
        p = jnp.asarray(r.normal(size=(C, N)), jnp.float32)
        g = jnp.asarray(r.normal(size=(C, N)), jnp.float32)
        eta = jnp.asarray(r.uniform(0.01, 1.0, C), jnp.float32)
        mask = (jnp.asarray(r.integers(0, 2, N), jnp.float32)
                if masked else None)
        return (dk.batched_apply(p, g, eta, mask=mask, interpret=True),
                dref.batched_apply_ref(p, g, eta, mask=mask), 1e-5, 1e-6)
    return run


# ----------------------------------------------------------------- compress
def _compress(kind, C, chunks):
    def run(seed):
        import jax.numpy as jnp
        from repro.core.flat import LANES
        from repro.kernels.compress import compress as ck
        from repro.kernels.compress import ref as cr
        r = _rng(seed)
        x = jnp.asarray(r.normal(size=(C, chunks * LANES)), jnp.float32)
        if kind == "int8":
            q, s = ck.quantize_int8(x, interpret=True)
            qr, sr = cr.quantize_int8_ref(x)
            return (ck.dequantize_int8(q, s, interpret=True),
                    cr.dequantize_int8_ref(qr, sr), 1e-5, 1e-5)
        k = max(1, LANES // 4)
        return (ck.topk_mask(x, k, interpret=True),
                cr.topk_mask_ref(x, k), 0.0, 0.0)
    return run


# --------------------------------------------------------------- robust_agg
def _trimmed(C, N, t):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.robust_agg import ref as rr
        from repro.kernels.robust_agg import robust_agg as rk
        r = _rng(seed)
        x = jnp.asarray(r.normal(size=(C, N)), jnp.float32)
        return (rk.batched_trimmed_mean(x, t, interpret=True),
                rr.batched_trimmed_mean_ref(x, t), 1e-6, 1e-7)
    return run


# ---------------------------------------------------------------- telemetry
def _telemetry(which, n):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.telemetry import (lane_histogram,
                                             lane_histogram_ref,
                                             lane_quantiles,
                                             lane_quantiles_ref)
        r = _rng(seed)
        x = jnp.asarray(r.normal(size=n), jnp.float32)
        if which == "hist":
            from repro.telemetry import TelemetrySpec
            edges = jnp.asarray(TelemetrySpec(eta_bins=16).eta_edges())
            return (lane_histogram(jnp.abs(x), edges, interpret=True),
                    lane_histogram_ref(jnp.abs(x), edges), 0.0, 0.0)
        return (lane_quantiles(x, Q=11, interpret=True),
                lane_quantiles_ref(x, Q=11), 0.0, 0.0)
    return run


# ---------------------------------------------------------- flash_attention
def _flash(B, S, H, KV, hd, causal, window, dtype):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention)
        from repro.kernels.flash_attention.ref import attention_ref
        r = _rng(seed)
        q = jnp.asarray(r.normal(size=(B, S, H, hd)), dtype)
        k = jnp.asarray(r.normal(size=(B, S, KV, hd)), dtype)
        v = jnp.asarray(r.normal(size=(B, S, KV, hd)), dtype)
        got = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
        want = attention_ref(q, k, v, causal=causal, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        return got, want, tol, tol
    return run


# -------------------------------------------------------------- mamba2_scan
def _mamba2(B, S, H, P, G, N):
    def run(seed):
        import jax.numpy as jnp
        from repro.kernels.mamba2_scan.ops import ssd_scan
        from repro.kernels.mamba2_scan.ref import ssd_ref
        r = _rng(seed)
        x = jnp.asarray(r.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(r.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
        A_log = jnp.asarray(np.log(r.uniform(1, 16, (H,))), jnp.float32)
        Bm = jnp.asarray(r.normal(size=(B, S, G, N)), jnp.float32)
        Cm = jnp.asarray(r.normal(size=(B, S, G, N)), jnp.float32)
        y, h = ssd_scan(x, dt, A_log, Bm, Cm)
        yr, hr = ssd_ref(x, dt, A_log, Bm, Cm)
        return (jnp.concatenate([y.ravel(), h.ravel()]),
                jnp.concatenate([yr.ravel(), hr.ravel()]), 1e-3, 1e-4)
    return run


def _build_matrix():
    import jax.numpy as jnp
    cells = []
    for shape in ((7,), (257, 33), (1000,)):
        for dt, dn in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
            cells.append(KernelCell(
                "delta_sgd", f"norms-{'x'.join(map(str, shape))}-{dn}",
                _delta_norms(shape, dt)))
    for shape in ((5,), (130, 7)):
        for dt, dn in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
            cells.append(KernelCell(
                "delta_sgd", f"apply-{'x'.join(map(str, shape))}-{dn}",
                _delta_apply(shape, dt)))
    for C, N in ((3, 256), (4, 128)):
        cells.append(KernelCell("delta_sgd", f"bnorms-{C}x{N}",
                                _delta_batched_norms(C, N)))
    for C, N, masked in ((3, 256, False), (4, 128, True)):
        cells.append(KernelCell(
            "delta_sgd", f"bapply-{C}x{N}{'-mask' if masked else ''}",
            _delta_batched_apply(C, N, masked)))
    for kind in ("int8", "topk"):
        for C, chunks in ((2, 3), (3, 5)):
            cells.append(KernelCell("compress", f"{kind}-{C}x{chunks}",
                                    _compress(kind, C, chunks)))
    for C, N, t in ((5, 256, 1), (8, 128, 2)):
        cells.append(KernelCell("robust_agg", f"trimmed-{C}x{N}-t{t}",
                                _trimmed(C, N, t)))
    for which, n in (("hist", 257), ("hist", 64), ("quant", 77),
                     ("quant", 130)):
        cells.append(KernelCell("telemetry", f"{which}-{n}",
                                _telemetry(which, n)))
    for args in ((1, 64, 2, 2, 16, True, 16),
                 (1, 128, 4, 1, 64, True, None),     # MQA
                 (2, 128, 4, 4, 32, False, None)):   # bidirectional
        for dt, dn in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
            B, S, H, KV, hd, causal, window = args
            cells.append(KernelCell(
                "flash_attention",
                f"{B}x{S}x{H}x{KV}x{hd}-{'c' if causal else 'b'}"
                f"{f'-w{window}' if window else ''}-{dn}",
                _flash(*args, dt)))
    for args in ((1, 64, 2, 16, 1, 8), (2, 64, 4, 32, 1, 16)):
        cells.append(KernelCell(
            "mamba2_scan", "ssd-" + "x".join(map(str, args)),
            _mamba2(*args)))
    return tuple(cells)


KERNEL_MATRIX: Tuple[KernelCell, ...] = _build_matrix()


def cells_for(ns: str) -> Tuple[KernelCell, ...]:
    return tuple(c for c in KERNEL_MATRIX if c.ns == ns)


def check_cell(cell: KernelCell, seed: int = 0):
    """Violation strings for one cell (empty = parity holds)."""
    got, want, rtol, atol = cell.run(seed)
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    if g.shape != w.shape:
        return [f"{cell.key}: shape {g.shape} vs {w.shape}"]
    if rtol == 0.0 and atol == 0.0:
        ok = np.array_equal(g, w, equal_nan=True)
    else:
        ok = np.allclose(g, w, rtol=rtol, atol=atol, equal_nan=True)
    if ok:
        return []
    return [f"{cell.key}: max|Δ|={float(np.nanmax(np.abs(g - w))):.3e} "
            f"(rtol={rtol:g} atol={atol:g})"]
