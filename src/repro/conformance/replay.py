"""Replay a conformance violation artifact.

    python -m repro.conformance.replay artifact.json
    python -m repro.conformance.replay artifact.json --ignore-mutation

Reconstructs the minimal config from the artifact, re-installs the
recorded engine mutation (if any — that is what makes fuzzer-teeth
failures reproducible across processes), and re-runs the one oracle
that failed. Exit 1 iff the violation reproduces; ``--ignore-mutation``
re-runs against the pristine engines, which for a mutation-induced
artifact must exit 0 — the control that proves the defect lives in the
planted perturbation, not the conformance plane.
"""
from __future__ import annotations

import argparse


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.conformance.replay",
        description="replay a conformance violation artifact")
    p.add_argument("artifact", help="violation JSON written by the "
                                    "fuzzer/runner")
    p.add_argument("--ignore-mutation", action="store_true",
                   help="replay on pristine engines even if the "
                        "artifact records a planted mutation")
    p.add_argument("--original", action="store_true",
                   help="replay the pre-shrink config instead of the "
                        "minimal one")
    return p


def run(argv=None) -> int:
    from .harness import Harness
    from .mutation import active_mutation
    from .oracles import ORACLES
    from .runner import read_artifact
    from .space import invalid_reason

    args = build_parser().parse_args(argv)
    v = read_artifact(args.artifact)
    cfg = v.shrunk_from if args.original else v.config
    oracle = ORACLES[v.oracle]
    bad = invalid_reason(cfg)
    if bad is not None:
        print(f"artifact config is invalid: {bad}")
        return 2
    why_not = oracle.applies(cfg)
    if why_not is not None:
        print(f"oracle {oracle.name} does not apply: {why_not}")
        return 2
    mutation = None if args.ignore_mutation else v.mutation
    print(f"replaying {oracle.name} on {cfg.label()}"
          + (f" with mutation={mutation}" if mutation else ""))
    with active_mutation(mutation):
        try:
            messages = oracle.check(Harness(cfg))
        except Exception as e:  # noqa: BLE001 - crash counts as repro
            messages = [f"[{oracle.name}] crashed: "
                        f"{type(e).__name__}: {e}"]
    if messages:
        print("violation REPRODUCES:")
        for m in messages:
            print(f"  {m}")
        return 1
    print("violation does NOT reproduce (engines agree)")
    return 0


if __name__ == "__main__":
    raise SystemExit(run())
