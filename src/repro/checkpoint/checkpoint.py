"""Checkpointing: save/restore any pytree (params, FLState, decode caches)
to a directory of .npy files + a JSON treedef manifest. No external deps;
atomic via tmp-dir rename; keeps the last N checkpoints.

    save(path, state, step=12)
    state, step = restore(path, like=state_template)
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out.append((key or "leaf", leaf))
    return out


def save(ckpt_dir: str, tree: Any, *, step: int = 0, keep: int = 3) -> str:
    """Write checkpoint ``step``; returns its directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(_flatten_with_paths(tree)):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype == jax.numpy.bfloat16:  # numpy can't store bf16
            arr = arr.astype(np.float32)
        fname = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "dtype": logical_dtype,
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes/dtypes verified)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(like_leaves) != len(leaves_meta):
        raise ValueError(f"checkpoint has {len(leaves_meta)} leaves, "
                         f"template has {len(like_leaves)}")
    leaves = []
    for meta, tmpl in zip(leaves_meta, like_leaves):
        arr = np.load(os.path.join(d, meta["file"]))
        if list(arr.shape) != list(np.shape(tmpl)):
            raise ValueError(f"shape mismatch at {meta['key']}: "
                             f"{arr.shape} vs {np.shape(tmpl)}")
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_params(ckpt_dir: str, like_params: Any, *,
                   step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore just the PARAMS subtree from a checkpoint holding either
    bare params or a full FLState (the training driver saves the
    latter): a template leaf with manifest key ``k`` matches ``k`` or
    ``params/k``, so a serving driver can load training checkpoints
    without reconstructing the optimizer/scenario state."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    tmpl_leaves, treedef = jax.tree_util.tree_flatten(like_params)
    leaves = []
    for (key, tmpl) in _flatten_with_paths(like_params):
        meta = by_key.get(key) or by_key.get("params/" + key)
        if meta is None:
            raise KeyError(
                f"param leaf {key!r} not in checkpoint step {step} "
                f"(neither bare nor under 'params/'); sample keys: "
                f"{sorted(by_key)[:4]}")
        arr = np.load(os.path.join(d, meta["file"]))
        if list(arr.shape) != list(np.shape(tmpl)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {np.shape(tmpl)}")
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    assert len(leaves) == len(tmpl_leaves)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
