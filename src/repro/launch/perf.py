import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (§Perf): lower a (arch × shape) pair under the
baseline (paper-faithful) configuration and under beyond-paper optimization
variants, and report the calibrated roofline terms side by side.

  PYTHONPATH=src python -m repro.launch.perf --pair zamba2-7b/train_4k \
      --variants baseline,seq_shard

Variants:
  baseline     paper-faithful configuration (reuses the sweep artifact)
  seq_shard    Megatron-SP analog: residual stream sharded over `model`
               along sequence (row-parallel epilogues -> reduce-scatter)
  softmax_bf16 bf16 softmax-weight storage between the attention matmuls
  quant_kv     int8 KV cache entries + f16 scales (decode shapes)
  capacity1    MoE capacity factor 1.25 -> 1.0
  flat_fed     flat-parameter Δ-SGD engine (train shapes): client params
               packed into one (C, N) buffer for the whole local scan
  flat_fed_sharded
               flat engine with the (C, N) buffer mesh-sharded per
               FederationSpec.flat_spec (clients over client axes, N over
               fsdp/tp axes); the compiled HLO is asserted to contain NO
               rematerialization of the full (C, N) buffer
               (repro.sharding.hlo.assert_flat_buffer_sharded)
  flat_fed_hetero
               sharded flat engine under the `dirichlet_stragglers`
               scenario: per-client step counts K_c ≤ K drawn each round
               and lowered as η=0 lane masks (repro.federation); HLO
               assertion as above
  flat_fed_async
               sharded flat engine under the `zipf_async` scenario:
               FedBuff-style staleness-weighted delta buffer in
               FLState.buffer; HLO assertion as above
  flat_fed_compressed
               sharded flat engine with int8 delta compression + EF21
               error feedback under the `bandwidth_tiered` scenario
               (repro.compression): client deltas are compressed
               chunk-locally BEFORE the client-mean psum. Reports the
               analytic wire-bytes / compression-ratio telemetry and
               runs BOTH HLO assertions (sharded buffer + no
               full-precision delta across the client boundary, the
               latter skipped with a note when the production spec
               leaves < 2 clients per client shard)
  flat_fed_rounds_fused
               round-fused training loop (repro.core.fed_loop): 8
               rounds as ONE jitted lax.scan on the sharded flat
               engine, donated carry; sharded-buffer HLO assertion on
               the scanned computation
  flat_fed_faults
               chaos round (repro.federation.faults): deterministic
               dropouts + NaN corruption + byzantine scaling under
               trimmed robust aggregation with quorum, on int8+EF
               compression; both HLO assertions as flat_fed_compressed
"""
import argparse
import json
import time

import jax.numpy as jnp

from repro import roofline
from repro.compression import CompressionSpec
from repro.configs import FLConfig, INPUT_SHAPES, get_config
from repro.federation import get_scenario
from repro.launch.dryrun import _at_depth, _calib_depths, _compile_step
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import federation_kind
from repro.sharding.spec import get_federation_spec

VARIANT_KNOBS = {
    "baseline": {},
    "seq_shard": {"seq_shard": True},
    "softmax_bf16": {"softmax_bf16": True},
    "seq_shard+softmax_bf16": {"seq_shard": True, "softmax_bf16": True},
    "quant_kv": {"quant_kv": True},
    "cache_seq_shard": {"cache_seq_shard": True},
    "quant_kv+cache_seq_shard": {"quant_kv": True, "cache_seq_shard": True},
    "capacity1": {"capacity": 1.0},
    "expert_2d": {"expert_2d": True},
    "expert_2d+capacity1": {"expert_2d": True, "capacity": 1.0},
    # flat-parameter Δ-SGD engine: packed (C, N) client-state buffer,
    # 2 fused update ops per local step instead of per-leaf/per-client
    "flat_fed": {"flat_fed": True},
    # mesh-native flat engine: the (C, N) buffer stays sharded per
    # FederationSpec.flat_spec end to end (shard_map kernel pair + psum
    # dual-norm reduction); compiled HLO is checked for remat copies
    "flat_fed_sharded": {"flat_fed": True, "flat_sharded": True},
    # federation scenarios (repro.federation) on the sharded flat engine:
    # heterogeneous per-client step counts lowered as η=0 lane masks
    # (dirichlet_stragglers), and FedBuff-style async buffered
    # aggregation with staleness-weighted merges (zipf_async). Both keep
    # the 2-launch/step invariant and the sharded-buffer HLO assertion.
    "flat_fed_hetero": {"flat_fed": True, "flat_sharded": True,
                        "scenario": "dirichlet_stragglers"},
    "flat_fed_async": {"flat_fed": True, "flat_sharded": True,
                       "scenario": "zipf_async"},
    # delta compression (repro.compression): int8 + EF21 client deltas
    # under the bandwidth_tiered scenario, compressed shard-locally
    # before the client-mean psum; wire-bytes/compression-ratio
    # telemetry lands in the perf artifact next to the roofline terms.
    # error_feedback=True matters: it allocates FLState.ef, so the
    # compiled program (and both HLO assertions) covers the EF sharding
    "flat_fed_compressed": {"flat_fed": True, "flat_sharded": True,
                            "scenario": "bandwidth_tiered",
                            "compression": CompressionSpec(
                                kind="int8", error_feedback=True)},
    # round-fused training loop (repro.core.fed_loop): 8 rounds as one
    # lax.scan on the sharded flat engine — proves the fused program
    # lowers/compiles on the production mesh and that the sharded-buffer
    # HLO assertion holds on the SCANNED computation (cost-analysis
    # counts the round body once, so the roofline terms are per-round)
    "flat_fed_rounds_fused": {"flat_fed": True, "flat_sharded": True,
                              "rounds_per_call": 8},
    # chaos round (repro.federation.faults): mid-round dropouts + NaN
    # corruption + byzantine scaling defended by trimmed robust
    # aggregation under quorum Q=2, stacked on int8+EF compression —
    # proves the guarded round tail lowers on the production mesh with
    # both HLO assertions (as in flat_fed_compressed)
    "flat_fed_faults": {"flat_fed": True, "flat_sharded": True,
                        "scenario": get_scenario(
                            "dirichlet_dropouts", robust_agg="trimmed",
                            byzantine_rate=0.1),
                        "compression": CompressionSpec(
                            kind="int8", error_feedback=True)},
}


def _check_flat_sharded(compiled, cfg, mesh, spec, variant,
                        compressed=False):
    """flat_fed_sharded copy-count assertion: the compiled module must
    never rematerialize the full packed (C, N) buffer on one device.
    ``compressed`` additionally asserts no full-precision client delta
    crosses the client shard boundary (skipped with a note when the
    spec leaves < 2 clients per client shard — indistinguishable from
    the aggregated mean)."""
    import jax
    import jax.numpy as jnp

    from repro.core import flat as flatlib
    from repro.models.model import build_model
    from repro.sharding.hlo import (assert_flat_buffer_sharded,
                                    assert_no_fullprec_delta_collective)

    model = build_model(cfg, jnp.bfloat16)
    pstruct = jax.eval_shape(model.init, jax.random.key(0))
    layout = flatlib.layout_of(pstruct, shards=spec.flat_shards(mesh))
    C = spec.clients_on(mesh)
    rep = assert_flat_buffer_sharded(compiled, C, layout.padded_size)
    print(f"[{variant}] ({C}, {layout.padded_size}) flat buffer stays "
          f"sharded: 0 full-shape HLO hits "
          f"(gather/copy={rep['gather_or_copy']})", flush=True)
    if compressed:
        try:
            brep = assert_no_fullprec_delta_collective(
                compiled, C, layout.padded_size, mesh=mesh,
                federation=spec)
            print(f"[{variant}] no full-precision delta crosses the "
                  f"client boundary ({brep['collectives']} collectives "
                  f"checked)", flush=True)
        except ValueError as e:
            print(f"[{variant}] boundary check skipped: {e}", flush=True)


def measure(arch: str, shape_id: str, variant: str, *, local_steps=2):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=False)
    spec = get_federation_spec(federation_kind(cfg), mesh)
    fl = FLConfig(local_steps=local_steps)
    knobs = dict(VARIANT_KNOBS[variant])
    if knobs.pop("expert_2d", False):
        import dataclasses
        spec = dataclasses.replace(spec, expert_2d=True)
    cap = knobs.pop("capacity", None)
    if cap is not None:
        import repro.models.moe as moe
        moe.CAPACITY_FACTOR = cap

    t0 = time.time()
    if shape.kind == "train" or True:
        # two-depth calibrated roofline (same methodology as the sweep)
        L1, L2 = _calib_depths(cfg)
        rls = []
        for L in (L1, L2):
            cfg_L = _at_depth(cfg, L)
            c, *_ = _compile_step(cfg_L, shape, mesh, spec, fl,
                                  unroll=True, remat=False, **knobs)
            if knobs.get("flat_sharded"):
                _check_flat_sharded(c, cfg_L, mesh, spec, variant,
                                    compressed=bool(
                                        knobs.get("compression")))
            rls.append(roofline.analyze(c, mesh.size))
        rl = roofline.extrapolate(rls[0], rls[1], L1, L2, cfg.num_layers)
    if cap is not None:
        import repro.models.moe as moe
        moe.CAPACITY_FACTOR = 1.25
    out = rl.summary()
    out["wall_s"] = round(time.time() - t0, 1)
    if knobs.get("compression"):
        # analytic wire telemetry: per-round client->server payload for
        # the FULL-depth config at this variant's compression kind
        # (bandwidth-tiered rounds mix levels per draw; this is the
        # fixed-kind figure the ratio columns are normalized against)
        import jax
        import jax.numpy as jnp
        from repro.compression import get_compression
        from repro.core import flat as flatlib
        from repro.models.model import build_model
        comp = get_compression(knobs["compression"])
        pstruct = jax.eval_shape(build_model(cfg, jnp.bfloat16).init,
                                 jax.random.key(0))
        layout = flatlib.layout_of(pstruct, shards=spec.flat_shards(mesh))
        C = spec.clients_on(mesh)
        table = comp.level_wire_bytes(layout.size)
        wire = float(table[comp.level]) * C
        out["wire"] = {"kind": comp.kind, "clients": C,
                       "wire_bytes_round": wire,
                       "uncompressed_bytes_round": float(table[0]) * C,
                       "comp_ratio": float(table[0]) / float(
                           table[comp.level])}
        print(f"[{variant}] wire: {wire/1e9:.2f} GB/round vs "
              f"{float(table[0]) * C/1e9:.2f} GB uncompressed "
              f"(ratio {out['wire']['comp_ratio']:.2f}x)", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True)  # arch/shape
    ap.add_argument("--variants", default="baseline,seq_shard")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    arch, shape_id = args.pair.split("/")
    os.makedirs(args.out, exist_ok=True)
    results = {}
    base_path = os.path.join("experiments/dryrun",
                             f"{arch}_{shape_id}_single.json")
    for v in args.variants.split(","):
        if v == "baseline" and os.path.exists(base_path):
            with open(base_path) as f:
                results[v] = json.load(f)["roofline"]
            print(f"[{v}] reused sweep artifact")
        else:
            print(f"[{v}] lowering...", flush=True)
            results[v] = measure(arch, shape_id, v)
        r = results[v]
        print(f"  t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
              f"t_coll={r['t_collective_s']:.3e} bot={r['bottleneck']}",
              flush=True)
    tag = f"{arch}_{shape_id}".replace("/", "_")
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(results, f, indent=2, default=float)


if __name__ == "__main__":
    main()
