"""Production mesh factory.

Target: TPU v5e pods, 256 chips each.
  single-pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Defined as a function so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """1-device mesh for CPU tests."""
    return jax.make_mesh(shape, axes)
