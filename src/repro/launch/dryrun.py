import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) program on
the production mesh, with 512 placeholder host devices standing in for the
TPU chips. Proves the sharding config is coherent end-to-end and emits the
memory/cost/collective numbers the roofline analysis (§Roofline) reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import roofline
from repro.configs import ARCH_IDS, FLConfig, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (decode_specs, decode_window, federation_kind,
                                prefill_specs, train_specs)
from repro.launch.steps import (abstract_fl_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models.model import build_model
from repro.sharding.spec import (LogicalRules, batch_shardings,
                                 cache_shardings, get_federation_spec,
                                 make_param_shardings,
                                 serve_batch_shardings)
from jax.sharding import NamedSharding, PartitionSpec as P


def _state_shardings(mesh, spec, state_struct, param_sh):
    """FLState shardings: params per rules; adaptive server-state slots
    (m/v, param-shaped) reuse the param shardings; scalars replicated.
    The async scenario delta buffer (param-shaped) also reuses the param
    shardings; the EF21 error-feedback tree ((C,)+param-shaped) shards
    its leading cohort axis over the client mesh axes."""
    from repro.core.fed_round import FLState

    pstruct = jax.tree_util.tree_structure(state_struct.params)

    def srv_group(sub):
        if jax.tree_util.tree_structure(sub) == pstruct:
            return param_sh
        return jax.tree.map(
            lambda l: NamedSharding(mesh, P(*((None,) * l.ndim))), sub)

    ss = state_struct.server_state
    if isinstance(ss, dict):
        srv_sh = {k: srv_group(v) for k, v in ss.items()}
    else:
        srv_sh = jax.tree.map(
            lambda l: NamedSharding(mesh, P(*((None,) * l.ndim))), ss)
    buf_sh = None
    if state_struct.buffer is not None:
        from repro.federation.buffer import AsyncBufferState
        rep = NamedSharding(mesh, P())
        buf_sh = AsyncBufferState(delta=param_sh, weight=rep, count=rep,
                                  stale_sum=rep, stale_max=rep)
    ef_sh = None
    if getattr(state_struct, "ef", None) is not None:
        ca, _ = spec.flat_axes(mesh)
        ca = ca if len(ca) > 1 else (ca[0] if ca else None)
        ef_sh = jax.tree.map(
            lambda l: NamedSharding(mesh, P(*((ca,) + (None,) * (l.ndim - 1)))),
            state_struct.ef)
    return FLState(params=param_sh, server_state=srv_sh,
                   round=NamedSharding(mesh, P()), buffer=buf_sh,
                   ef=ef_sh)


def _shard_bytes(struct, shardings):
    """Exact per-device bytes of a pytree under its NamedShardings."""
    import numpy as np
    total = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(struct),
                        jax.tree_util.tree_leaves(
                            shardings, is_leaf=lambda x: isinstance(
                                x, jax.sharding.NamedSharding))):
        shp = sh.shard_shape(tuple(leaf.shape))
        total += int(np.prod(shp)) * leaf.dtype.itemsize
    return total


def analytic_memory(cfg, shape, spec, mesh, pstruct, param_sh, fl,
                    cache_struct=None, cache_sh=None):
    """Remat-aware per-device HBM estimate (bytes). The measured CPU-backend
    temp is a NO-REMAT upper bound (XLA CPU CSE eliminates jax.checkpoint —
    verified empirically); this is the capacity-planning number for TPU,
    where per-block remat holds: live set = params/opt + per-layer residual
    saves + ONE block's internals + logits."""
    import numpy as np
    tp = mesh.shape.get(spec.tp_axes[0], 1) if spec.tp_axes else 1
    fsdp = int(np.prod([mesh.shape[a] for a in spec.fsdp_axes])) or 1
    pdev = _shard_bytes(pstruct, param_sh)
    D, L = cfg.d_model, cfg.num_layers
    Vt = cfg.padded_vocab // tp if cfg.padded_vocab % tp == 0 \
        else cfg.padded_vocab
    out = {"params_dev": pdev}
    if shape.kind == "train":
        C = spec.clients_on(mesh)
        b = max(1, shape.global_batch // C)
        tok = b * shape.seq_len // fsdp          # per device, per client slot
        resid = L * tok * D * 2
        att = 3 * (shape.seq_len // 8) * shape.seq_len \
            * max(1, cfg.num_heads // tp) * 4 * b // fsdp
        blk = att
        if cfg.num_experts:
            cap = max(4, int(tok * cfg.num_experts_per_tok * 1.25
                             / cfg.num_experts))
            blk = max(blk, 3 * (cfg.num_experts // max(1, tp)) * cap * D * 2)
        logits = 2 * tok * Vt * 4
        # global params + per-client local copy + grads + prev-grads(Δ-SGD)
        opt_copies = 4 if fl.client_opt == "delta_sgd" else 3
        out.update(residuals=resid, block_peak=blk, logits=logits,
                   total=pdev * opt_copies + resid + blk + logits)
    elif shape.kind == "prefill":
        data = int(np.prod([mesh.shape[a] for a in mesh.shape
                            if a != (spec.tp_axes[0] if spec.tp_axes
                                     else "")])) or 1
        bloc = max(1, shape.global_batch // data)
        cache = _shard_bytes(cache_struct, cache_sh) if cache_struct else \
            L * bloc * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * 2 * 2
        att = 3 * (shape.seq_len // 8) * shape.seq_len \
            * max(1, cfg.num_heads // tp) * 4 * bloc
        out.update(cache=cache, block_peak=att,
                   total=pdev + cache + att + bloc * Vt * 4)
    else:
        cache = _shard_bytes(cache_struct, cache_sh) if cache_struct else 0
        out.update(cache=cache, total=pdev + cache + shape.global_batch
                   * Vt * 4)
    return out


def _compile_step(cfg, shape, mesh, spec, fl, *, unroll, remat,
                  use_pallas=False, seq_shard=False, quant_kv=False,
                  softmax_bf16=False, cache_seq_shard=False,
                  flat_fed=None, flat_sharded=False, scenario=None,
                  compression=None, clients=None, rounds_per_call=1):
    """Lower + compile one program variant. Returns (compiled, t_lower,
    t_compile, analytic). ``flat_sharded`` (flat_fed only) threads the
    mesh + FederationSpec into the round so the packed (C, N) buffer
    stays sharded per ``spec.flat_spec(mesh)``. ``scenario`` (preset
    name or Scenario) adds heterogeneous-K lane masks / async buffered
    aggregation to the round. ``compression`` (kind name or
    CompressionSpec) compresses the client deltas on the flat engine
    (repro.compression). ``clients`` overrides the cohort size C
    (default ``spec.clients_on(mesh)`` — one client per client-axis
    coordinate); a multiple of it stacks several clients per shard,
    which the compressed-boundary HLO assertion needs to tell a leaked
    delta slab from the aggregated mean. ``rounds_per_call`` > 1 (train
    shapes, flat_fed only) lowers the round-fused R-round ``lax.scan``
    loop (repro.core.fed_loop) instead of the single round — batches
    gain a leading R axis, the carried state is donated."""
    import repro.models.attention as _att
    from repro.models.common import logical_rules, unroll_scans
    _att.SOFTMAX_BF16 = softmax_bf16
    model = build_model(cfg, jnp.bfloat16)
    rules = LogicalRules(spec, mesh, serve=shape.kind != "train",
                         seq_shard=seq_shard)
    analytic = None
    t0 = time.time()
    with mesh, unroll_scans(unroll), logical_rules(rules):
        if shape.kind == "train" and rounds_per_call > 1:
            if not (flat_fed and flat_sharded):
                raise ValueError("rounds_per_call > 1 on a mesh requires "
                                 "the sharded flat engine (flat_fed=True, "
                                 "flat_sharded=True): the mesh-form loop "
                                 "carries the tree FLState whose "
                                 "shardings this driver derives")
            from repro.launch.steps import make_train_loop
            loop, sopt, scn, comp = make_train_loop(
                model, fl, rounds_per_call=rounds_per_call,
                use_pallas=use_pallas, remat=remat,
                mesh=mesh if flat_sharded else None,
                federation=spec if flat_sharded else None,
                scenario=scenario, compression=compression)
            C = clients or spec.clients_on(mesh)
            # under a mesh the fused loop carries the tree-form FLState
            # (fed_loop.state_form) — the single-round state shardings
            # apply verbatim; batches just gain the leading R axis
            state_struct = abstract_fl_state(model, sopt, scn, comp, C)
            R = rounds_per_call
            round_batch = train_specs(model, shape, fl, C)
            batch = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((R,) + s.shape, s.dtype),
                round_batch)
            param_sh = make_param_shardings(spec, mesh, state_struct.params)
            state_sh = _state_shardings(mesh, spec, state_struct, param_sh)
            batch_sh = jax.tree.map(
                lambda sh: NamedSharding(mesh, P(None, *sh.spec)),
                batch_shardings(spec, mesh, round_batch),
                is_leaf=lambda x: isinstance(x, NamedSharding))
            analytic = analytic_memory(cfg, shape, spec, mesh,
                                       state_struct.params, param_sh, fl)
            lowered = jax.jit(loop, in_shardings=(state_sh, batch_sh),
                              donate_argnums=0
                              ).lower(state_struct, batch)
        elif shape.kind == "train":
            step, sopt, scn, comp = make_train_step(
                model, fl, use_pallas=use_pallas, remat=remat, flat=flat_fed,
                mesh=mesh if (flat_fed and flat_sharded) else None,
                federation=spec if (flat_fed and flat_sharded) else None,
                scenario=scenario, compression=compression)
            C = clients or spec.clients_on(mesh)
            state_struct = abstract_fl_state(model, sopt, scn, comp, C)
            batch = train_specs(model, shape, fl, C)
            param_sh = make_param_shardings(spec, mesh, state_struct.params)
            state_sh = _state_shardings(mesh, spec, state_struct, param_sh)
            batch_sh = batch_shardings(spec, mesh, batch)
            analytic = analytic_memory(cfg, shape, spec, mesh,
                                       state_struct.params, param_sh, fl)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)
                              ).lower(state_struct, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, use_pallas=use_pallas)
            pstruct = jax.eval_shape(model.init, jax.random.key(0))
            batch = prefill_specs(model, shape)
            param_sh = make_param_shardings(spec, mesh, pstruct)
            batch_sh = serve_batch_shardings(mesh, batch)
            analytic = analytic_memory(cfg, shape, spec, mesh, pstruct,
                                       param_sh, fl)
            lowered = jax.jit(step, in_shardings=(param_sh, batch_sh)
                              ).lower(pstruct, batch)
        else:  # decode
            window = decode_window(cfg, shape)
            step = make_serve_step(model, window=window)
            pstruct = jax.eval_shape(model.init, jax.random.key(0))
            cache, tokens = decode_specs(model, shape, window,
                                         quant_kv=quant_kv)
            param_sh = make_param_shardings(spec, mesh, pstruct)
            cache_sh = cache_shardings(spec, mesh, cache,
                                       batch_size=shape.global_batch,
                                       seq_shard=cache_seq_shard)
            tok_sh = serve_batch_shardings(mesh, {"t": tokens})["t"]
            analytic = analytic_memory(cfg, shape, spec, mesh, pstruct,
                                       param_sh, fl, cache, cache_sh)
            lowered = jax.jit(step, in_shardings=(param_sh, cache_sh, tok_sh)
                              ).lower(pstruct, cache, tokens)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    _att.SOFTMAX_BF16 = False
    return compiled, t_lower, t_compile, analytic


def _calib_depths(cfg):
    """Two reduced depths (whole pattern cycles) for roofline calibration."""
    cyc = len(cfg.block_pattern)
    return cyc, 2 * cyc


def _at_depth(cfg, L):
    import dataclasses
    return dataclasses.replace(cfg, name=f"{cfg.name}@{L}", num_layers=L)


def lower_one(arch: str, shape_id: str, multi_pod: bool, *,
              fl: FLConfig = None, local_steps: int = 2,
              use_pallas: bool = False, remat: bool = True,
              fed_kind: str = None, verbose: bool = True,
              calibrate: bool = True):
    """One (arch, shape, mesh) dry-run:

    Pass A — FULL config, rolled scans: proves lower+compile coherence on
    the production mesh and yields memory_analysis (CPU backend = no-remat
    upper bound; see analytic_memory).

    Pass B (single-pod only) — the same program at two reduced depths with
    ALL structural scans unrolled, because XLA cost_analysis counts a
    while-loop body once regardless of trip count (verified). FLOPs/bytes/
    collective-bytes are exactly affine in depth, so two points give the
    per-layer slope and the full-depth roofline: m(L) = m1 + (L-L1)·slope.
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    fed_kind = fed_kind or federation_kind(cfg)
    spec = get_federation_spec(fed_kind, mesh)
    fl = fl or FLConfig(local_steps=local_steps)

    # ---- Pass A: full config, rolled ----
    compiled, t_lower, t_compile, analytic = _compile_step(
        cfg, shape, mesh, spec, fl, unroll=False, remat=remat,
        use_pallas=use_pallas)
    mem = roofline.memory_analysis_summary(compiled)

    # ---- Pass B: two-depth unrolled calibration (single-pod roofline) ----
    rl_summary = None
    calib = None
    if calibrate and not multi_pod:
        L1, L2 = _calib_depths(cfg)
        rls = []
        for L in (L1, L2):
            cL, *_ = _compile_step(_at_depth(cfg, L), shape, mesh, spec, fl,
                                   unroll=True, remat=remat,
                                   use_pallas=use_pallas)
            rls.append(roofline.analyze(cL, chips))
        rl = roofline.extrapolate(rls[0], rls[1], L1, L2, cfg.num_layers)
        rl_summary = rl.summary()
        calib = {"depths": [L1, L2],
                 "flops_at_depths": [rls[0].flops, rls[1].flops]}
    else:
        rl = roofline.analyze(compiled, chips)
        rl_summary = rl.summary()
        rl_summary["note"] = ("rolled-scan numbers (loop bodies counted "
                              "once); use the single-pod calibrated "
                              "roofline for this pair")

    tokens_per_step = (shape.global_batch * shape.seq_len * fl.local_steps
                       if shape.kind == "train" else
                       shape.global_batch * (shape.seq_len
                                             if shape.kind == "prefill"
                                             else 1))
    mf = roofline.model_flops(cfg, tokens_per_step)
    if shape.kind != "train":
        mf /= 3.0  # fwd only: 2·N·D
    total_hlo_flops = rl.flops * chips
    result = {
        "arch": arch, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "federation": fed_kind, "clients": spec.clients_on(mesh),
        "step_kind": shape.kind,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "analytic_memory": analytic,
        "roofline": rl_summary,
        "calibration": calib,
        "model_flops": mf,
        "hlo_flops_total": total_hlo_flops,
        "useful_flops_ratio": mf / total_hlo_flops if total_hlo_flops else 0,
    }
    if verbose:
        print(json.dumps(result, indent=2, default=float))
        print(f"memory_analysis: {mem}")
    return result


def scenario_smoke(verbose: bool = True):
    """CI scenario leg: compile the flat_fed_hetero / flat_fed_async /
    flat_fed_compressed rounds — plus the round-fused R-round scan
    (flat_fed_rounds_fused, repro.core.fed_loop) and the chaos variant
    flat_fed_faults (repro.federation.faults: dropouts + NaN + byzantine
    under trimmed aggregation and quorum) — of a reduced config on an
    8-virtual-device (4, 2) host mesh and assert the packed (C, N)
    buffer stays sharded under every variant; the compressed variants
    additionally assert no full-precision client delta crosses the
    client shard boundary, with the TIGHTENED ``2*n_loc`` payload bound
    on the robust round (the production-mesh versions run via
    ``launch/perf.py --variants flat_fed_hetero,flat_fed_async,
    flat_fed_compressed,flat_fed_rounds_fused``)."""
    from repro.configs.base import ShapeConfig
    from repro.core import flat as flatlib
    from repro.models.model import build_model
    from repro.sharding.hlo import (assert_flat_buffer_sharded,
                                    assert_no_fullprec_delta_collective)
    from repro.sharding.spec import cross_device

    cfg = get_config("tinyllama-1.1b").reduced(num_layers=2, d_model=256)
    shape = ShapeConfig("train_smoke", "train", 256, 8)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    fl = FLConfig(local_steps=2, flat_engine=True)
    model = build_model(cfg, jnp.bfloat16)
    pstruct = jax.eval_shape(model.init, jax.random.key(0))
    layout = flatlib.layout_of(pstruct, shards=spec.flat_shards(mesh))
    from repro.compression import CompressionSpec
    from repro.federation import get_scenario
    # chaos variant: mid-round dropouts + NaN corruption + byzantine
    # scaling defended by trimmed aggregation under quorum Q=2, stacked
    # on int8+EF compression (repro.federation.faults)
    faults_scn = get_scenario("dirichlet_dropouts", robust_agg="trimmed",
                              quorum=2, byzantine_rate=0.1)
    n_loc = layout.padded_size // spec.flat_shards(mesh)
    for variant, scn, comp, rpc, cmul in (
            ("flat_fed_hetero", "dirichlet_stragglers", None, 1, 1),
            ("flat_fed_async", "zipf_async", None, 1, 1),
            # error_feedback=True allocates FLState.ef, so the compiled
            # program (and both HLO assertions) covers the EF sharding
            ("flat_fed_compressed", "bandwidth_tiered",
             CompressionSpec(kind="int8", error_feedback=True), 1, 2),
            # round-fused loop (repro.core.fed_loop): the sharded-buffer
            # assertion must hold on the SCANNED computation too
            ("flat_fed_rounds_fused", "dirichlet_stragglers", None, 4, 1),
            # chaos smoke: 4 clients per client shard, so the TIGHTENED
            # 2*n_loc robust-round bound sits strictly below the default
            # (C_loc, N_loc) slab bound and actually bites
            ("flat_fed_faults", faults_scn,
             CompressionSpec(kind="int8", error_feedback=True), 1, 4)):
        # the compressed variants stack >= 2 clients per client shard so
        # the boundary assertion can tell a leaked full-precision delta
        # slab (C_loc, N_loc) from the legitimate (N_loc,) client mean
        C = spec.clients_on(mesh) * cmul
        t0 = time.time()
        compiled, *_ = _compile_step(cfg, shape, mesh, spec, fl,
                                     unroll=False, remat=False,
                                     flat_fed=True, flat_sharded=True,
                                     scenario=scn, compression=comp,
                                     clients=C, rounds_per_call=rpc)
        rep = assert_flat_buffer_sharded(compiled, C, layout.padded_size)
        extra = ""
        if comp is not None:
            kw = ({"max_payload_elems": 2 * n_loc}
                  if variant == "flat_fed_faults" else {})
            brep = assert_no_fullprec_delta_collective(
                compiled, C, layout.padded_size, mesh=mesh,
                federation=spec, **kw)
            extra = (f", no full-precision delta over the client "
                     f"boundary ({brep['collectives']} collectives "
                     f"checked)")
        if verbose:
            sname = scn if isinstance(scn, str) else scn.name
            print(f"[scenario-smoke] {variant} ({sname}): compiled in "
                  f"{time.time() - t0:.1f}s, ({C}, {layout.padded_size}) "
                  f"flat buffer stays sharded "
                  f"(gather/copy={rep['gather_or_copy']}){extra}",
                  flush=True)
    print("scenario smoke passed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--remat", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="per-local-step activation checkpointing (default)")
    ap.add_argument("--fed-kind", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--scenario-smoke", action="store_true",
                    help="compile flat_fed_hetero + flat_fed_async + "
                         "flat_fed_compressed + flat_fed_rounds_fused + "
                         "flat_fed_faults on an 8-virtual-device mesh and "
                         "check the sharded-buffer + compressed-boundary "
                         "HLO assertions (CI scenario leg)")
    args = ap.parse_args()

    if args.scenario_smoke:
        scenario_smoke()
        return

    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_id in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_id}_{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = lower_one(arch, shape_id, multi,
                                    local_steps=args.local_steps,
                                    remat=args.remat,
                                    fed_kind=args.fed_kind, verbose=False)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=2, default=float)
                    rl = res["roofline"]
                    print(f"  ok: bottleneck={rl['bottleneck']} "
                          f"t_comp={rl['t_compute_s']:.3e} "
                          f"t_mem={rl['t_memory_s']:.3e} "
                          f"t_coll={rl['t_collective_s']:.3e} "
                          f"compile={res['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"  FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
