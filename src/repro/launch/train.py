"""End-to-end federated training driver.

Trains an assigned architecture (reduced or full) federatedly on synthetic
LM data with any client/server optimizer, or a paper-task model (MLP/CNN)
on the synthetic classification suite. This is the (b) end-to-end example
driver: ~100M-class models for a few hundred rounds on CPU, or the full
configs on a real TPU mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 50 --client-opt delta_sgd
  PYTHONPATH=src python -m repro.launch.train --task hard --model mlp \
      --rounds 200 --client-opt delta_sgd --alpha 0.1
  PYTHONPATH=src python -m repro.launch.train --task medium --model mlp \
      --rounds 100 --scenario zipf_async

``--scenario`` selects a federation scenario preset
(repro.federation.scenarios): participation scheduling, per-client
compute heterogeneity (K_c ≤ K lane masks), and/or FedBuff-style async
buffered aggregation. Async scenarios require (and auto-enable) the
flat Δ-SGD engine. The driver prints a per-run scenario report (cohort
histogram, staleness, effective-K) and appends it to the ``--out``
artifact.

``--compression`` (+ ``--k-frac``, ``--error-feedback``) compresses the
client->server deltas on the flat engine (repro.compression: int8
per-chunk quantization or magnitude top-k, optional EF21 error
feedback); the round log and the report gain wire-bytes /
compression-ratio telemetry. Combine with ``--scenario
bandwidth_tiered`` to draw per-client compression levels each round.

``--rounds-per-call R`` (R > 1) switches the training loop onto the
round-fused engine (repro.core.fed_loop): R rounds run as ONE jitted
``lax.scan`` on the persistent flat state, with donated buffers. The
paper-task driver stages the example arena on device once and ships
only (R, C, K, b) gather indices per block; the LM driver stacks R
rounds of synthetic batches. Metrics are bit-exact vs the host loop on
the flat engine (``--flat`` forces it for a host-loop parity run);
checkpoints land on block boundaries (still keyed on the round
counter, so fused and host-loop checkpoints interoperate), and eval /
state unpacking happens only at block cadence. Requires
``--client-opt delta_sgd``.

``--num-registered M`` (paper tasks) switches on the FLEET regime
(repro.core.fed_loop.make_fleet_loop + repro.federation.arena): M
registered clients known to the server, cohorts of |S_t| =
``--participation``·M drawn over ALL of them each round, per-client
state (round-end η, participation counters, EF21 residuals) in a
device-sharded ClientArena indexed by registered id. Registered client
i trains on data partition ``i % num_clients``, so fleet scale never
multiplies dataset memory. The ``fleet_uniform`` / ``fleet_zipf``
scenario presets carry hints (M=100k, p=0.05%) that apply when the
flags are not given; ``--eta-carry`` warm-starts returning clients
from their arena row.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, FLConfig, get_config
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import FederatedDataset, lm_round_batches
from repro.data.synthetic import get_task


def _resolve_scenario(args):
    """Preset with the run's --seed threaded in, so multi-seed sweeps
    actually vary the cohort / K_c / staleness draws. --robust-agg /
    --quorum fold onto the preset (and promote a bare run to sync_iid
    so the robust tail has a Scenario to live on)."""
    overrides = {}
    if getattr(args, "robust_agg", "mean") != "mean":
        overrides["robust_agg"] = args.robust_agg
    if getattr(args, "quorum", 0):
        overrides["quorum"] = args.quorum
    if not args.scenario and not overrides:
        return None
    from repro.federation import get_scenario
    return get_scenario(args.scenario or "sync_iid", seed=args.seed,
                        **overrides)


def _resolve_compression(args):
    """CompressionSpec from the --compression/--k-frac/--error-feedback
    flags (repro.compression); inert kind="none" specs leave the round
    engines bit-exact."""
    from repro.compression import CompressionSpec
    return CompressionSpec(kind=args.compression, k_frac=args.k_frac,
                           error_feedback=args.error_feedback)


def _resolve_fleet(args, scn):
    """(num_registered, participation) for the run. Explicit
    --num-registered / --participation win; otherwise a fleet preset's
    ``registered_hint`` / ``participation_hint`` apply (so
    ``--scenario fleet_uniform`` alone turns on the fleet regime);
    otherwise legacy: registered == num_clients, participation 0.1."""
    m = getattr(args, "num_registered", None)
    if m is None and scn is not None:
        m = scn.registered_hint
    p = getattr(args, "participation", None)
    if p is None and scn is not None and scn.participation_hint:
        p = scn.participation_hint
    return m, (0.1 if p is None else p)


class _ScenarioStats:
    """Per-run accumulator for the scenario report (launch/report.py):
    cohort ids per round + every metric the round emits, routed through
    the repro.telemetry.schema registry instead of a hardcoded key
    whitelist — an unregistered producer key warns ONCE (the old KEYS
    tuple silently discarded it) and is still kept, so nothing a round
    reports can vanish between the engine and the report."""

    def __init__(self, scenario, num_clients):
        self.scenario, self.num_clients = scenario, num_clients
        self.ids, self.metrics = [], []

    def update(self, ids, metrics):
        from repro.telemetry import schema
        if ids is not None:
            self.ids.append(np.asarray(ids))
        elif "cohort_ids" in metrics:
            self.ids.append(np.asarray(metrics["cohort_ids"]))
        row = {}
        for k, v in metrics.items():
            if k == "cohort_ids":
                continue        # carried in the ids stream above
            spec = schema.get(k)
            if spec is None:
                schema.warn_unregistered(k, producer="round metrics")
            if spec is not None and spec.shape != "()":
                row[k] = np.asarray(v, np.float64)
            else:
                row[k] = float(v)
        self.metrics.append(row)

    def summary(self):
        from repro.launch.report import scenario_summary
        name = self.scenario.name if self.scenario else "none"
        return scenario_summary(name, self.ids,
                                self.num_clients, self.metrics)

    def report(self, out_path=None, extra=None):
        s = self.summary()
        if extra:
            s.update(extra)
        print("scenario report:", json.dumps(s, indent=2, default=float))
        if out_path:
            import os
            os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(s, f, indent=2, default=float)
        return s


class _RoundLog:
    """Buffered round log for the HOST loops: per-round metric rows stay
    device arrays and are converted with ONE batched ``jax.device_get``
    per ``--log-every`` interval, instead of the old per-round blocking
    ``float(...)`` fan (which forced a host sync on every round, ~20
    scalars at a time, right in the dispatch hot path). The converted
    rows then feed the scenario stats and the JSONL event log."""

    def __init__(self, log_every, stats=None, events=None):
        self.log_every = max(1, int(log_every))
        self.stats, self.events = stats, events
        self._buf = []

    def push(self, t, metrics, ids=None):
        self._buf.append((t, ids, metrics))
        if len(self._buf) >= self.log_every:
            self.flush()

    def flush(self):
        if not self._buf:
            return
        rows = jax.device_get([m for _, _, m in self._buf])
        for (t, ids, _), row in zip(self._buf, rows):
            if self.stats is not None:
                self.stats.update(ids, row)
            if self.events is not None:
                self.events.emit("round", t=t, **row)
        if self.events is not None:
            self.events.flush()
        self._buf.clear()


def _resolve_events(args):
    """EventLog from --events (repro.telemetry.events), header stamped
    with the full CLI config."""
    if not getattr(args, "events", None):
        return None
    from repro.telemetry import EventLog
    return EventLog(args.events, config=vars(args))


def _log_every(args):
    """--log-every N, 0 = legacy cadence (~10 intervals per run)."""
    n = getattr(args, "log_every", 0)
    return n if n > 0 else max(1, args.rounds // 10)


def _finish_run(events, spans):
    """Common tail: span summary into the event log + stdout."""
    if spans is not None and spans.summary():
        print(f"spans: {spans}", flush=True)
    if events is not None:
        if spans is not None:
            events.emit("spans", **spans.summary())
        events.close()
        print(f"event log: {events.path} "
              f"({events.events_written} events)", flush=True)


def _health_str(m):
    """Compact round-health suffix for the round log. Fault-free legacy
    rounds emit none of the guard keys, so this stays empty and the log
    format is unchanged."""
    if "valid_count" not in m:
        return ""
    s = f" valid {int(float(m['valid_count']))}"
    ng = float(m.get("nan_guard_rate", 0.0))
    if ng > 0:
        s += f" nan {ng:.2f}"
    if float(m.get("round_skipped", 0.0)) > 0:
        s += " SKIPPED(quorum)"
    return s


def _run_fused(args, loop, state, rounds, stage_block, on_round,
               fleet_arena=None, events=None, spans=None):
    """Drive the round-fused loop (repro.core.fed_loop) in R-round
    blocks on donated flat state. ``stage_block(round0, n) ->
    (round_data, arena)`` stages one block's batches (or arena gather
    indices); ``on_round(t, row)`` consumes one round's metrics row.
    The flat carry is unpacked ONLY at block boundaries — that is the
    checkpoint cadence of a fused run: saves land on the first boundary
    at or after each ``--ckpt-every`` hit, keyed on the round counter
    like the host loop's (so fused and host-loop checkpoints
    interoperate via --resume). Returns the final FLState.

    ``fleet_arena`` switches to the fleet carry
    (core.fed_loop.make_fleet_loop): the loop carries
    (FlatFLState, ClientArena). Checkpoints save BOTH halves: the
    FLState lands in ``--ckpt-dir`` and the arena in its ``arena/``
    subdirectory (invisible to latest_step/GC of the FLState stream —
    they match only ``step_*`` entries), keyed on the same round so a
    --resume restores η warm-starts, participation counters, and the
    EF21 slab along with the params (see _maybe_resume_arena; the
    resume-parity test in tests/test_serving.py pins bit-exactness
    across a mid-run restart).

    Observability (repro.telemetry): the block is the host-sync
    boundary — the ONLY host transfer per block is the single batched
    metrics device_get after the block executes, and the JSONL
    ``events`` sink flushes exactly there (tests/test_telemetry.py runs
    a block under ``jax.transfer_guard("disallow")`` to pin this).
    ``spans`` accumulates pack/stage/block_execute/convert/ckpt
    wall-clock. ``--profile r`` profiles the block containing (1-based)
    round r: an HLO-derived static telemetry row — collective count +
    payload bytes per round (roofline.parse_collectives), Pallas launch
    counts per namespace — is emitted at compile time via an AOT
    lower+compile (one extra XLA compile, profiling runs only), and the
    block executes under a ``jax.profiler`` trace written to
    ``--profile-dir``."""
    from repro.checkpoint import save
    from repro.core import flatten_fl_state, unflatten_fl_state
    from repro.telemetry import (SpanTimer, kernel_launch_snapshot,
                                 reset_kernel_launches, static_telemetry,
                                 trace_block)
    if spans is None:
        spans = SpanTimer()
    R = max(1, args.rounds_per_call)
    layout = loop.layout
    jloop = jax.jit(loop, donate_argnums=0)
    with spans.span("pack"):
        fstate = flatten_fl_state(state, layout)
    car = fleet_arena
    base, t = int(state.round), 0
    profile_round = getattr(args, "profile", 0)
    profiled = False
    while t < rounds:
        n = min(R, rounds - t)
        with spans.span("stage"):
            data, arena = stage_block(base + t, n)

        do_profile = (profile_round > 0 and not profiled
                      and t <= profile_round - 1 < t + n)
        if do_profile:
            reset_kernel_launches()
            with spans.span("compile"):
                if car is not None:
                    lowered = jloop.lower((fstate, car), data, arena=arena)
                else:
                    lowered = jloop.lower(fstate, data, arena=arena)
                launches = kernel_launch_snapshot()
                compiled = lowered.compile()
            static = static_telemetry(compiled, rounds=n,
                                      launches=launches)
            print("static telemetry:",
                  json.dumps(static, default=str), flush=True)
            if events is not None:
                events.emit("static", **static)

        def call(fs=fstate, c=car, d=data, a=arena):
            if c is not None:
                return jloop((fs, c), d, arena=a)
            return jloop(fs, d, arena=a)

        with spans.span("block_execute"):
            if do_profile:
                out = trace_block(call, getattr(args, "profile_dir",
                                                "experiments/profile"))
                profiled = True
            else:
                out = call()
        if car is not None:
            (fstate, car), mets = out
        else:
            fstate, mets = out
        # the block boundary is the host-sync point: ONE batched
        # device_get for all R rounds' metric rows
        with spans.span("convert"):
            mets = jax.device_get(mets)
        for r in range(n):
            row = {k: v[r] for k, v in mets.items()}
            on_round(t + r, row)
            if events is not None:
                events.emit("round", t=t + r, round=base + t + r, **row)
        if events is not None:
            events.flush()
        t += n
        cadence_hit = any(t0 % args.ckpt_every == 0
                          for t0 in range(t - n, t))
        if args.ckpt_dir and (cadence_hit or t >= rounds):
            with spans.span("ckpt"):
                boundary = unflatten_fl_state(fstate, layout)
                save(args.ckpt_dir, boundary, step=int(boundary.round))
                if car is not None:
                    save(_arena_dir(args.ckpt_dir), car,
                         step=int(boundary.round))
    if profile_round > 0 and not profiled:
        print(f"--profile {profile_round}: no block contained that "
              f"round (run is {rounds} rounds); no trace captured",
              flush=True)
    with spans.span("unpack"):
        return unflatten_fl_state(fstate, layout)


def train_lm(args):
    from repro.models import build_model
    if getattr(args, "num_registered", None):
        raise SystemExit("--num-registered (the fleet regime) is a "
                         "paper-task feature: synthetic LM batches have "
                         "no per-client partitions to map registered "
                         "ids onto — use --task, not --arch")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=args.layers, d_model=args.d_model)
    model = build_model(cfg, jnp.float32)
    scn = _resolve_scenario(args)
    telemetry = getattr(args, "telemetry", False)
    fl = FLConfig(local_steps=args.local_steps, client_opt=args.client_opt,
                  server_opt=args.server_opt, lr=args.lr,
                  fedprox_mu=args.fedprox_mu, scenario=args.scenario,
                  num_clients=args.num_clients)
    copt = get_client_opt(fl.client_opt, fl, use_pallas=args.use_pallas)
    sopt = get_server_opt(fl.server_opt)
    loss_fn = make_loss(lambda p, b: model.loss(p, b),
                        fedprox_mu=fl.fedprox_mu)
    comp = _resolve_compression(args)
    comp_active = comp.active(scn)
    flat = ("xla" if (args.flat or (scn is not None and scn.is_async)
                      or comp_active) else False)
    params = model.init(jax.random.key(args.seed))
    state = init_fl_state(params, sopt, scn, compression=comp,
                          cohort=args.clients_per_round)
    state = _maybe_resume(args, state)
    # synthetic-data rng is derived PER ROUND from (seed, round): a
    # --resume at any round boundary replays the exact batch stream an
    # uninterrupted run would see (a single sequential stream would
    # restart from the beginning after a crash)
    round_rng = lambda r: np.random.default_rng((args.seed, int(r)))
    stats = (_ScenarioStats(scn, args.num_clients)
             if (scn or comp_active or telemetry) else None)
    events = _resolve_events(args)
    from repro.telemetry import SpanTimer
    spans = SpanTimer()

    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = (cfg.encoder_seq, cfg.d_model)
    if cfg.num_image_tokens:
        extras["image_embeds"] = (cfg.num_image_tokens, cfg.d_model)

    t0 = time.time()

    def print_round(t, metrics):
        if t % max(1, args.rounds // 10) == 0 or t == args.rounds - 1:
            wire = (f" wire {float(metrics['wire_bytes'])/1e6:.2f}MB "
                    f"(x{float(metrics['comp_ratio']):.2f})"
                    if "wire_bytes" in metrics else "")
            print(f"round {t:4d} loss {float(metrics['loss']):.4f} "
                  f"eta {float(metrics['eta_mean']):.4f}{wire}"
                  f"{_health_str(metrics)} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    def log_round(t, metrics):
        # fused-path consumer: rows arrive host-side already (one
        # batched device_get per block in _run_fused)
        if stats:
            stats.update(None, metrics)
        print_round(t, metrics)

    if args.rounds_per_call > 1:
        from repro.core import make_fl_loop
        loop = make_fl_loop(loss_fn, copt, sopt, params_like=params,
                            num_rounds=args.rounds,
                            rounds_per_call=args.rounds_per_call,
                            flat="pallas" if args.use_pallas else "xla",
                            scenario=scn, num_clients=args.num_clients,
                            compression=comp, telemetry=telemetry)

        def stage_block(round0, n):
            blocks = [lm_round_batches(round_rng(round0 + i),
                                       clients=args.clients_per_round,
                                       local_steps=fl.local_steps,
                                       batch=args.batch, seq=args.seq,
                                       vocab=cfg.vocab_size,
                                       extras=extras)
                      for i in range(n)]
            stacked = {k: jnp.asarray(np.stack([b[k] for b in blocks]))
                       for k in blocks[0]}
            return stacked, None

        state = _run_fused(args, loop, state, args.rounds, stage_block,
                           log_round, events=events, spans=spans)
        if stats:
            stats.report(args.out)
        _finish_run(events, spans)
        return state

    round_fn = jax.jit(make_fl_round(loss_fn, copt, sopt,
                                     num_rounds=args.rounds, flat=flat,
                                     scenario=scn,
                                     num_clients=args.num_clients,
                                     compression=comp,
                                     telemetry=telemetry))
    rlog = _RoundLog(_log_every(args), stats=stats, events=events)
    for t in range(args.rounds):
        # keyed on state.round, not the loop index, for the same
        # resume-replay reason as the paper-task cohort draw below
        batches = lm_round_batches(round_rng(int(state.round)),
                                   clients=args.clients_per_round,
                                   local_steps=fl.local_steps,
                                   batch=args.batch, seq=args.seq,
                                   vocab=cfg.vocab_size, extras=extras)
        batches = jax.tree.map(jnp.asarray, batches)
        state, metrics, _ = round_fn(state, batches)
        # metric rows stay on device: _RoundLog batches the host
        # conversion once per --log-every interval; only the sparse
        # print cadence below touches individual scalars
        rlog.push(t, metrics)
        print_round(t, metrics)
        _maybe_ckpt(args, state, t, final=(t == args.rounds - 1))
    rlog.flush()
    if stats:
        stats.report(args.out)
    _finish_run(events, None)
    return state


def _arena_dir(ckpt_dir):
    """Fleet-arena checkpoints live in a subdirectory of the FLState
    checkpoint dir: latest_step/_gc only match ``step_*`` entries, so
    the two streams never see each other."""
    return os.path.join(ckpt_dir, "arena")


def _maybe_ckpt(args, state, t, final=False, arena=None):
    """Periodic checkpoint, plus ALWAYS the final round: with
    ``T % ckpt_every != 0`` the last periodic save would otherwise
    predate round T and a --resume would silently redo (and a reader
    silently lose) up to ckpt_every-1 rounds.

    Saves are keyed on ``state.round`` (completed rounds), NOT the loop
    index: after a --resume the loop restarts at t=0 while the round
    counter continues, and loop-index steps would sort BELOW the
    pre-resume checkpoints — save()'s keep-newest GC would delete the
    new saves and latest_step would restore stale pre-resume state.

    ``arena`` (fleet runs) rides along into ``<ckpt_dir>/arena`` at the
    same step, so warm per-client state survives a --resume."""
    if args.ckpt_dir and (t % args.ckpt_every == 0 or final):
        from repro.checkpoint import save
        save(args.ckpt_dir, state, step=int(state.round))
        if arena is not None:
            save(_arena_dir(args.ckpt_dir), arena, step=int(state.round))


def _maybe_resume(args, state):
    from repro.checkpoint import latest_step, restore
    if args.ckpt_dir and args.resume and latest_step(args.ckpt_dir) is not None:
        state, step = restore(args.ckpt_dir, like=state)
        print(f"resumed from checkpoint step {step} "
              f"(round {int(state.round)})")
    return state


def _maybe_resume_arena(args, arena, round_):
    """Restore the fleet arena saved alongside the FLState checkpoint
    at round ``round_`` (the round _maybe_resume restored). Falls back
    to the cold arena — with a warning — when the checkpoint predates
    arena persistence or was saved by a non-fleet run; raises if the
    arena on disk has a different shape (e.g. the run was resumed with
    a different --num-registered or --error-feedback setting)."""
    from repro.checkpoint import latest_step, restore
    if not (args.ckpt_dir and args.resume):
        return arena
    adir = _arena_dir(args.ckpt_dir)
    steps_seen = latest_step(adir)
    if steps_seen is None:
        return arena
    if not os.path.isdir(os.path.join(adir, f"step_{round_:08d}")):
        warnings.warn(f"no arena checkpoint at round {round_} under "
                      f"{adir} (latest is {steps_seen}): resuming with "
                      f"a cold arena — η warm-starts and participation "
                      f"counters reset")
        return arena
    arena, step = restore(adir, like=arena, step=round_)
    print(f"resumed fleet arena from step {step}")
    return arena


def train_paper_task(args):
    from repro.configs.paper_tasks import CNN_PAPER, MLP_SMALL, MLP_WIDE
    from repro.models.small import accuracy, make_small_model, softmax_ce
    task = get_task(args.task, seed=args.seed)
    scn = _resolve_scenario(args)
    telemetry = getattr(args, "telemetry", False)
    num_reg, participation = _resolve_fleet(args, scn)
    fed = FederatedDataset.build(task, num_clients=args.num_clients,
                                 alpha=args.alpha, seed=args.seed,
                                 scenario=scn, num_registered=num_reg)
    mcfg = {"mlp": MLP_SMALL, "mlp-wide": MLP_WIDE, "cnn": CNN_PAPER}[
        args.model]
    init_fn, logits_fn = make_small_model(mcfg)
    fl = FLConfig(client_opt=args.client_opt, server_opt=args.server_opt,
                  lr=args.lr, fedprox_mu=args.fedprox_mu,
                  scenario=args.scenario, num_clients=args.num_clients,
                  participation=participation,
                  num_registered_clients=num_reg)
    copt = get_client_opt(fl.client_opt, fl)
    sopt = get_server_opt(fl.server_opt)
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}),
        fedprox_mu=fl.fedprox_mu)
    K = fed.epoch_steps(args.batch)
    comp = _resolve_compression(args)
    comp_active = comp.active(scn)
    flat = ("xla" if (args.flat or (scn is not None and scn.is_async)
                      or comp_active) else False)
    state = init_fl_state(init_fn(jax.random.key(args.seed)), sopt, scn,
                          compression=comp, cohort=fl.clients_per_round)
    state = _maybe_resume(args, state)
    stats = (_ScenarioStats(scn, fl.registered_clients)
             if (scn or comp_active or fl.fleet or telemetry)
             else None)
    events = _resolve_events(args)
    from repro.telemetry import SpanTimer
    spans = SpanTimer()
    t0 = time.time()

    def log_fused_round(t, row):
        if stats:
            stats.update(None, row)
        if t % max(1, args.rounds // 10) == 0 or t == args.rounds - 1:
            fleet = (f" revisit {float(row['revisit_frac']):.2f}"
                     if "revisit_frac" in row else "")
            print(f"round {t:4d} loss {float(row['loss']):.4f} "
                  f"eta {float(row['eta_mean']):.4f}{fleet}"
                  f"{_health_str(row)} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    if fl.fleet:
        # fleet regime: the loop carries (FlatFLState, ClientArena) and
        # draws its cohort over all C_registered candidates ON DEVICE —
        # the same (seed, round)-keyed scheduler draw sample_block uses
        # to gather data, so staged indices and arena rows agree. The
        # host ships only (R, C, K, b) gather indices per block; the
        # arena holds O(C_registered) scalars (plus the EF21 slab only
        # under --error-feedback).
        from repro.core import arena_gather, make_fleet_loop
        from repro.federation import arena_init
        loop = make_fleet_loop(
            loss_fn, copt, sopt,
            params_like=jax.eval_shape(init_fn, jax.random.key(args.seed)),
            num_rounds=args.rounds, num_registered=fl.registered_clients,
            rounds_per_call=max(1, args.rounds_per_call),
            flat="pallas" if args.use_pallas else "xla", scenario=scn,
            client_sizes=(jnp.asarray(fed.registered_sizes())
                          if scn else None),
            compression=comp, gather=arena_gather,
            eta_carry=getattr(args, "eta_carry", False), seed=fed.seed,
            telemetry=telemetry)
        use_ef = comp.error_feedback and comp.active(scn)
        car = arena_init(fl.registered_clients, eta0=loop.eta0,
                         ef_width=(loop.layout.padded_size if use_ef
                                   else None))
        car = _maybe_resume_arena(args, car, int(state.round))
        arena = jax.tree.map(jnp.asarray, fed.arena())

        def stage_block(round0, n):
            idx, _, _ = fed.sample_block(fl.participation, K, args.batch,
                                         round0=round0, rounds=n)
            return jnp.asarray(idx), arena

        state = _run_fused(args, loop, state, args.rounds, stage_block,
                           log_fused_round, fleet_arena=car,
                           events=events, spans=spans)
        with spans.span("eval"):
            xt, yt = fed.test_batch(2000)
            acc = float(accuracy(logits_fn(state.params, jnp.asarray(xt)),
                                 jnp.asarray(yt)))
        print(f"final test-acc {acc:.4f}", flush=True)
        if stats:
            stats.report(args.out, extra={"final_acc": acc})
        _finish_run(events, spans)
        return state

    if args.rounds_per_call > 1:
        # round-fused path: stage the example arena on device ONCE and
        # ship only (R, C, K, b) gather indices per block — the in-scan
        # gather (repro.core.arena_gather) replaces the per-round host
        # gather + transfer, and the cohort index stream is the same
        # rng stream sample_round consumes, so metrics/params stay
        # bit-exact vs the host loop on the flat engine.
        from repro.core import arena_gather, make_fl_loop
        loop = make_fl_loop(
            loss_fn, copt, sopt,
            params_like=jax.eval_shape(init_fn, jax.random.key(args.seed)),
            num_rounds=args.rounds, rounds_per_call=args.rounds_per_call,
            flat="pallas" if args.use_pallas else "xla", scenario=scn,
            num_clients=args.num_clients,
            client_sizes=fed.client_sizes() if scn else None,
            compression=comp, gather=arena_gather,
            telemetry=telemetry)
        arena = jax.tree.map(jnp.asarray, fed.arena())

        def stage_block(round0, n):
            idx, _, _ = fed.sample_block(fl.participation, K, args.batch,
                                         round0=round0, rounds=n)
            return jnp.asarray(idx), arena

        state = _run_fused(args, loop, state, args.rounds, stage_block,
                           log_fused_round, events=events, spans=spans)
        with spans.span("eval"):
            xt, yt = fed.test_batch(2000)
            acc = float(accuracy(logits_fn(state.params, jnp.asarray(xt)),
                                 jnp.asarray(yt)))
        print(f"final test-acc {acc:.4f}", flush=True)
        if stats:
            stats.report(args.out, extra={"final_acc": acc})
        _finish_run(events, spans)
        return state

    round_fn = jax.jit(make_fl_round(
        loss_fn, copt, sopt, num_rounds=args.rounds, flat=flat,
        scenario=scn, num_clients=args.num_clients,
        client_sizes=fed.client_sizes() if scn else None,
        compression=comp, telemetry=telemetry))
    rlog = _RoundLog(_log_every(args), stats=stats, events=events)
    for t in range(args.rounds):
        # key the host-side cohort draw on the ROUND COUNTER IN THE
        # STATE, not the loop index: after --resume the loop restarts at
        # 0 but state.round continues, and the jitted round's scenario
        # draws (step counts, staleness, reported cohort_ids) are keyed
        # on state.round — this keeps the gathered data and the in-round
        # draws agreeing across resumes.
        batches, w, ids = fed.sample_round(fl.participation, K, args.batch,
                                           round_idx=int(state.round))
        batches = {"x": jnp.asarray(batches["x"]),
                   "y": jnp.asarray(batches["y"])}
        state, metrics, _ = round_fn(state, batches)
        # device rows buffer in _RoundLog (one batched device_get per
        # --log-every interval); only the sparse eval/print cadence
        # below syncs individual scalars
        rlog.push(t, metrics, ids=ids)
        _maybe_ckpt(args, state, t, final=(t == args.rounds - 1))
        if t % max(1, args.rounds // 10) == 0 or t == args.rounds - 1:
            with spans.span("eval"):
                xt, yt = fed.test_batch(2000)
                acc = accuracy(logits_fn(state.params, jnp.asarray(xt)),
                               jnp.asarray(yt))
            print(f"round {t:4d} loss {float(metrics['loss']):.4f} "
                  f"test-acc {float(acc):.4f} "
                  f"eta {float(metrics['eta_mean']):.4f}"
                  f"{_health_str(metrics)} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    rlog.flush()
    if stats:
        xt, yt = fed.test_batch(2000)
        acc = float(accuracy(logits_fn(state.params, jnp.asarray(xt)),
                             jnp.asarray(yt)))
        stats.report(args.out, extra={"final_acc": acc})
    _finish_run(events, None)
    return state


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--task", default=None,
                    choices=["easy", "medium", "hard", "image", "lm"])
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "mlp-wide", "cnn"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--num-clients", type=int, default=100)
    ap.add_argument("--num-registered", type=int, default=None,
                    help="fleet regime (paper tasks): C_registered "
                         "clients known to the server, sampled over by "
                         "the schedulers; registered client i trains on "
                         "data partition i %% num_clients. Defaults to "
                         "the scenario's registered_hint (the fleet_* "
                         "presets set 100k), else legacy "
                         "registered == num_clients.")
    ap.add_argument("--participation", type=float, default=None,
                    help="participation rate p (|S_t| = p*C_registered); "
                         "defaults to the scenario's participation_hint, "
                         "else 0.1")
    ap.add_argument("--eta-carry", action="store_true",
                    help="fleet: warm-start a returning client's eta0 "
                         "from its arena row instead of the scalar eta0 "
                         "(off = Algorithm 1's per-round reset)")
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--client-opt", default="delta_sgd")
    ap.add_argument("--server-opt", default="fedavg")
    ap.add_argument("--scenario", default=None,
                    help="federation scenario preset "
                         "(repro.federation.scenarios: sync_iid, "
                         "dirichlet_stragglers, zipf_async, ...)")
    ap.add_argument("--out", default=None,
                    help="write the scenario report JSON here")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"],
                    help="client->server delta compression on the flat "
                         "engine (repro.compression); auto-enables it")
    ap.add_argument("--k-frac", type=float, default=0.25,
                    help="topk: fraction of each 128-lane chunk kept")
    ap.add_argument("--error-feedback", action="store_true",
                    help="EF21 error feedback (per-cohort-slot state in "
                         "FLState.ef)")
    ap.add_argument("--robust-agg", default="mean",
                    choices=["mean", "clip", "trimmed", "median"],
                    help="robust server aggregation on the flat engine "
                         "(repro.federation.faults); overrides the "
                         "scenario preset's choice")
    ap.add_argument("--quorum", type=int, default=0,
                    help="skip the server update when fewer than Q "
                         "clients survive the round's faults")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--fedprox-mu", type=float, default=0.0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help="R > 1 fuses R rounds into one jitted lax.scan "
                         "on persistent flat state (repro.core.fed_loop); "
                         "requires --client-opt delta_sgd")
    ap.add_argument("--flat", action="store_true",
                    help="force the flat Δ-SGD engine in the host loop "
                         "(the engine --rounds-per-call fuses, for "
                         "bit-exact parity runs)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="in-scan telemetry block (repro.telemetry): "
                         "per-round eta histogram, loss deciles, guard "
                         "hit counts ride the round metrics — "
                         "trajectory stays bit-exact")
    ap.add_argument("--log-every", type=int, default=0,
                    help="host-loop metric conversion interval (rounds "
                         "per batched device_get); 0 = ~10 per run")
    ap.add_argument("--events", default=None,
                    help="write a structured JSONL event log here "
                         "(header: config hash, git sha, jax versions; "
                         "flushed once per block boundary)")
    ap.add_argument("--profile", type=int, default=0,
                    help="profile the fused block containing this "
                         "(1-based) round: jax.profiler trace to "
                         "--profile-dir + an HLO-derived static "
                         "telemetry row (collectives/round, pallas "
                         "launch counts); needs --rounds-per-call > 1")
    ap.add_argument("--profile-dir", default="experiments/profile",
                    help="jax.profiler trace output directory")
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()
    if args.profile and args.rounds_per_call <= 1:
        ap.error("--profile needs the round-fused engine: pass "
                 "--rounds-per-call > 1")
    if args.arch:
        train_lm(args)
    elif args.task:
        train_paper_task(args)
    else:
        ap.error("pass --arch or --task")


if __name__ == "__main__":
    main()
