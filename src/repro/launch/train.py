"""End-to-end federated training driver.

Trains an assigned architecture (reduced or full) federatedly on synthetic
LM data with any client/server optimizer, or a paper-task model (MLP/CNN)
on the synthetic classification suite. This is the (b) end-to-end example
driver: ~100M-class models for a few hundred rounds on CPU, or the full
configs on a real TPU mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 50 --client-opt delta_sgd
  PYTHONPATH=src python -m repro.launch.train --task hard --model mlp \
      --rounds 200 --client-opt delta_sgd --alpha 0.1
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, FLConfig, get_config
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import FederatedDataset, lm_round_batches
from repro.data.synthetic import get_task


def train_lm(args):
    from repro.models import build_model
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=args.layers, d_model=args.d_model)
    model = build_model(cfg, jnp.float32)
    fl = FLConfig(local_steps=args.local_steps, client_opt=args.client_opt,
                  server_opt=args.server_opt, lr=args.lr,
                  fedprox_mu=args.fedprox_mu)
    copt = get_client_opt(fl.client_opt, fl, use_pallas=args.use_pallas)
    sopt = get_server_opt(fl.server_opt)
    loss_fn = make_loss(lambda p, b: model.loss(p, b),
                        fedprox_mu=fl.fedprox_mu)
    round_fn = jax.jit(make_fl_round(loss_fn, copt, sopt,
                                     num_rounds=args.rounds))
    params = model.init(jax.random.key(args.seed))
    state = init_fl_state(params, sopt)
    state = _maybe_resume(args, state)
    rng = np.random.default_rng(args.seed)

    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = (cfg.encoder_seq, cfg.d_model)
    if cfg.num_image_tokens:
        extras["image_embeds"] = (cfg.num_image_tokens, cfg.d_model)

    t0 = time.time()
    for t in range(args.rounds):
        batches = lm_round_batches(rng, clients=args.clients_per_round,
                                   local_steps=fl.local_steps,
                                   batch=args.batch, seq=args.seq,
                                   vocab=cfg.vocab_size, extras=extras)
        batches = jax.tree.map(jnp.asarray, batches)
        state, metrics, _ = round_fn(state, batches)
        _maybe_ckpt(args, state, t)
        if t % max(1, args.rounds // 10) == 0 or t == args.rounds - 1:
            print(f"round {t:4d} loss {float(metrics['loss']):.4f} "
                  f"eta {float(metrics['eta_mean']):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return state


def _maybe_ckpt(args, state, t):
    if args.ckpt_dir and (t % args.ckpt_every == 0):
        from repro.checkpoint import save
        save(args.ckpt_dir, state, step=t)


def _maybe_resume(args, state):
    from repro.checkpoint import latest_step, restore
    if args.ckpt_dir and args.resume and latest_step(args.ckpt_dir) is not None:
        state, step = restore(args.ckpt_dir, like=state)
        print(f"resumed from checkpoint step {step}")
    return state


def train_paper_task(args):
    from repro.configs.paper_tasks import CNN_PAPER, MLP_SMALL, MLP_WIDE
    from repro.models.small import accuracy, make_small_model, softmax_ce
    task = get_task(args.task, seed=args.seed)
    fed = FederatedDataset.build(task, num_clients=args.num_clients,
                                 alpha=args.alpha, seed=args.seed)
    mcfg = {"mlp": MLP_SMALL, "mlp-wide": MLP_WIDE, "cnn": CNN_PAPER}[
        args.model]
    init_fn, logits_fn = make_small_model(mcfg)
    fl = FLConfig(client_opt=args.client_opt, server_opt=args.server_opt,
                  lr=args.lr, fedprox_mu=args.fedprox_mu)
    copt = get_client_opt(fl.client_opt, fl)
    sopt = get_server_opt(fl.server_opt)
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}),
        fedprox_mu=fl.fedprox_mu)
    K = fed.epoch_steps(args.batch)
    round_fn = jax.jit(make_fl_round(loss_fn, copt, sopt,
                                     num_rounds=args.rounds))
    state = init_fl_state(init_fn(jax.random.key(args.seed)), sopt)
    state = _maybe_resume(args, state)
    t0 = time.time()
    for t in range(args.rounds):
        batches, w, _ = fed.sample_round(fl.participation, K, args.batch)
        batches = {"x": jnp.asarray(batches["x"]),
                   "y": jnp.asarray(batches["y"])}
        state, metrics, _ = round_fn(state, batches)
        _maybe_ckpt(args, state, t)
        if t % max(1, args.rounds // 10) == 0 or t == args.rounds - 1:
            xt, yt = fed.test_batch(2000)
            acc = accuracy(logits_fn(state.params, jnp.asarray(xt)),
                           jnp.asarray(yt))
            print(f"round {t:4d} loss {float(metrics['loss']):.4f} "
                  f"test-acc {float(acc):.4f} "
                  f"eta {float(metrics['eta_mean']):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--task", default=None,
                    choices=["easy", "medium", "hard", "image", "lm"])
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "mlp-wide", "cnn"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--num-clients", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--client-opt", default="delta_sgd")
    ap.add_argument("--server-opt", default="fedavg")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--fedprox-mu", type=float, default=0.0)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arch:
        train_lm(args)
    elif args.task:
        train_paper_task(args)
    else:
        ap.error("pass --arch or --task")


if __name__ == "__main__":
    main()
