"""Serving driver: continuous-batching greedy decode on the
:mod:`repro.serving` engine, for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --reduced \
      --batch 4 --prompt-len 64 --gen 32

The engine replaces the old per-token host loop: decode runs in fused
``flush_tokens``-step ``lax.scan`` blocks with ONE device_get per
flush (see ``repro/serving/engine.py``). ``--ckpt-dir`` loads params
from a checkpoint (``repro.checkpoint.restore_params`` — training
FLState checkpoints work directly: the manifest's ``params/...`` keys
match the serving template) AND keeps watching the directory through a
:class:`~repro.serving.registry.ModelRegistry`: a newer round saved
mid-run hot-swaps at the next flush boundary. ``--ckpt-step`` pins a
step (default: latest) — pinning disables the watch.

``--loadgen N`` switches from the one-batch demo to the load
generator: N requests (Poisson or closed-loop arrival), reporting
tokens/s, p50/p99 latency, occupancy, and swap stall. ``--personalize
K`` registers K synthetic client deltas and routes a fraction of
load-gen traffic through the personalized-decode overlay (real fleet
deltas come from ``PersonalizationStore.from_arena`` on a training
arena checkpoint). ``--events`` streams per-flush serving telemetry
(schema-checked JSONL, ``docs/TELEMETRY.md`` rows).

``--window`` must cover the full request (image tokens + prompt + gen)
unless ``--roll-cache`` is passed, in which case the KV cache is sized
to the window and rolls as a ring buffer (tokens beyond the window are
evicted). Silently truncating the cache below the request length — the
old behaviour — corrupts decode state and is now an error.

``run(args)`` is the driver body; it returns the generated token batch
plus timing so tests can call it in-process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--roll-cache", action="store_true",
                    help="with --window smaller than the full request, "
                         "size the cache to the window and roll it as a "
                         "ring buffer instead of erroring")
    ap.add_argument("--slots", type=int, default=None,
                    help="KV-pool slots (default: --batch)")
    ap.add_argument("--flush-tokens", type=int, default=8,
                    help="decode tokens fused per host flush")
    ap.add_argument("--ckpt-dir", default=None,
                    help="load params from this checkpoint dir "
                         "(training FLState checkpoints work: the "
                         "'params/' manifest prefix is matched) and "
                         "hot-swap when newer rounds appear")
    ap.add_argument("--ckpt-step", type=int, default=None,
                    help="checkpoint step to load (default: latest; "
                         "pinning disables the hot-swap watch)")
    ap.add_argument("--loadgen", type=int, default=0,
                    help="run the load generator with N requests "
                         "instead of the one-batch demo")
    ap.add_argument("--arrival", choices=("poisson", "closed"),
                    default="poisson")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="poisson arrival rate (req/s)")
    ap.add_argument("--personalize", type=int, default=0,
                    help="register N synthetic client deltas; load-gen "
                         "traffic is partly routed through them")
    ap.add_argument("--events", default=None,
                    help="write per-flush serving telemetry JSONL here")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _row_extras(cfg, rng):
    ex = {}
    if cfg.encoder_layers:
        ex["frames"] = rng.normal(
            size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.num_image_tokens:
        ex["image_embeds"] = rng.normal(
            size=(cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    return ex or None


def run(args) -> dict:
    """Serve one batch (or a load-gen stream); returns {"tokens":
    (B, gen) int32 array, "tok_per_s": float, "ckpt_step": int | None,
    "metrics": engine counters, "report": load-gen report | None}."""
    from repro.serving import (DecodeEngine, ModelRegistry,
                               PersonalizationStore, Workload, run_load)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(args.seed))

    ckpt_step, registry = None, None
    if args.ckpt_dir:
        from repro.checkpoint import restore_params
        params, ckpt_step = restore_params(args.ckpt_dir, params,
                                           step=args.ckpt_step)
        print(f"loaded params from {args.ckpt_dir} step {ckpt_step}")
        if args.ckpt_step is None:        # unpinned: watch for new rounds
            registry = ModelRegistry(args.ckpt_dir, params)
            registry.version = ckpt_step

    B, S, gen = args.batch, args.prompt_len, args.gen
    full_len = (cfg.num_image_tokens or 0) + S + gen
    window = args.window
    if window and window < full_len:
        if not args.roll_cache:
            raise SystemExit(
                f"--window {window} is smaller than the full request "
                f"({full_len} = image tokens + prompt + gen): the KV "
                f"cache would be silently truncated and decode state "
                f"corrupted. Pass --roll-cache to serve with a rolling "
                f"ring-buffer cache, or raise --window.")
        cache_len = window
    else:
        cache_len = full_len

    rng = np.random.default_rng(args.seed)
    store = None
    if args.personalize:
        store = PersonalizationStore(params, scale=1.0)
        for cid in range(args.personalize):
            store.set_delta(cid, jnp.asarray(
                rng.normal(scale=1e-3, size=(store.layout.padded_size,)),
                jnp.float32))
    events = None
    if args.events:
        from repro.telemetry import EventLog
        events = EventLog(args.events, config={
            "arch": args.arch, "mode": "serve", "slots":
            args.slots or B, "flush_tokens": args.flush_tokens})

    engine = DecodeEngine(model, params, slots=args.slots or B,
                          cache_len=cache_len,
                          flush_tokens=args.flush_tokens, window=window,
                          version=ckpt_step or 0, registry=registry,
                          personalization=store, events=events)

    report = None
    if args.loadgen:
        wl = Workload(num_requests=args.loadgen, arrival=args.arrival,
                      rate=args.rate, concurrency=engine.slots,
                      prompt_lens=(S,), gen_lens=(gen,),
                      personalized_frac=0.25 if store else 0.0,
                      client_ids=tuple(store.client_ids()) if store
                      else (0,), seed=args.seed)
        report = run_load(engine, wl, cfg.vocab_size)
        print(f"loadgen: {report['requests']} requests, "
              f"{report['tok_per_s']:.1f} tok/s, "
              f"p50 {report['p50_s'] * 1e3:.1f}ms "
              f"p99 {report['p99_s'] * 1e3:.1f}ms, "
              f"occupancy {report['occupancy']:.2f}, "
              f"swaps {report['swaps']}")

    # the one-batch demo (also the deterministic surface tests rely on)
    prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    rids = [engine.submit(prompts[i], gen, extras=_row_extras(cfg, rng))
            for i in range(B)]
    t0 = time.time()
    done = {c.request_id: c.tokens for c in engine.run_until_idle()}
    dt = time.time() - t0
    toks = np.stack([done[r] for r in rids])
    print(f"decoded {gen} tokens x {B} in {dt:.2f}s "
          f"({gen * B / max(dt, 1e-9):.1f} tok/s, "
          f"{engine.stats['flushes']} flushes)")
    print("sample:", toks[0][:16].tolist())
    if events is not None:
        events.close()
    return {"tokens": toks, "tok_per_s": gen * B / max(dt, 1e-9),
            "ckpt_step": ckpt_step, "metrics": engine.metrics(),
            "report": report, "history": engine.history}


def main():
    run(build_parser().parse_args())


if __name__ == "__main__":
    main()
