"""Serving driver: batched prefill + greedy decode for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --reduced \
      --batch 4 --prompt-len 64 --gen 32

``--ckpt-dir`` loads the params from a checkpoint
(repro.checkpoint.restore_params) instead of a fresh init — training
checkpoints work directly: the FLState manifest's ``params/...`` keys
match the serving template. ``--ckpt-step`` pins a step (default:
latest). ``run(args)`` is the driver body; it returns the generated
token batch plus timing so tests can call it in-process.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None,
                    help="load params from this checkpoint dir "
                         "(training FLState checkpoints work: the "
                         "'params/' manifest prefix is matched)")
    ap.add_argument("--ckpt-step", type=int, default=None,
                    help="checkpoint step to load (default: latest)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run(args) -> dict:
    """Prefill + greedy-decode one batch; returns {"tokens": (B, gen)
    int32 array, "tok_per_s": float, "ckpt_step": int | None}."""
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.key(args.seed))
    ckpt_step = None
    if args.ckpt_dir:
        from repro.checkpoint import restore_params
        params, ckpt_step = restore_params(args.ckpt_dir, params,
                                           step=args.ckpt_step)
        print(f"loaded params from {args.ckpt_dir} step {ckpt_step}")
    rng = np.random.default_rng(args.seed)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)

    cache_len = (cfg.num_image_tokens or 0) + S + args.gen
    if args.window:
        cache_len = min(cache_len, args.window)

    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(
        p, b, cache_len=cache_len, window=args.window))
    logits, cache = prefill(params, batch)
    print(f"prefill {S} tokens x {B}: {time.time() - t0:.2f}s")

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t,
                                                     window=args.window))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen} tokens x {B} in {dt:.2f}s "
          f"({args.gen * B / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:16].tolist())
    return {"tokens": np.asarray(gen),
            "tok_per_s": args.gen * B / max(dt, 1e-9),
            "ckpt_step": ckpt_step}


def main():
    run(build_parser().parse_args())


if __name__ == "__main__":
    main()
