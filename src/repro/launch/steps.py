"""Step-function builders shared by dryrun/train/serve drivers."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_loop, make_fl_round, make_fleet_loop,
                        make_loss)
from repro.models.model import Model


def _resolve_scenario(fl: FLConfig, scenario):
    """Resolve ``scenario`` (Scenario | preset name | None, defaulting to
    ``fl.scenario``) and fold in the FLConfig robust-aggregation
    overrides. ``robust_agg="mean"`` / ``quorum=0`` are inert; non-
    default values need a Scenario to live on, so they promote a bare
    config to the ``sync_iid`` preset."""
    if scenario is None and fl.scenario:
        scenario = fl.scenario
    overrides = {}
    if fl.robust_agg != "mean":
        overrides["robust_agg"] = fl.robust_agg
    if fl.quorum:
        overrides["quorum"] = fl.quorum
    if scenario is None and not overrides:
        return None
    if scenario is not None and hasattr(scenario, "is_async") \
            and not overrides:
        return scenario
    from repro.federation import get_scenario
    return get_scenario(scenario if scenario is not None else "sync_iid",
                        **overrides)


def make_train_step(model: Model, fl: FLConfig, *, num_rounds: int = 1000,
                    use_pallas: bool = False, remat: bool = False,
                    flat: Optional[bool] = None, mesh=None,
                    federation=None, scenario=None, compression=None):
    """One federated round over the (C, K, b, ...) batch layout.

    ``flat`` switches in the flat-parameter Δ-SGD engine (defaults to
    ``fl.flat_engine``); under meshes the kernels lower through XLA unless
    ``use_pallas`` is also set. ``mesh`` + ``federation`` (flat engine
    only) keep the packed (C, N) buffer sharded per
    ``federation.flat_spec(mesh)`` for the whole round. ``scenario`` (a
    repro.federation.Scenario or preset name; defaults to
    ``fl.scenario``) adds heterogeneous step counts and/or async
    buffered aggregation; async scenarios auto-enable the flat engine
    (the delta buffer is one reduction over the packed client axis).
    ``compression`` (a repro.compression.CompressionSpec or kind name;
    defaults to ``fl.compression_spec``) compresses the client deltas on
    the flat engine and auto-enables it when active.

    Returns (train_step, sopt, scenario, compression) — the resolved
    scenario/compression so the caller can allocate a matching
    ``init_fl_state``.
    """
    copt = get_client_opt(fl.client_opt, fl, use_pallas=use_pallas)
    sopt = get_server_opt(fl.server_opt)
    scenario = _resolve_scenario(fl, scenario)
    from repro.compression import get_compression
    compression = get_compression(compression if compression is not None
                                  else fl.compression_spec)
    if flat is None:
        flat = fl.flat_engine
    if scenario is not None and (scenario.is_async or scenario.faulty
                                 or scenario.robust or scenario.quorum > 0):
        flat = True
    if compression.active(scenario):
        flat = True
    flat_mode = False
    if flat:
        if fl.client_opt != "delta_sgd":
            raise ValueError("flat engine requires client_opt='delta_sgd', "
                             f"got {fl.client_opt!r}")
        flat_mode = "pallas" if use_pallas else "xla"

    def base_loss(params, batch):
        from repro.models.common import remat_blocks
        with remat_blocks(remat):
            return model.loss(params, batch, use_pallas=use_pallas)

    loss_fn = make_loss(base_loss, fedprox_mu=fl.fedprox_mu)
    round_fn = make_fl_round(loss_fn, copt, sopt, num_rounds=num_rounds,
                             weighted=fl.weighted_agg, flat=flat_mode,
                             mesh=mesh, federation=federation,
                             scenario=scenario,
                             num_clients=fl.num_clients,
                             compression=compression)

    def train_step(state, client_batches):
        new_state, metrics, _ = round_fn(state, client_batches)
        return new_state, metrics

    return train_step, sopt, scenario, compression


def make_train_loop(model: Model, fl: FLConfig, *, num_rounds: int = 1000,
                    rounds_per_call: int = 8, use_pallas: bool = False,
                    remat: bool = False, mesh=None, federation=None,
                    scenario=None, compression=None):
    """R rounds fused into one jitted call (core.fed_loop.make_fl_loop):
    ``lax.scan`` over the flat round body on a persistent flat carry —
    batches arrive with a leading R axis (stacked, or arena gather
    indices via ``repro.core.arena_gather``), metrics come back stacked.

    Same resolution rules as ``make_train_step``, except the flat engine
    is REQUIRED (the loop carries the packed flat state), so
    ``fl.client_opt`` must be ``delta_sgd``. Returns
    (train_loop, sopt, scenario, compression); the loop exposes
    ``.layout`` (for flatten/unflatten at block boundaries) and
    ``.state_form`` ("flat", or "tree" under meshes — see
    core.fed_loop). Jit the loop with ``donate_argnums=0`` so the
    carried buffers update in place.
    """
    if fl.client_opt != "delta_sgd":
        raise ValueError("the round-fused loop requires client_opt="
                         f"'delta_sgd', got {fl.client_opt!r}")
    copt = get_client_opt(fl.client_opt, fl, use_pallas=use_pallas)
    sopt = get_server_opt(fl.server_opt)
    scenario = _resolve_scenario(fl, scenario)
    from repro.compression import get_compression
    compression = get_compression(compression if compression is not None
                                  else fl.compression_spec)

    def base_loss(params, batch):
        from repro.models.common import remat_blocks
        with remat_blocks(remat):
            return model.loss(params, batch, use_pallas=use_pallas)

    loss_fn = make_loss(base_loss, fedprox_mu=fl.fedprox_mu)
    params_like = jax.eval_shape(model.init, jax.random.key(0))
    train_loop = make_fl_loop(loss_fn, copt, sopt, params_like=params_like,
                              num_rounds=num_rounds,
                              rounds_per_call=rounds_per_call,
                              weighted=fl.weighted_agg,
                              flat="pallas" if use_pallas else "xla",
                              mesh=mesh, federation=federation,
                              scenario=scenario,
                              num_clients=fl.num_clients,
                              compression=compression)
    return train_loop, sopt, scenario, compression


def make_fleet_train_loop(model: Model, fl: FLConfig, *,
                          num_rounds: int = 1000, rounds_per_call: int = 8,
                          use_pallas: bool = False, remat: bool = False,
                          scenario=None, compression=None,
                          client_sizes=None, gather=None,
                          batch_index_fn=None, eta_carry: bool = False,
                          seed: int = 0):
    """Fleet-scale variant of ``make_train_loop``
    (core.fed_loop.make_fleet_loop): the loop's carry is
    ``(FlatFLState, repro.federation.arena.ClientArena)`` — global
    training state plus per-REGISTERED-client rows — and each scanned
    round draws its cohort ids on device over all
    ``fl.registered_clients`` candidates, gathers only those rows, and
    scatters them back.

    Requires ``fl.num_registered_clients`` (the fleet regime) and the
    flat Δ-SGD engine. Same scenario/compression resolution as
    ``make_train_step``; ``client_sizes`` should be the
    (C_registered,) per-registered-client sizes (e.g.
    ``FederatedDataset.registered_sizes()``) when the scenario's
    scheduler is size-weighted. Returns
    (train_loop, sopt, scenario, compression); build the arena half of
    the carry with ``repro.federation.arena_init(fl.registered_clients,
    eta0=train_loop.eta0, ...)``.
    """
    if not fl.fleet:
        raise ValueError("make_fleet_train_loop needs the fleet regime: "
                         "set FLConfig.num_registered_clients")
    if fl.client_opt != "delta_sgd":
        raise ValueError("the fleet loop requires client_opt='delta_sgd', "
                         f"got {fl.client_opt!r}")
    copt = get_client_opt(fl.client_opt, fl, use_pallas=use_pallas)
    sopt = get_server_opt(fl.server_opt)
    scenario = _resolve_scenario(fl, scenario)
    from repro.compression import get_compression
    compression = get_compression(compression if compression is not None
                                  else fl.compression_spec)

    def base_loss(params, batch):
        from repro.models.common import remat_blocks
        with remat_blocks(remat):
            return model.loss(params, batch, use_pallas=use_pallas)

    loss_fn = make_loss(base_loss, fedprox_mu=fl.fedprox_mu)
    params_like = jax.eval_shape(model.init, jax.random.key(0))
    train_loop = make_fleet_loop(loss_fn, copt, sopt,
                                 params_like=params_like,
                                 num_rounds=num_rounds,
                                 num_registered=fl.registered_clients,
                                 rounds_per_call=rounds_per_call,
                                 weighted=fl.weighted_agg,
                                 flat="pallas" if use_pallas else "xla",
                                 scenario=scenario,
                                 client_sizes=client_sizes,
                                 compression=compression, gather=gather,
                                 batch_index_fn=batch_index_fn,
                                 eta_carry=eta_carry, seed=seed)
    return train_loop, sopt, scenario, compression


def make_prefill_step(model: Model, *, window: Optional[int] = None,
                      use_pallas: bool = False):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, window=window,
                                      use_pallas=use_pallas)
        return logits, cache

    return prefill_step


def make_serve_step(model: Model, *, window: Optional[int] = None,
                    greedy: bool = True):
    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens,
                                          window=window)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def abstract_fl_state(model: Model, sopt, scenario=None, compression=None,
                      cohort=None):
    """FLState ShapeDtypeStructs without allocating params (incl. the
    async delta buffer when ``scenario`` is an async Scenario, and the
    EF21 error-feedback tree when ``compression`` carries error
    feedback — ``cohort`` sizes its leading axis)."""
    pstruct = jax.eval_shape(model.init, jax.random.key(0))
    return jax.eval_shape(
        lambda p: init_fl_state(p, sopt, scenario, compression, cohort),
        pstruct)
