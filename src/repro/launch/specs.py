"""input_specs(): ShapeDtypeStruct stand-ins for every lowered program —
weak-type-correct, shardable, zero allocation.

Step kinds per input shape (DESIGN.md §4):
  train_4k    -> fl_round(state, client_batches)
  prefill_32k -> prefill_step(params, batch)
  decode_32k  -> serve_step(params, cache, tokens)     cache_len = 32768
  long_500k   -> serve_step(params, cache, tokens)     sub-quadratic path
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import FLConfig, ModelConfig, ShapeConfig
from repro.models.model import Model, build_model

# Architectures above this size train as 2 cross-silo clients (FSDP within
# silo); smaller ones as one client per (pod, data) coordinate.
CROSS_SILO_THRESHOLD = 10e9


def federation_kind(cfg: ModelConfig) -> str:
    return ("cross_silo" if cfg.param_count() > CROSS_SILO_THRESHOLD
            else "cross_device")


def _struct(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def params_struct(model: Model):
    return jax.eval_shape(model.init, jax.random.key(0))


def _frontend_extras(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict:
    out = {}
    if cfg.encoder_layers:
        out["frames"] = SDS(lead + (cfg.encoder_seq, cfg.d_model),
                            jnp.bfloat16)
    if cfg.num_image_tokens:
        out["image_embeds"] = SDS(lead + (cfg.num_image_tokens, cfg.d_model),
                                  jnp.bfloat16)
    return out


def train_specs(model: Model, shape: ShapeConfig, fl: FLConfig,
                clients: int) -> Dict[str, Any]:
    """FL-round batch struct: leaves (C, K, b, ...)."""
    cfg = model.cfg
    C, K = clients, fl.local_steps
    b = max(1, shape.global_batch // C)
    lead = (C, K, b)
    batch = {"tokens": SDS(lead + (shape.seq_len,), jnp.int32),
             "labels": SDS(lead + (shape.seq_len,), jnp.int32)}
    batch.update(_frontend_extras(cfg, lead))
    return batch


def prefill_specs(model: Model, shape: ShapeConfig) -> Dict[str, Any]:
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32)}
    batch.update(_frontend_extras(cfg, (B,)))
    return batch


def decode_specs(model: Model, shape: ShapeConfig,
                 window: Optional[int],
                 quant_kv: bool = False) -> Tuple[Any, Any]:
    """(cache_struct, tokens_struct)."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    cache_len = model.cache_len_for(S, window)
    cache = jax.eval_shape(lambda: model.init_cache(B, cache_len,
                                                    quant_kv=quant_kv))
    if cfg.encoder_layers:
        kv = SDS((cfg.num_layers, B, cfg.encoder_seq, cfg.num_kv_heads,
                  cfg.head_dim), model.dtype)
        cache = dict(cache)
        cache["enc_kv"] = {"xk": kv, "xv": kv}
    tokens = SDS((B, 1), jnp.int32)
    return cache, tokens


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding window policy: only the long-context shape uses it, and only
    when the config defines one (all attention-bearing archs do; pure-SSM
    archs have no attention cache at all)."""
    if shape.name == "long_500k" and cfg.sliding_window:
        return cfg.sliding_window
    return None
