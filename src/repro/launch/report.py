"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON artifacts, plus the federation scenario report (per-round cohort
composition, staleness, effective-K distribution).

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
  PYTHONPATH=src python -m repro.launch.report --dir experiments/train
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np


def fmt_t(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(dirname):
    rows = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows):
    # .get() guards throughout: artifacts from older runs (or partial
    # writes) may miss columns — a report renderer must degrade to "-",
    # never raise over a missing key
    out = ["| arch | shape | mesh | fed | clients | compile | temp/dev "
           "(no-remat UB) | analytic/dev (remat) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        am = r.get("analytic_memory") or {}
        mem = r.get("memory") or {}
        compile_s = r.get("compile_s")
        out.append(
            f"| {r.get('arch', '-')} | {r.get('shape', '-')} | "
            f"{r.get('mesh', '-')} | "
            f"{r.get('federation', '-')} | {r.get('clients', '-')} | "
            f"{'-' if compile_s is None else f'{compile_s}s'} | "
            f"{fmt_b(mem.get('temp_size_in_bytes', 0))} | "
            f"{fmt_b(am.get('total', 0))} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO flops | dominant collective |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != "16x16":
            continue
        rl = r.get("roofline") or {}
        if "note" in rl or "t_compute_s" not in rl:
            continue
        by = rl.get("coll_by_kind") or {}
        dom = max(by, key=by.get) if by else "-"
        out.append(
            f"| {r.get('arch', '-')} | {r.get('shape', '-')} | "
            f"{fmt_t(rl['t_compute_s'])} | "
            f"{fmt_t(rl['t_memory_s'])} | {fmt_t(rl['t_collective_s'])} | "
            f"**{rl.get('bottleneck', '-')}** | "
            f"{r.get('useful_flops_ratio', 0.0):.2f} | "
            f"{dom} ({fmt_b(by.get(dom, 0))}) |")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# federation scenario report (repro.federation): cohort composition,
# staleness, effective-K — consumed by launch/train.py and benchmarks
# ---------------------------------------------------------------------------
def cohort_histogram(ids_per_round, num_clients: int) -> np.ndarray:
    """(m,) counts: how many cohort slots each client filled across the
    run. ``ids_per_round`` is a list of per-round id arrays."""
    h = np.zeros(num_clients, np.int64)
    for ids in ids_per_round:
        np.add.at(h, np.asarray(ids, np.int64), 1)
    return h


def scenario_summary(name: str, ids_per_round, num_clients: int,
                     metrics_per_round) -> dict:
    """Aggregate one run's scenario telemetry into a report dict:
    participation histogram stats, mean/max staleness, effective-K
    distribution, buffer flush rate."""
    out = {"scenario": name, "rounds": len(metrics_per_round),
           "num_clients": num_clients}
    if ids_per_round:
        h = cohort_histogram(ids_per_round, num_clients)
        slots = max(1, int(h.sum()))
        top = np.sort(h)[::-1]
        out.update(
            clients_seen=int((h > 0).sum()),
            cohort_top1_share=float(top[0] / slots),
            cohort_top5_share=float(top[:5].sum() / slots))
        # the raw per-client list stays readable at per-round scale but
        # would be a 100k-entry JSON blob in the fleet regime — there
        # the share stats above carry the skew story
        if num_clients <= 10_000:
            out["cohort_histogram"] = h.tolist()

    # registry-driven aggregation: each MetricSpec's ``summaries``
    # declares how its per-round stream folds into per-run report
    # fields (repro.telemetry.schema is the single source of truth —
    # register a metric there and it shows up here with no edit)
    from repro.telemetry import schema

    reds = {"mean": np.mean, "sum": np.sum, "min": np.min, "max": np.max}
    for spec in schema.specs():
        vals = [m[spec.name] for m in metrics_per_round if spec.name in m]
        if not vals:
            continue
        for out_name, red in spec.summaries:
            if spec.shape == "()":
                out[out_name] = float(reds[red](vals))
            else:
                # distribution vectors (η hist, loss deciles) fold
                # elementwise across rounds and stay lists in the report
                out[out_name] = reds[red](
                    np.asarray(vals, np.float64), axis=0).tolist()
    if "eta_hist" in out and len(out["eta_hist"]) >= 3:
        from repro.telemetry.spec import TelemetrySpec
        out["eta_hist_edges"] = [
            float(e) for e in
            TelemetrySpec(eta_bins=len(out["eta_hist"])).eta_edges()]
    return out


def eta_hist_render(hist, edges, width: int = 40) -> str:
    """ASCII bar rendering of a run-summed η histogram. First bin is
    η < edges[1] underflow, last is overflow."""
    hist = np.asarray(hist, np.float64)
    total = hist.sum()
    if total <= 0:
        return "(empty η histogram)"
    peak = hist.max()
    lines = [f"η distribution ({total:.0f} client-rounds)"]
    for i, n in enumerate(hist):
        lo = edges[i] if i < len(edges) - 1 else edges[-2]
        hi = edges[i + 1] if i + 1 < len(edges) else float("inf")
        label = (f"<{hi:8.1e}" if i == 0
                 else f">{lo:8.1e}" if not np.isfinite(hi)
                 else f" {lo:8.1e}")
        bar = "#" * int(round(width * n / peak)) if peak else ""
        lines.append(f"  {label} |{bar} {n:.0f}")
    return "\n".join(lines)


def scenario_table(rows):
    """Markdown table over artifacts that carry a scenario report
    (launch/train.py --scenario --out)."""
    rows = [r for r in rows if "scenario" in r]
    if not rows:
        return "(no scenario artifacts)"
    out = ["| scenario | rounds | clients seen | top-1/top-5 cohort share "
           "| stale mean/max | K_eff mean (min..max) | flush rate "
           "| wire/round | comp ratio | valid mean | skips | η clip/NaN |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        seen = r.get("clients_seen", "-")
        share = (f"{r['cohort_top1_share']:.2f}/{r['cohort_top5_share']:.2f}"
                 if "cohort_top1_share" in r else "-")
        stale = (f"{r['stale_mean']:.2f}/{r['stale_max']:.0f}"
                 if "stale_mean" in r else "-")
        keff = (f"{r['k_eff_mean']:.2f} "
                f"({r['k_eff_min']:.0f}..{r['k_eff_max']:.0f})"
                if "k_eff_mean" in r else "-")
        flush = (f"{r['flush_rate']:.2f}" if "flush_rate" in r else "-")
        wire = (fmt_b(r["wire_bytes_round"])
                if "wire_bytes_round" in r else "-")
        ratio = (f"{r['comp_ratio']:.2f}x" if "comp_ratio" in r else "-")
        vmean = (f"{r['valid_mean']:.2f}" if "valid_mean" in r else "-")
        skips = (f"{r['skipped_rounds']:.0f}"
                 if "skipped_rounds" in r else "-")
        guard = (f"{r['eta_clip_rate']:.3f}/{r['nan_guard_rate']:.3f}"
                 if "eta_clip_rate" in r and "nan_guard_rate" in r else "-")
        out.append(f"| {r.get('scenario', '-')} | {r.get('rounds', '-')} "
                   f"| {seen} | {share} "
                   f"| {stale} | {keff} | {flush} | {wire} | {ratio} "
                   f"| {vmean} | {skips} | {guard} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    scen = [r for r in rows if "scenario" in r]
    dry = [r for r in rows if "scenario" not in r]
    if dry:
        print(f"## Dry-run ({len(dry)} artifacts)\n")
        print(dryrun_table(dry))
        print("\n## Roofline (single-pod 16x16, calibrated)\n")
        print(roofline_table(dry))
    if scen:
        print(f"\n## Federation scenarios ({len(scen)} runs)\n")
        print(scenario_table(scen))
        for r in scen:
            if "eta_hist" in r and "eta_hist_edges" in r:
                print(f"\n### {r.get('scenario', '-')}\n")
                print(eta_hist_render(r["eta_hist"], r["eta_hist_edges"]))


if __name__ == "__main__":
    main()
