"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON artifacts.

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_t(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(dirname):
    rows = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows):
    out = ["| arch | shape | mesh | fed | clients | compile | temp/dev "
           "(no-remat UB) | analytic/dev (remat) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        am = r.get("analytic_memory") or {}
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['federation']} | {r['clients']} | {r['compile_s']}s | "
            f"{fmt_b(r['memory'].get('temp_size_in_bytes', 0))} | "
            f"{fmt_b(am.get('total', 0))} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | MODEL/HLO flops | dominant collective |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        if "note" in rl:
            continue
        by = rl.get("coll_by_kind") or {}
        dom = max(by, key=by.get) if by else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(rl['t_compute_s'])} | "
            f"{fmt_t(rl['t_memory_s'])} | {fmt_t(rl['t_collective_s'])} | "
            f"**{rl['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{dom} ({fmt_b(by.get(dom, 0))}) |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print(f"## Dry-run ({len(rows)} artifacts)\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16, calibrated)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
