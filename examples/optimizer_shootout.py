"""Scenario: the paper's headline experiment in miniature — tune every
baseline on ONE task, transfer to another, watch them degrade while Δ-SGD
(never tuned) stays robust. (Paper Fig. 1 / Table 1 narrative.)

  PYTHONPATH=src python examples/optimizer_shootout.py
"""
import sys

sys.path.insert(0, ".")
from benchmarks.fl_common import OPTS, run_fl, tuned_lrs  # noqa: E402

print("tuning every optimizer on 'hard' (α=0.1)...")
lrs = tuned_lrs(rounds=30)
print("tuned lrs:", lrs)

print("\ntransfer to 'easy' (α=0.01) with the SAME step sizes:")
results = {}
for opt in OPTS:
    r = run_fl(opt, "easy", alpha=0.01, rounds=40, lr=lrs[opt])
    results[opt] = r["acc"]
    print(f"  {opt:12s} acc {r['acc']:.3f}")

best = max(results.values())
print(f"\nΔ-SGD gap to best: {best - results['delta_sgd']:+.3f} "
      "(paper claim: small without any tuning)")
