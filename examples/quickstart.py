"""Quickstart: Δ-SGD federated learning in ~50 lines.

Trains an MLP on a non-iid synthetic federation (100 clients, Dirichlet
α=0.1, 10% participation) with the paper's auto-tuned client step size —
no learning rate anywhere.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import MLP_SMALL
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import FederatedDataset
from repro.data.synthetic import get_task
from repro.models.small import accuracy, make_small_model, softmax_ce

ROUNDS = 100

# 1. a federated, non-iid dataset (latent-Dirichlet label skew)
task = get_task("medium")
fed = FederatedDataset.build(task, num_clients=100, alpha=0.1, seed=0)

# 2. a model and a loss
init_fn, logits_fn = make_small_model(MLP_SMALL)
loss_fn = make_loss(lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]),
                                  {}))

# 3. Δ-SGD clients (paper defaults γ=2, η0=0.2, θ0=1, δ=0.1 — no tuning)
#    + FedAvg server, compiled into one jitted round
client_opt = get_client_opt("delta_sgd")
server_opt = get_server_opt("fedavg")
fl_round = jax.jit(make_fl_round(loss_fn, client_opt, server_opt,
                                 num_rounds=ROUNDS))

state = init_fl_state(init_fn(jax.random.key(0)), server_opt)
K = fed.epoch_steps(batch_size=64)          # E = 1 local epoch

for t in range(ROUNDS):
    batches, weights, _ = fed.sample_round(0.1, K, batch_size=64)
    state, metrics, _ = fl_round(state, jax.tree.map(jnp.asarray, batches))
    if t % 10 == 0 or t == ROUNDS - 1:
        xt, yt = fed.test_batch(2000)
        acc = accuracy(logits_fn(state.params, jnp.asarray(xt)),
                       jnp.asarray(yt))
        print(f"round {t:3d}  train-loss {float(metrics['loss']):.3f}  "
              f"test-acc {float(acc):.3f}  "
              f"mean η {float(metrics['eta_mean']):.4f}")
