"""Scenario: federated training of a (reduced) assigned transformer
architecture with Δ-SGD clients — the big-model path of the framework,
runnable on CPU.

  PYTHONPATH=src python examples/federated_lm.py --arch olmoe-1b-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, FLConfig, get_config
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import lm_round_batches
from repro.models import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
ap.add_argument("--rounds", type=int, default=30)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
fl = FLConfig(local_steps=4)

copt = get_client_opt("delta_sgd", fl)
sopt = get_server_opt("fedavg")
loss_fn = make_loss(lambda p, b: model.loss(p, b))
fl_round = jax.jit(make_fl_round(loss_fn, copt, sopt,
                                 num_rounds=args.rounds))
state = init_fl_state(model.init(jax.random.key(0)), sopt)

extras = {}
if cfg.encoder_layers:
    extras["frames"] = (cfg.encoder_seq, cfg.d_model)
if cfg.num_image_tokens:
    extras["image_embeds"] = (cfg.num_image_tokens, cfg.d_model)

rng = np.random.default_rng(0)
t0 = time.time()
for t in range(args.rounds):
    batches = lm_round_batches(rng, clients=4, local_steps=fl.local_steps,
                               batch=4, seq=128, vocab=cfg.vocab_size,
                               extras=extras)
    state, metrics, _ = fl_round(state, jax.tree.map(jnp.asarray, batches))
    if t % 5 == 0 or t == args.rounds - 1:
        print(f"round {t:3d}  loss {float(metrics['loss']):.4f}  "
              f"η {float(metrics['eta_mean']):.4f}  "
              f"({time.time()-t0:.0f}s)", flush=True)
