"""Scenario: continuous-batching serving — submit a prompt batch to the
repro.serving.DecodeEngine and greedy-decode it in fused flush blocks,
for any assigned architecture including the recurrent ones (O(1)-state
decode for Mamba2/xLSTM), encoder/VLM archs (per-request frames /
image_embeds), and the sliding-window long-context path.

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b --window 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serving import DecodeEngine, ModelRegistry

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="zamba2-7b")
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--prompt-len", type=int, default=48)
ap.add_argument("--gen", type=int, default=16)
ap.add_argument("--window", type=int, default=None)
ap.add_argument("--flush-tokens", type=int, default=8)
ap.add_argument("--ckpt-dir", default=None,
                help="load params from a checkpoint (training FLState "
                     "checkpoints work via repro.checkpoint."
                     "restore_params) and hot-swap newer rounds")
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
registry = None
if args.ckpt_dir:
    registry = ModelRegistry(args.ckpt_dir, params)
    staged = registry.poll()
    if staged is not None:
        params = staged.params
        print(f"loaded params from {args.ckpt_dir} step {staged.step}")
rng = np.random.default_rng(0)

cache_len = (cfg.num_image_tokens or 0) + args.prompt_len + args.gen
if args.window:
    cache_len = min(cache_len, args.window)

engine = DecodeEngine(model, params, slots=args.batch,
                      cache_len=cache_len,
                      flush_tokens=args.flush_tokens,
                      window=args.window,
                      version=registry.version or 0 if registry else 0,
                      registry=registry)
rids = []
for _ in range(args.batch):
    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = rng.normal(
            size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.num_image_tokens:
        extras["image_embeds"] = rng.normal(
            size=(cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.prompt_len,)).astype(np.int32)
    rids.append(engine.submit(prompt, args.gen, extras=extras or None))

t0 = time.time()
done = {c.request_id: c.tokens for c in engine.run_until_idle()}
dt = time.time() - t0
print(f"{args.arch}: generated {args.gen}x{args.batch} tokens in "
      f"{engine.stats['flushes']} flushes "
      f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s)")
print("first row:", done[rids[0]][:12].tolist())
