"""Scenario: batched serving — prefill a prompt batch, then greedy-decode,
for any assigned architecture including the recurrent ones (O(1)-state
decode for Mamba2/xLSTM) and the sliding-window long-context path.

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b --window 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="zamba2-7b")
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--prompt-len", type=int, default=48)
ap.add_argument("--gen", type=int, default=16)
ap.add_argument("--window", type=int, default=None)
ap.add_argument("--ckpt-dir", default=None,
                help="load params from a checkpoint (training FLState "
                     "checkpoints work via repro.checkpoint.restore_params)")
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
if args.ckpt_dir:
    from repro.checkpoint import restore_params
    params, step0 = restore_params(args.ckpt_dir, params)
    print(f"loaded params from {args.ckpt_dir} step {step0}")
rng = np.random.default_rng(0)

batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
    jnp.int32)}
if cfg.encoder_layers:
    batch["frames"] = jnp.asarray(rng.normal(
        size=(args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
if cfg.num_image_tokens:
    batch["image_embeds"] = jnp.asarray(rng.normal(
        size=(args.batch, cfg.num_image_tokens, cfg.d_model)), jnp.float32)

cache_len = (cfg.num_image_tokens or 0) + args.prompt_len + args.gen
if args.window:
    cache_len = min(cache_len, args.window)

logits, cache = jax.jit(lambda p, b: model.prefill(
    p, b, cache_len=cache_len, window=args.window))(params, batch)
step = jax.jit(lambda p, c, t: model.decode_step(p, c, t,
                                                 window=args.window))

tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
out = [tok]
t0 = time.time()
for _ in range(args.gen - 1):
    logits, cache = step(params, cache, tok)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(tok)
dt = time.time() - t0
print(f"{args.arch}: generated {args.gen}x{args.batch} tokens "
      f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s)")
print("first row:", np.asarray(jnp.concatenate(out, 1))[0][:12].tolist())
