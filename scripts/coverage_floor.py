"""Per-module coverage table + soft floor over a coverage.json report.

    python scripts/coverage_floor.py coverage.json

Groups file coverage by top-level module under src/repro/ (core,
federation, kernels, launch, ...; top-level files stand alone), prints
the table worst-first, and checks the total line rate against the
floor. The floor is SOFT by default — coverage regressions print a
loud warning but do not fail CI (set REPRO_COV_HARD=1 to make it
blocking once the number has been stable for a while).

    REPRO_COV_FLOOR   total-percent floor (default 70)
    REPRO_COV_HARD    "1" -> exit 1 when below the floor
"""
from __future__ import annotations

import json
import os
import sys


def module_of(path: str) -> str:
    parts = path.replace("\\", "/").split("/")
    if "repro" in parts:
        sub = parts[parts.index("repro") + 1:]
        return f"repro/{sub[0]}" if sub else "repro"
    return parts[0]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print(__doc__)
        return 2
    with open(argv[0]) as f:
        report = json.load(f)

    floor = float(os.environ.get("REPRO_COV_FLOOR", "70"))
    hard = os.environ.get("REPRO_COV_HARD") == "1"

    mods = {}
    for path, entry in report["files"].items():
        s = entry["summary"]
        cov, tot = mods.setdefault(module_of(path), [0, 0])
        mods[module_of(path)] = [cov + s["covered_lines"],
                                 tot + s["num_statements"]]

    rows = sorted(((100.0 * c / t if t else 100.0, m, c, t)
                   for m, (c, t) in mods.items()))
    width = max(len(m) for _, m, _, _ in rows)
    print(f"{'module':<{width}}  covered/stmts   %")
    for pct, mod, cov, tot in rows:
        print(f"{mod:<{width}}  {cov:>6}/{tot:<6}  {pct:5.1f}")

    total = report["totals"]["percent_covered"]
    print(f"\ntotal: {total:.1f}% (floor {floor:.0f}%, "
          f"{'hard' if hard else 'soft'})")
    if total < floor:
        print(f"WARNING: total coverage {total:.1f}% is below the "
              f"{floor:.0f}% floor")
        return 1 if hard else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
