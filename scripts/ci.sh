#!/usr/bin/env bash
# Lightweight CI: tier-1 tests + kernels benchmark smoke (parity +
# launch-count assertions live inside the kernels suite).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -q
python -m benchmarks.run --only kernels --quick
