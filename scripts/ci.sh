#!/usr/bin/env bash
# CI: docs-drift check (scripts/gen_docs.py) + tier-1 tests (exact
# ROADMAP verify command) + kernels/sharded/scenarios/compression/
# faults/rounds_fused/fleet/telemetry/serving benchmark smoke +
# benchmark-regression guard (scenario/compression/fault/fleet/
# telemetry/serving rows are soft-baselined).
#
# BENCH_GUARD=hard|soft|off (default hard): the guard compares
# bench_results.csv against benchmarks/baseline.json — soft on the
# latest-jax CI leg, hard on pinned (see .github/workflows/ci.yml).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# 8 virtual CPU devices so the sharded flat-engine tests exercise a real
# (data, model) mesh (tests/test_flat.py needs8 cases + `sharded` bench)
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

# docs drift: the scenario table in docs/SCENARIOS.md and the metric
# table in docs/TELEMETRY.md are generated from the SCENARIOS /
# telemetry.schema registries — regenerate and fail on any diff
python scripts/gen_docs.py
git diff --exit-code -- docs/

# fast tier first (-m "not slow"), then the slow tail — a broken fast
# test fails CI before the multi-round/mesh-heavy tests even start
python -m pytest -x -q -m "not slow"
python -m pytest -x -q -m slow
python -m benchmarks.run \
    --only kernels,sharded,scenarios,compression,faults,rounds_fused,fleet,telemetry,serving \
    --quick
python -m benchmarks.compare bench_results.csv benchmarks/baseline.json \
    --mode "${BENCH_GUARD:-hard}"
