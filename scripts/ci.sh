#!/usr/bin/env bash
# CI entry point. Two modes:
#
#   bash scripts/ci.sh              # main: docs-drift + tier-1 tests
#                                   # (+ coverage when pytest-cov is
#                                   # installed) + benchmark smoke +
#                                   # benchmark-regression guard
#   bash scripts/ci.sh conformance  # deflake audit (fast tier under a
#                                   # deterministic shuffled order) +
#                                   # budgeted config-space differential
#                                   # fuzz (repro.conformance.fuzz);
#                                   # violation artifacts land in
#                                   # conformance-artifacts/ for upload
#
# Knobs:
#   BENCH_GUARD=hard|soft|off   benchmark guard mode (default hard) —
#                               soft on the latest-jax CI leg, hard on
#                               pinned (see .github/workflows/ci.yml)
#   PYTEST_ORDER_SEED=<n>       shuffled-order seed for the deflake leg
#                               (conformance mode; default 1, CI passes
#                               the run id so every run tries a fresh
#                               order that stays replayable from logs)
#   CONF_FUZZ_SEEDS=<n>         fuzz budget in sampled configs (def 10)
#   REPRO_COV_FLOOR / REPRO_COV_HARD   see scripts/coverage_floor.py
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# 8 virtual CPU devices so the sharded flat-engine tests exercise a real
# (data, model) mesh (tests/test_flat.py needs8 cases + `sharded` bench)
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

MODE="${1:-main}"

if [ "$MODE" = "conformance" ]; then
    # deflake audit: the fast tier must pass in a shuffled order too —
    # any difference vs the default order is an inter-test dependency
    PYTEST_ORDER_SEED="${PYTEST_ORDER_SEED:-1}" \
        python -m pytest -x -q -m "not slow"
    # budgeted differential fuzz over the conformance config space; the
    # regression corpus (seeds 0..21 + pinned) already ran in tier-1
    # above, so start the budget past it for fresh configs
    python -m repro.conformance.fuzz \
        --start 1000 --seeds "${CONF_FUZZ_SEEDS:-10}" \
        --out conformance-artifacts
    exit 0
fi

# docs drift: the scenario table in docs/SCENARIOS.md and the metric
# table in docs/TELEMETRY.md are generated from the SCENARIOS /
# telemetry.schema registries — regenerate and fail on any diff
python scripts/gen_docs.py
git diff --exit-code -- docs/

# coverage rides along when pytest-cov is installed (CI installs it;
# the dev container may not have it — the tier runs identically bare)
COV_ARGS=()
if python -c "import pytest_cov" 2>/dev/null; then
    COV_ARGS=(--cov=repro --cov-report=json:coverage.json
              --cov-report=term:skip-covered)
fi

# fast tier first (-m "not slow"), then the slow tail — a broken fast
# test fails CI before the multi-round/mesh-heavy tests even start
python -m pytest -x -q -m "not slow" "${COV_ARGS[@]}"
python -m pytest -x -q -m slow
if [ "${#COV_ARGS[@]}" -gt 0 ]; then
    python scripts/coverage_floor.py coverage.json
fi
python -m benchmarks.run \
    --only kernels,sharded,scenarios,compression,faults,rounds_fused,fleet,telemetry,serving \
    --quick
python -m benchmarks.compare bench_results.csv benchmarks/baseline.json \
    --mode "${BENCH_GUARD:-hard}"
