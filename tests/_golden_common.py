"""Shared runner for the golden-trajectory regression fixtures.

One small, fully deterministic FL run per engine configuration; the
fixtures under ``tests/golden/`` pin the per-round loss / η traces (and
a final-params l2) so any numerical drift in the round engines — packer,
kernels, scenario masking, compression, aggregation — fails the suite
loudly. Regenerate with ``python tests/golden/regen.py`` (only when a
numeric change is INTENDED; the diff is the review artifact).
"""
from __future__ import annotations

import json
import os

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# engine configurations the fixtures pin. seed_vmap is the paper-faithful
# per-leaf engine; the flat_* cases are the packed flat engine the fused
# loop builds on (bit-exact asserted), incl. a heterogeneous-K scenario
# and int8+EF21 delta compression.
CASES = {
    "seed_vmap": dict(flat=False),
    "flat_xla": dict(flat="xla"),
    "flat_scenario": dict(flat="xla", scenario="dirichlet_stragglers"),
    "flat_int8_ef21": dict(flat="xla", compression=True),
}

ROUNDS, CLIENTS, PART, BATCH, LOCAL_STEPS, SEED = 5, 20, 0.2, 8, 3, 7


def run_case(name):
    """-> {"loss": [R floats], "loss_last_step": [...], "eta_mean":
    [...], "params_l2": float} for one fixture case. Fully
    deterministic: fixed seeds, fixed cohort draws keyed on (seed,
    round), eval rng untouched."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.paper_tasks import MLP_SMALL
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.data.pipeline import FederatedDataset
    from repro.data.synthetic import get_task
    from repro.models.small import make_small_model, softmax_ce

    spec = CASES[name]
    scn = None
    if spec.get("scenario"):
        from repro.federation import get_scenario
        scn = get_scenario(spec["scenario"], seed=SEED)
    comp = None
    if spec.get("compression"):
        from repro.compression import CompressionSpec
        comp = CompressionSpec(kind="int8", error_feedback=True)

    task = get_task("easy", seed=SEED)
    fed = FederatedDataset.build(task, num_clients=CLIENTS, alpha=0.5,
                                 seed=SEED, scenario=scn)
    init_fn, logits_fn = make_small_model(MLP_SMALL)
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}))
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(loss_fn, copt, sopt, num_rounds=ROUNDS,
                                flat=spec["flat"], scenario=scn,
                                num_clients=CLIENTS,
                                client_sizes=(fed.client_sizes()
                                              if scn else None),
                                compression=comp))
    from repro.federation.schedulers import cohort_size
    C = cohort_size(PART, CLIENTS)
    state = init_fl_state(init_fn(jax.random.key(SEED)), sopt, scn,
                          compression=comp, cohort=C)
    out = {"loss": [], "loss_last_step": [], "eta_mean": []}
    for t in range(ROUNDS):
        bat, _, _ = fed.sample_round(PART, LOCAL_STEPS, BATCH,
                                     round_idx=t)
        state, m, _ = rnd(state, {"x": jnp.asarray(bat["x"]),
                                  "y": jnp.asarray(bat["y"])})
        for k in out:
            out[k].append(float(np.float32(m[k])))
    out["params_l2"] = float(np.float32(np.sqrt(sum(
        float(jnp.sum(jnp.square(l.astype(jnp.float32))))
        for l in jax.tree_util.tree_leaves(state.params)))))
    return out


def fixture_path(name):
    return os.path.join(GOLDEN_DIR, name + ".json")


def load_fixture(name):
    with open(fixture_path(name)) as f:
        return json.load(f)
