"""Delta-compression subsystem (repro.compression + kernels/compress):
kernel parity vs the pure-jnp oracle, int8/top-k contracts, EF21
round-level behavior, bit-exactness of the inert spec, and the sharded
compressed round (parity + both HLO assertions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import (CompressionSpec, compress_flat,
                               get_compression)
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.core import flat as fp
from repro.kernels.compress import compress as ck
from repro.kernels.compress import ref as cr

LANES = fp.LANES


def _buf(rng, C=3, chunks=5):
    return jnp.asarray(rng.normal(size=(C, chunks * LANES)), jnp.float32)


# ------------------------------------------------------------------ kernels
def test_quantize_int8_interpret_matches_ref(rng):
    x = _buf(rng)
    q, s = ck.quantize_int8(x, interpret=True)
    qr, sr = cr.quantize_int8_ref(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    dq = ck.dequantize_int8(q, s, interpret=True)
    dqr = cr.dequantize_int8_ref(qr, sr)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dqr),
                               rtol=1e-5, atol=1e-5)


def test_int8_dequant_error_bound(rng):
    """Satellite acceptance: |dequant(quant(x)) − x| ≤ scale/2 per chunk
    (symmetric rounding to 127 levels), and zero chunks are exact."""
    x = _buf(rng, C=2, chunks=4)
    x = x.at[1, :LANES].set(0.0)      # one all-zero chunk
    q, s = ck.quantize_int8(x, interpret=True)
    dq = ck.dequantize_int8(q, s, interpret=True)
    err = jnp.abs(dq - x).reshape(2, -1, LANES)
    bound = (s / 2.0 + 1e-7)[..., None]
    assert bool(jnp.all(err <= bound)), float(jnp.max(err - bound))
    assert float(jnp.max(jnp.abs(dq[1, :LANES]))) == 0.0


@pytest.mark.parametrize("k", [1, 32, LANES])
def test_topk_keeps_exactly_k_per_row(k, rng):
    """Satellite acceptance: exactly k slots survive per LANES-chunk —
    distinct magnitudes, full ties, and the k=LANES identity."""
    x = _buf(rng, C=2, chunks=3)
    out = ck.topk_mask(x, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(cr.topk_mask_ref(x, k)))
    kept = jnp.sum((out != 0.0).reshape(2, -1, LANES), axis=-1)
    assert bool(jnp.all(kept == k)), np.asarray(kept)
    if k < LANES:
        # kept entries are the largest: min kept |x| >= max dropped |x|
        a = jnp.abs(x).reshape(2, -1, LANES)
        keep = (out != 0.0).reshape(2, -1, LANES)
        min_kept = jnp.min(jnp.where(keep, a, jnp.inf), axis=-1)
        max_drop = jnp.max(jnp.where(keep, -jnp.inf, a), axis=-1)
        assert bool(jnp.all(min_kept >= max_drop))
    # ties: constant-magnitude chunk keeps the FIRST k lanes
    xc = jnp.ones((1, LANES), jnp.float32)
    tc = cr.topk_mask_ref(xc, min(k, 5))
    kept = np.flatnonzero(np.asarray(tc[0]))
    np.testing.assert_array_equal(kept, np.arange(min(k, 5)))


def test_topk_rejects_bad_k(rng):
    x = _buf(rng, C=1, chunks=1)
    for bad in (0, LANES + 1):
        with pytest.raises(ValueError):
            ck.topk_mask(x, bad, interpret=True)
        with pytest.raises(ValueError):
            cr.topk_mask_ref(x, bad)


def test_compress_flat_backends_agree_and_levels_select(rng):
    x = _buf(rng)
    spec = CompressionSpec(kind="int8")
    a = compress_flat(x, spec, backend="pallas", interpret=True)
    b = compress_flat(x, spec, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
    levels = jnp.asarray([0, 1, 2], jnp.int32)
    out = compress_flat(x, spec, levels=levels, backend="xla")
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(
        np.asarray(out[1]),
        np.asarray(cr.dequantize_int8_ref(*cr.quantize_int8_ref(x))[1]))
    np.testing.assert_array_equal(
        np.asarray(out[2]), np.asarray(cr.topk_mask_ref(x, spec.k)[2]))


# --------------------------------------------------------------------- spec
def test_spec_validation_and_wire_math():
    with pytest.raises(KeyError):
        CompressionSpec(kind="fp4")
    with pytest.raises(ValueError):
        CompressionSpec(k_frac=0.0)
    spec = CompressionSpec(kind="int8", k_frac=0.25)
    assert spec.k == 32 and spec.level == 1
    n = 4 * LANES
    table = spec.level_wire_bytes(n)
    assert table[0] == 4 * n                       # f32
    assert table[1] == n + 4 * (n // LANES)        # int8 + scales
    assert table[2] == 5 * spec.k * (n // LANES)   # topk value+index
    wb = spec.wire_bytes(n, levels=jnp.asarray([0, 1, 2]))
    np.testing.assert_allclose(np.asarray(wb), table)
    wb_fixed = spec.wire_bytes(n, num_clients=3)
    np.testing.assert_allclose(np.asarray(wb_fixed), [table[1]] * 3)
    # inert vs active
    assert not CompressionSpec().active()
    assert CompressionSpec(error_feedback=True).active()
    assert get_compression("topk").active()
    from repro.federation import get_scenario
    assert CompressionSpec().active(get_scenario("bandwidth_tiered"))
    assert not CompressionSpec().active(get_scenario("sync_iid"))


def test_bandwidth_scenario_draws():
    from repro.federation import Scenario, get_scenario
    with pytest.raises(KeyError):
        Scenario("bad", bandwidth="dsl")
    with pytest.raises(ValueError):
        # tier_probs must cover the 3-level ladder exactly — a short or
        # long tuple would silently draw out-of-ladder levels
        Scenario("bad", bandwidth="tiered", tier_probs=(0.5, 0.5))
    scn = get_scenario("bandwidth_tiered")
    assert scn.bandwidth_heterogeneous
    l1 = scn.draw_compression_levels(3, 64)
    l2 = scn.draw_compression_levels(3, 64)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert l1.dtype == jnp.int32
    assert int(jnp.min(l1)) >= 0 and int(jnp.max(l1)) <= 2
    # different rounds draw different mixes
    l3 = scn.draw_compression_levels(4, 64)
    assert not np.array_equal(np.asarray(l1), np.asarray(l3))
    uni = get_scenario("bandwidth_tiered", bandwidth="uniform")
    lu = uni.draw_compression_levels(0, 256)
    assert set(np.unique(np.asarray(lu))) <= {0, 1, 2}
    assert not get_scenario("sync_iid").bandwidth_heterogeneous


# ------------------------------------------------------------- round engine
def _quad_problem(rng, D=300, C=4, K=3):
    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)) / np.sqrt(D),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    return quad, params, batches


def test_inert_spec_bit_exact_all_engines(rng):
    """Acceptance: with compression="none" all three engines produce
    bit-identical states vs a round built without any compression."""
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    for eng in (False, "xla", "pallas"):
        r0 = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                   flat=eng))
        r1 = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                   flat=eng, compression="none"))
        s0, s1 = init_fl_state(params, sopt), init_fl_state(params, sopt)
        for _ in range(2):
            s0, m0, _ = r0(s0, batches)
            s1, m1, _ = r1(s1, batches)
        np.testing.assert_array_equal(np.asarray(s0.params["x"]),
                                      np.asarray(s1.params["x"]))
        assert "wire_bytes" not in m1     # inert spec: no telemetry
        assert s1.ef is None


def test_vmap_engine_rejects_active_compression(rng):
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(lambda p, b: (0.0, {}))
    with pytest.raises(ValueError):
        make_fl_round(loss, copt, sopt, num_rounds=1, compression="int8")
    with pytest.raises(ValueError):
        make_fl_round(loss, copt, sopt, num_rounds=1,
                      compression=CompressionSpec(error_feedback=True))


def test_ef_requires_allocated_state(rng):
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    spec = CompressionSpec(kind="int8", error_feedback=True)
    rnd = make_fl_round(make_loss(quad), copt, sopt, num_rounds=10,
                        flat="xla", compression=spec)
    st = init_fl_state(params, sopt)          # no ef allocated
    with pytest.raises(ValueError):
        jax.eval_shape(lambda s, b: rnd(s, b), st, batches)
    with pytest.raises(ValueError):
        init_fl_state(params, sopt, compression=spec)   # cohort missing


def test_ef21_roundtrip_int8_converges_to_none(rng):
    """Satellite acceptance: with EF21 error feedback the int8-compressed
    run tracks the uncompressed run's loss on the synthetic quad task —
    and EF keeps it strictly closer than naive int8 compression."""
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)

    def run(spec, ef):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=30,
                                    flat="xla", compression=spec))
        st = init_fl_state(params, sopt, compression=spec,
                           cohort=4 if ef else None)
        m = {}
        for _ in range(20):
            st, m, _ = rnd(st, batches)
        return float(m["loss"]), st

    l_none, _ = run(None, False)
    spec = CompressionSpec(kind="int8", error_feedback=True)
    l_int8, st = run(spec, True)
    l_raw, _ = run(CompressionSpec(kind="int8"), False)
    assert abs(l_int8 - l_none) <= 0.05 * abs(l_none) + 1e-6, \
        (l_int8, l_none)
    assert abs(l_int8 - l_none) <= abs(l_raw - l_none) + 1e-6
    # the EF tree tracks the last reconstructed delta: f32, (C,)+shape
    assert st.ef["x"].dtype == jnp.float32
    assert st.ef["x"].shape == (4, 300)
    assert float(jnp.max(jnp.abs(st.ef["x"]))) > 0.0


def test_compressed_round_telemetry_and_async(rng):
    """Wire telemetry in the metrics + compression composes with the
    FedBuff async buffer (deltas enter the buffer dequantized)."""
    from repro.federation import get_scenario
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    layout = fp.layout_of(params)
    spec = CompressionSpec(kind="topk", k_frac=0.25)
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                flat="xla", compression=spec))
    st = init_fl_state(params, sopt)
    st, m, _ = rnd(st, batches)
    C, chunks = 4, -(-layout.size // LANES)
    want = 5.0 * spec.k * chunks * C
    assert float(m["wire_bytes"]) == want
    np.testing.assert_allclose(
        float(m["comp_ratio"]),
        4.0 * layout.size * C / want, rtol=1e-6)

    scn = get_scenario("zipf_async", staleness_max=0, buffer_size=4)
    spec = CompressionSpec(kind="int8", error_feedback=True)
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                flat="xla", scenario=scn,
                                compression=spec))
    st = init_fl_state(params, sopt, scn, compression=spec, cohort=4)
    for _ in range(2):
        st, m, _ = rnd(st, batches)
    assert st.buffer is not None and st.ef is not None
    assert "wire_bytes" in m and float(m["flushed"]) == 1.0
    assert np.isfinite(float(m["loss"]))


def test_compression_launch_counts(rng):
    """int8 adds exactly 2 compress launches per traced round (quantize +
    dequantize), top-k exactly 1 — and the Δ-SGD step pair stays at 2."""
    from repro.kernels.delta_sgd import delta_sgd as dk
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    for kind, n_comp in (("int8", 2), ("topk", 1)):
        rnd = make_fl_round(loss, copt, sopt, num_rounds=10,
                            flat="pallas", compression=kind)
        st = init_fl_state(params, sopt)
        dk.reset_launch_count()
        ck.reset_launch_count()
        jax.eval_shape(lambda s, b: rnd(s, b), st, batches)
        assert dk.launch_count() == 2, dict(dk.LAUNCHES)
        assert ck.launch_count() == n_comp, dict(ck.LAUNCHES)


def test_bandwidth_hetero_round_mixes_levels(rng):
    """bandwidth_tiered: the per-client level draw selects compressors
    per lane — lanes at level 0 aggregate their exact delta."""
    from repro.federation import get_scenario
    quad, params, batches = _quad_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    scn = get_scenario("bandwidth_tiered")
    spec = CompressionSpec(kind="int8")
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10, flat="xla",
                                scenario=scn, compression=spec))
    st = init_fl_state(params, sopt, scn)
    st, m, _ = rnd(st, batches)
    levels = np.asarray(scn.draw_compression_levels(0, 4))
    want = float(jnp.sum(spec.wire_bytes(
        fp.layout_of(params).size, levels=jnp.asarray(levels))))
    assert float(m["wire_bytes"]) == want
    np.testing.assert_allclose(float(m["comp_level_mean"]),
                               levels.astype(np.float32).mean(), rtol=1e-6)
    # a bandwidth-hetero scenario implies compression even with no
    # compression= argument: the engine resolves the inert "none" spec
    # (level-0 clients pass through, level-1/2 get compressed) ...
    rnd0 = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                 flat="xla", scenario=scn))
    _, m0, _ = rnd0(init_fl_state(params, sopt, scn), batches)
    assert "wire_bytes" in m0 and "comp_level_mean" in m0
    # ... and, like async, it cannot run on the vmap engine
    with pytest.raises(ValueError):
        make_fl_round(loss, copt, sopt, num_rounds=10, scenario=scn)


# ---------------------------------------------------------------- sharded
needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")


def _fl_problem(rng, C=8, K=3, D=300, E=40):
    """Mixed f32/bf16 quadratic FL problem (same shape as test_flat)."""
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    return quad, params, batches


@needs8
@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_sharded_compressed_round_matches_replicated(kind, rng):
    """Tentpole acceptance: the compressed sharded round (compress
    before the client-mean psum, inside shard_map) matches the
    compressed replicated round to <= 1e-5, EF + bandwidth levels
    included."""
    from repro.federation import get_scenario
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    quad, params, batches = _fl_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    cspec = CompressionSpec(kind=kind, error_feedback=True)
    scn = get_scenario("bandwidth_tiered")
    out = {}
    for name, kw in (("repl", {}),
                     ("shard", dict(mesh=mesh, federation=spec))):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat="xla", scenario=scn,
                                    compression=cspec, **kw))
        st = init_fl_state(params, sopt, scn, compression=cspec, cohort=8)
        for _ in range(2):
            st, m, _ = rnd(st, batches)
        out[name] = (np.asarray(st.params["x"]),
                     np.asarray(st.ef["x"]),
                     np.asarray([m["loss"], m["wire_bytes"],
                                 m["comp_ratio"]], np.float64))
    for a, b in zip(out["repl"], out["shard"]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@needs8
@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_sharded_compressed_round_hlo_assertions(kind, rng):
    """Acceptance: under the 8-device test mesh, for both int8 and
    top-k, the compiled compressed sharded round (a) never materializes
    the full (C, N) buffer and (b) ships no full-precision client delta
    across the client shard boundary."""
    from repro.federation import get_scenario
    from repro.sharding.hlo import (assert_flat_buffer_sharded,
                                    assert_no_fullprec_delta_collective)
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    quad, params, batches = _fl_problem(rng)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    loss = make_loss(quad)
    cspec = CompressionSpec(kind=kind, error_feedback=True)
    scn = get_scenario("bandwidth_tiered")
    rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="xla",
                        scenario=scn, compression=cspec,
                        mesh=mesh, federation=spec)
    st = init_fl_state(params, sopt, scn, compression=cspec, cohort=8)
    lay = fp.layout_of(params, shards=spec.flat_shards(mesh))
    compiled = jax.jit(rnd).lower(st, batches).compile()
    assert_flat_buffer_sharded(compiled, 8, lay.padded_size)
    rep = assert_no_fullprec_delta_collective(compiled, 8,
                                              lay.padded_size,
                                              mesh=mesh, federation=spec)
    assert rep["collectives"] > 0     # the check actually saw traffic


@needs8
def test_fullprec_collective_report_has_teeth():
    """The boundary checker itself: client-crossing big f32 collectives
    are flagged, intra-client flat-dim reshards and operand-name
    mentions are not, unparseable groups are conservative."""
    from repro.sharding.hlo import (_client_coords,
                                    fullprec_collective_report)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    coords = _client_coords(mesh, ("data",))
    cross = ('  %all-gather = f32[2,256]{1,0} all-gather(f32[2,64] %p), '
             'replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={1}')
    intra = ('  %all-reduce = f32[2,512]{1,0} all-reduce(f32[2,512] %p), '
             'replica_groups={{0,1},{2,3},{4,5},{6,7}}, to_apply=%add')
    small = ('  %all-reduce.2 = f32[256]{0} all-reduce(f32[256] %p), '
             'replica_groups={{0,2,4,6},{1,3,5,7}}, to_apply=%add')
    operand = ('  %f = f32[2,512]{1,0} fusion(f32[2,512] '
               '%all-gather.3), kind=kLoop')
    noparse = ('  %all-gather.9 = f32[2,256]{1,0} all-gather(f32[2,64] '
               '%p), replica_groups=[2,4]<=[8], dimensions={1}')
    allrep = ('  %all-reduce.7 = f32[2,256]{1,0} all-reduce(f32[2,256] '
              '%p), replica_groups={}, to_apply=%add')
    text = "\n".join([cross, intra, small, operand, noparse, allrep])
    rep = fullprec_collective_report(text, max_elems=2 * 256,
                                     client_coord_of=coords)
    assert rep["collectives"] == 5          # operand mention not counted
    # cross + unparseable + empty-groups (= ALL devices, spans clients)
    assert rep["fullprec"] == 3
    assert "all-gather" in rep["sample"][0]
