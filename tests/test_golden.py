"""Golden-trajectory regression tier: small deterministic runs pinned
against committed fixtures (tests/golden/*.json, regenerated only by
``python tests/golden/regen.py``).

Assertions per the regression contract:
  * flat engines (flat_xla, flat_scenario, flat_int8_ef21) reproduce
    their fixture BIT-EXACTLY on the fixture's jax version (<= 1e-6
    across versions — the latest-jax CI leg);
  * the seed vmap engine reproduces its fixture the same way;
  * cross-engine (flat vs the seed vmap trajectory) stays <= 1e-5 —
    the engine-parity envelope the repo has tested since PR 1.
"""
import numpy as np
import pytest

from _golden_common import CASES, load_fixture, run_case

TRACE_KEYS = ("loss", "loss_last_step", "eta_mean")


def _assert_trace(got, fixture, *, exact):
    import jax
    same_version = fixture.get("jax") == jax.__version__
    for k in TRACE_KEYS + ("params_l2",):
        a = np.asarray(got[k], np.float32)
        b = np.asarray(fixture[k], np.float32)
        if exact and same_version:
            np.testing.assert_array_equal(a, b, err_msg=k)
        else:
            # cross-jax-version leg: identical math, but XLA is free to
            # re-fuse — hold the trace to a tight numerical envelope
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=k)


@pytest.fixture(scope="module")
def traces():
    return {name: run_case(name) for name in CASES}


@pytest.mark.parametrize("name", list(CASES))
def test_golden_trajectory(name, traces):
    _assert_trace(traces[name], load_fixture(name), exact=True)


def test_cross_engine_envelope(traces):
    """flat engine vs the seed vmap engine on the IDENTICAL run: the
    1e-5 parity envelope (same protocol as the PR 1 parity tests, now
    pinned against the committed seed trajectory)."""
    vmap_fix = load_fixture("seed_vmap")
    for k in TRACE_KEYS:
        np.testing.assert_allclose(
            np.asarray(traces["flat_xla"][k], np.float32),
            np.asarray(vmap_fix[k], np.float32),
            rtol=1e-5, atol=1e-5, err_msg=k)
