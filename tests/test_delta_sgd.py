"""Unit + property tests for the Δ-SGD step-size rule (paper Eq. 4 /
Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.delta_sgd import (delta_sgd_init, delta_sgd_reset,
                                  delta_sgd_update, _global_norm)

GAMMA, DELTA, ETA0, THETA0 = 2.0, 0.1, 0.2, 1.0


def _params(vals):
    return {"w": jnp.asarray(vals, jnp.float32)}


def _step(params, grads, state):
    return delta_sgd_update(params, grads, state, gamma=GAMMA, delta=DELTA,
                            eta0=ETA0)


def test_first_step_uses_eta0():
    p = _params([1.0, 2.0])
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    g = _params([1.0, 1.0])
    p2, s2 = _step(p, g, s)
    assert float(s2.eta) == pytest.approx(ETA0)
    np.testing.assert_allclose(p2["w"], np.array([1.0, 2.0]) - ETA0,
                               rtol=1e-6)


def test_growth_bound_and_theta():
    """Second condition: η_k ≤ sqrt(1+δθ_{k-1})·η_{k-1}; θ = η_k/η_{k-1}."""
    p = _params(np.linspace(1, 4, 8))
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    rng = np.random.default_rng(0)
    prev_eta = None
    for k in range(6):
        g = _params(rng.normal(size=8).astype(np.float32))
        p, s = _step(p, g, s)
        eta = float(s.eta)
        assert np.isfinite(eta) and eta > 0
        if prev_eta is not None:
            bound = np.sqrt(1 + DELTA * prev_theta) * prev_eta
            assert eta <= bound * (1 + 1e-5)
            assert float(s.theta) == pytest.approx(eta / prev_eta, rel=1e-5)
        prev_eta, prev_theta = eta, float(s.theta)


def test_smoothness_estimate_on_quadratic():
    """On f(x) = 0.5 λ‖x‖², ∇f = λx, the first condition equals
    γ/(2λ) exactly — the rule measures inverse local curvature."""
    lam = 4.0
    x = _params([1.0, -2.0, 3.0])
    s = delta_sgd_init(x, eta0=ETA0, theta0=THETA0)
    for _ in range(8):
        g = {"w": lam * x["w"]}
        x, s = _step(x, g, s)
    # after warm-up the curvature term γ/(2λ) = 0.25 should bind
    assert float(s.eta) == pytest.approx(GAMMA / (2 * lam), rel=1e-3)


def test_reset_restores_round_start():
    p = _params([1.0])
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    p, s = _step(p, {"w": jnp.asarray([2.0])}, s)
    p, s = _step(p, {"w": jnp.asarray([1.0])}, s)
    s = delta_sgd_reset(s, eta0=ETA0, theta0=THETA0)
    assert int(s.k) == 0
    assert float(s.eta) == pytest.approx(ETA0)
    assert float(s.theta) == pytest.approx(THETA0)


def test_dx_norm_identity():
    """The state-carried ‖Δx‖ = η_{k-1}‖g_{k-1}‖ must equal the explicit
    ‖x_k − x_{k-1}‖ (exact for SGD updates)."""
    rng = np.random.default_rng(1)
    p = _params(rng.normal(size=16).astype(np.float32))
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    p_hist = [p["w"].copy()]
    for _ in range(4):
        g = _params(rng.normal(size=16).astype(np.float32))
        p, s = _step(p, g, s)
        p_hist.append(p["w"].copy())
    implied = float(s.eta * 0 + s.prev_grad_norm * s.eta)  # next-step dx
    explicit = float(jnp.linalg.norm(p_hist[-1] - p_hist[-2]))
    # prev_grad_norm*eta corresponds to the LAST update made
    assert implied == pytest.approx(explicit, rel=1e-5)


def test_zero_grad_delta_no_nan():
    """Identical consecutive grads (dg=0) must fall back to the growth
    condition, not NaN."""
    p = _params([1.0, 1.0])
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    g = _params([0.5, 0.5])
    p, s = _step(p, g, s)
    p, s = _step(p, g, s)  # same grads -> dg = 0
    assert np.isfinite(float(s.eta))
    assert float(s.eta) == pytest.approx(
        np.sqrt(1 + DELTA * THETA0) * ETA0, rel=1e-5)


def test_groupwise_variant_runs():
    p = {"a": jnp.ones((4,)), "b": jnp.ones((3,))}
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0, groupwise=True)
    g = {"a": jnp.ones((4,)) * 0.1, "b": jnp.ones((3,)) * 10.0}
    p, s = delta_sgd_update(p, g, s, gamma=GAMMA, delta=DELTA, eta0=ETA0)
    p, s = delta_sgd_update(p, g, s, gamma=GAMMA, delta=DELTA, eta0=ETA0)
    assert set(s.eta) == {"a", "b"}
    assert all(np.isfinite(float(v)) for v in s.eta.values())


def test_pallas_path_matches_jnp():
    rng = np.random.default_rng(2)
    p = {"a": jnp.asarray(rng.normal(size=(33, 7)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(129,)), jnp.float32)}
    g1 = jax.tree.map(lambda x: x * 0.1, p)
    g2 = jax.tree.map(lambda x: x * -0.2 + 0.01, p)
    for use_pallas in (False, True):
        pp = p
        s = delta_sgd_init(pp, eta0=ETA0, theta0=THETA0)
        for g in (g1, g2, g1):
            pp, s = delta_sgd_update(pp, g, s, gamma=GAMMA, delta=DELTA,
                                     eta0=ETA0, use_pallas=use_pallas)
        if use_pallas:
            np.testing.assert_allclose(pp["a"], ref_p["a"], rtol=1e-5)
            np.testing.assert_allclose(float(s.eta), ref_eta, rtol=1e-5)
        else:
            ref_p, ref_eta = pp, float(s.eta)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-5, 5, allow_nan=False), min_size=4, max_size=16),
       st.integers(2, 6))
def test_property_eta_positive_finite_bounded(vals, steps):
    """For any gradient sequence: η stays positive, finite, and obeys the
    growth bound; params stay finite."""
    rng = np.random.default_rng(abs(hash(tuple(vals))) % 2**31)
    p = _params(np.asarray(vals, np.float32))
    s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
    prev = None
    for k in range(steps):
        g = _params(rng.normal(size=len(vals)).astype(np.float32) * 10)
        p, s = _step(p, g, s)
        eta = float(s.eta)
        assert np.isfinite(eta) and eta > 0
        assert np.all(np.isfinite(np.asarray(p["w"])))
        if prev is not None:
            assert eta <= np.sqrt(1 + DELTA * prev_theta) * prev * (1 + 1e-5)
        prev, prev_theta = eta, float(s.theta)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(_global_norm(t)) == pytest.approx(5.0)
