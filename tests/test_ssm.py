"""Recurrent mixers: chunked forms vs exact sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm

_jmamba2_step = jax.jit(ssm.mamba2_step, static_argnames=("cfg",))
_jmlstm_step = jax.jit(ssm.mlstm_step, static_argnames=("cfg",))
_jslstm_step = jax.jit(ssm.slstm_step, static_argnames=("cfg",))


@pytest.fixture
def zcfg():
    return get_config("zamba2-7b").reduced()


@pytest.fixture
def xcfg():
    return get_config("xlstm-1.3b").reduced()


def test_mamba2_full_vs_stepwise(zcfg, rng):
    """Chunked SSD over S tokens == S recurrent decode steps."""
    p = ssm.init_mamba2(jax.random.key(0), zcfg, jnp.float32)
    B, S = 2, 37
    x = jnp.asarray(rng.normal(size=(B, S, zcfg.d_model)) * 0.3, jnp.float32)
    y_full, cache = ssm.mamba2_full(p, x, zcfg, build_cache=True)
    c = ssm.init_mamba2_cache(zcfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y, c = _jmamba2_step(p, x[:, t:t + 1], zcfg, c)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_seq, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(cache["ssm"], c["ssm"], rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(cache["conv"], c["conv"], rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(S=st.integers(2, 70), seed=st.integers(0, 999))
@pytest.mark.slow
def test_mamba2_chunk_invariance(S, seed):
    """Property: output independent of chunk length."""
    cfg = get_config("zamba2-7b").reduced()
    r = np.random.default_rng(seed)
    B, H, P, G, N = 1, 4, 16, 1, 8
    x = jnp.asarray(r.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.001, 0.2, (B, S, H)), jnp.float32)
    A_log = jnp.asarray(np.log(r.uniform(1, 8, (H,))), jnp.float32)
    Bm = jnp.asarray(r.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(r.normal(size=(B, S, G, N)), jnp.float32)
    y1, h1 = ssm._ssd_chunked(x, dt, A_log, Bm, Cm, chunk=64)
    y2, h2 = ssm._ssd_chunked(x, dt, A_log, Bm, Cm, chunk=7)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)


def test_mlstm_full_vs_stepwise(xcfg, rng):
    p = ssm.init_mlstm(jax.random.key(1), xcfg, jnp.float32)
    B, S = 2, 29
    x = jnp.asarray(rng.normal(size=(B, S, xcfg.d_model)) * 0.3, jnp.float32)
    y_full, cache = ssm.mlstm_full(p, x, xcfg, build_cache=True)
    c = ssm.init_mlstm_cache(xcfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y, c = _jmlstm_step(p, x[:, t:t + 1], xcfg, c)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_seq, rtol=3e-3, atol=3e-4)
    np.testing.assert_allclose(cache["C"], c["C"], rtol=3e-3, atol=3e-4)
    np.testing.assert_allclose(cache["m"], c["m"], rtol=1e-4, atol=1e-5)


def test_slstm_full_vs_stepwise(xcfg, rng):
    p = ssm.init_slstm(jax.random.key(2), xcfg, jnp.float32)
    B, S = 2, 17
    x = jnp.asarray(rng.normal(size=(B, S, xcfg.d_model)) * 0.3, jnp.float32)
    y_full, cache = ssm.slstm_full(p, x, xcfg, build_cache=True)
    c = ssm.init_slstm_cache(xcfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y, c = _jslstm_step(p, x[:, t:t + 1], xcfg, c)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_seq, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(cache["h"], c["h"], rtol=2e-3, atol=2e-4)


def test_mlstm_gate_stability(xcfg, rng):
    """Extreme gate pre-activations must not produce NaN/Inf (the
    stabilizer m is the whole point)."""
    p = ssm.init_mlstm(jax.random.key(3), xcfg, jnp.float32)
    p = dict(p)
    p["b_if"] = p["b_if"] + 40.0  # huge input-gate bias
    x = jnp.asarray(rng.normal(size=(1, 24, xcfg.d_model)) * 3, jnp.float32)
    y, _ = ssm.mlstm_full(p, x, xcfg)
    assert np.all(np.isfinite(np.asarray(y)))
