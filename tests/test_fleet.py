"""Fleet-scale simulation (PR 7): the sharded client-state arena, the
10^5-candidate schedulers, and the block-level shard_map loop.

Contracts under test:
  * Gumbel-top-k schedulers stay deterministic and correctly skewed at
    C_registered = 10^5, and their sample trace never materializes a
    buffer wider than a few O(C_registered) vectors (no O(C_reg * N),
    no O(C_reg * cohort)).
  * Arena gather/scatter round-trips exactly: ``arena_take`` is plain
    row indexing, an identity ``arena_update`` is a bit-level no-op,
    and rows of never-sampled clients stay bit-identical through any
    number of scatters (property-tested).
  * ``make_fleet_loop`` with eta_carry off, EF off and no weights is
    BIT-EXACT against ``make_fl_loop`` on the same stacked data (it
    runs the identical flat round body), while its arena bookkeeping
    (rounds_seen / last_round / cohort_ids) replays exactly from the
    host-side scheduler draw.
  * Fleet memory ceiling: the compiled fleet program materializes
    nothing wider than O(C_registered) scalars along the registered
    dim (EF21 relaxes this by exactly its one (C_reg, N) slab).
  * The block-level shard_map loop (one shard_map around the whole
    R-round scan) matches the replicated engine, fuses bit-exactly
    (R=1 blocks vs one R-block), and passes both sharding HLO
    assertions on the SCANNED program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (flatten_fl_state, get_client_opt, get_server_opt,
                        init_fl_state, make_fl_loop, make_fleet_loop)
from repro.federation import (ClientArena, arena_init, arena_take,
                              arena_update, get_scenario, make_scheduler)
from repro.sharding.hlo import (assert_cohort_only_materialization,
                                cohort_materialization_report)

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")

R, C, K, D, E = 4, 8, 3, 96, 18
M_BIG = 100_000


def _problem(rng, rounds=R):
    """Quadratic FL problem, mixed f32/bf16 tree, stacked rounds."""
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(rounds, C, K, 4, D)),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(rounds, C, K, 4)),
                                jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    from repro.core import make_loss
    return make_loss(quad), params, batches


def _opts():
    return (get_client_opt("delta_sgd", gamma=2.0, eta0=0.2, theta0=1.0,
                           delta=0.1),
            get_server_opt("fedavg"))


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


# ---------------------------------------------------------------------------
# schedulers at fleet scale
# ---------------------------------------------------------------------------

def _jaxpr_max_elems(closed):
    """Largest intermediate buffer (in elements) anywhere in a jaxpr,
    including sub-jaxprs (scan/cond/pjit bodies) — duck-typed so it
    works across jax versions without jax.core imports."""
    mx = 0
    stack = [closed.jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                shape = getattr(getattr(v, "aval", None), "shape", None)
                if shape:
                    mx = max(mx, int(np.prod(shape)))
            for p in eqn.params.values():
                for q in (p if isinstance(p, (list, tuple)) else (p,)):
                    sub = getattr(q, "jaxpr", q)
                    if hasattr(sub, "eqns"):
                        stack.append(sub)
    return mx


@pytest.mark.parametrize("kind", ["uniform", "zipf", "cyclic",
                                  "size_weighted"])
def test_scheduler_100k_deterministic_distinct(kind):
    sizes = (jnp.ones((M_BIG,), jnp.float32)
             if kind == "size_weighted" else None)
    sch = make_scheduler(kind, num_clients=M_BIG, cohort=64, sizes=sizes)
    key = jax.random.key(3)
    a = np.asarray(sch.sample(key, 5))
    b = np.asarray(sch.sample(key, 5))
    c = np.asarray(sch.sample(key, 6))
    np.testing.assert_array_equal(a, b)          # same (key, t) -> same
    assert len(np.unique(a)) == 64               # without replacement
    assert a.min() >= 0 and a.max() < M_BIG
    assert not np.array_equal(a, c)              # fold_in(t) decorrelates


def test_zipf_100k_skew():
    sch = make_scheduler("zipf", num_clients=M_BIG, cohort=64)
    key = jax.random.key(0)
    samp = jax.jit(lambda t: sch.sample(key, t))
    ids = np.concatenate([np.asarray(samp(jnp.int32(t)))
                          for t in range(30)])
    # s=1.2 puts >80% of the mass on the first decile of ranks; a
    # uniform draw would land ~10% there
    frac_low = np.mean(ids < M_BIG // 10)
    assert frac_low > 0.5, frac_low
    assert ids.mean() < M_BIG / 4, ids.mean()


@pytest.mark.parametrize("kind", ["uniform", "zipf"])
def test_scheduler_100k_trace_stays_o_registered(kind):
    """The sample trace may hold a few (C_reg,) vectors (weights,
    gumbels, random bits) but nothing O(C_reg * cohort) or wider."""
    sch = make_scheduler(kind, num_clients=M_BIG, cohort=64)
    key = jax.random.key(0)
    closed = jax.make_jaxpr(lambda t: sch.sample(key, t))(jnp.int32(0))
    mx = _jaxpr_max_elems(closed)
    assert mx <= 4 * M_BIG, (
        f"scheduler trace materializes a {mx}-element buffer "
        f"(> 4 * C_registered = {4 * M_BIG})")


# ---------------------------------------------------------------------------
# arena gather/scatter round-trip (property tests — run under real
# hypothesis or the deterministic fallback in tests/_hypothesis_fallback)
# ---------------------------------------------------------------------------

def _rand_arena(r, m, with_ef):
    return ClientArena(
        jnp.asarray(r.normal(size=m), jnp.float32),
        jnp.asarray(r.integers(0, 5, size=m), jnp.int32),
        jnp.asarray(r.integers(-1, 7, size=m), jnp.int32),
        jnp.asarray(r.normal(size=(m, 6)), jnp.float32)
        if with_ef else None)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(m=st.integers(4, 64), k=st.integers(1, 8),
       seed=st.integers(0, 10_000), ef=st.integers(0, 1))
def test_arena_roundtrip_property(m, k, seed, ef):
    k = min(k, m)
    r = np.random.default_rng(seed)
    ids = jnp.asarray(r.choice(m, size=k, replace=False).astype(np.int32))
    arena = _rand_arena(r, m, bool(ef))
    rows = arena_take(arena, ids)
    # gather IS row indexing
    _assert_trees_equal(rows, jax.tree.map(lambda a: a[np.asarray(ids)],
                                           arena))
    # identity scatter is a bit-level no-op
    _assert_trees_equal(arena_update(arena, ids, rows), arena)
    # modified scatter touches exactly the sampled rows
    new_rows = jax.tree.map(lambda a: a + jnp.ones((), a.dtype), rows)
    upd = arena_update(arena, ids, new_rows)
    touched = np.zeros(m, bool)
    touched[np.asarray(ids)] = True
    for la, lu in zip(jax.tree_util.tree_leaves(arena),
                      jax.tree_util.tree_leaves(upd)):
        la, lu = np.asarray(la), np.asarray(lu)
        np.testing.assert_array_equal(lu[~touched], la[~touched])
        np.testing.assert_array_equal(lu[touched], la[touched] + 1)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 48), rounds=st.integers(1, 6),
       seed=st.integers(0, 10_000))
def test_arena_never_sampled_bit_identical_property(m, rounds, seed):
    """Clients outside every cohort keep bit-identical state through
    any sequence of scatters."""
    r = np.random.default_rng(seed)
    arena = _rand_arena(r, m, with_ef=True)
    ref = jax.tree.map(np.asarray, arena)
    ever = np.zeros(m, bool)
    for _ in range(rounds):
        k = int(r.integers(1, max(2, m // 3)))
        ids = r.choice(m, size=k, replace=False).astype(np.int32)
        ever[ids] = True
        rows = arena_take(arena, jnp.asarray(ids))
        arena = arena_update(arena, jnp.asarray(ids),
                             jax.tree.map(lambda a: a * 2 + 1, rows))
    for lr, la in zip(jax.tree_util.tree_leaves(ref),
                      jax.tree_util.tree_leaves(arena)):
        np.testing.assert_array_equal(np.asarray(la)[~ever], lr[~ever])


# ---------------------------------------------------------------------------
# fleet loop: bit-exactness, bookkeeping, eta carry, EF, memory ceiling
# ---------------------------------------------------------------------------

def _fleet_setup(rng, m, *, rounds=R, seed=7, **kw):
    loss, params, batches = _problem(rng, rounds=rounds)
    copt, sopt = _opts()
    loop = make_fleet_loop(loss, copt, sopt, params_like=params,
                           num_rounds=100, num_registered=m, flat="xla",
                           seed=seed, **kw)
    f0 = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
    return loss, params, batches, copt, sopt, loop, f0


def test_fleet_matches_fused_loop_bit_exact(rng):
    """eta_carry off + EF off: the fleet loop IS make_fl_loop plus
    arena bookkeeping — global state must match bit for bit."""
    loss, params, batches, copt, sopt, loop, f0 = _fleet_setup(rng, 500)
    car = arena_init(500, eta0=loop.eta0)
    (ff, _), mf = jax.jit(loop)((f0, car), batches)
    ref_loop = make_fl_loop(loss, copt, sopt, params_like=params,
                            num_rounds=100, flat="xla")
    fr, mr = jax.jit(ref_loop)(f0, batches)
    np.testing.assert_array_equal(np.asarray(ff.P), np.asarray(fr.P))
    _assert_trees_equal(ff.server_state, fr.server_state)
    for k in ("loss", "eta_mean", "eta_min", "eta_max"):
        np.testing.assert_array_equal(np.asarray(mf[k]),
                                      np.asarray(mr[k]))


def test_fleet_arena_bookkeeping_replays_from_scheduler(rng):
    m, seed = 200, 11
    _, _, batches, _, _, loop, f0 = _fleet_setup(rng, m, seed=seed)
    car = arena_init(m, eta0=loop.eta0)
    (_, ar), mets = jax.jit(loop)((f0, car), batches)
    # the on-device draw replays exactly from the host-side scheduler
    sch = make_scheduler("uniform", num_clients=m, cohort=C)
    key = jax.random.key(seed)
    host_ids = np.stack([np.asarray(sch.sample(key, t))
                         for t in range(R)])
    np.testing.assert_array_equal(np.asarray(mets["cohort_ids"]),
                                  host_ids)
    counts = np.bincount(host_ids.ravel(), minlength=m)
    np.testing.assert_array_equal(np.asarray(ar.rounds_seen), counts)
    last = np.full(m, -1, np.int32)
    for t in range(R):
        last[host_ids[t]] = t
    np.testing.assert_array_equal(np.asarray(ar.last_round), last)
    # never-sampled clients: state bit-identical to arena_init
    never = counts == 0
    assert never.any()
    np.testing.assert_array_equal(np.asarray(ar.eta)[never],
                                  np.float32(loop.eta0))
    # first-round cohort has no returning clients
    assert float(mets["revisit_frac"][0]) == 0.0
    assert 0.0 <= float(mets["revisit_frac"][-1]) <= 1.0


@pytest.mark.slow
def test_fleet_eta_carry_warm_starts_returning_clients(rng):
    """With a small fleet every client returns; the warm-started eta0
    changes the trajectory (and the arena stores round-end etas)."""
    m, rounds = 12, 6
    loss, params, batches = _problem(rng, rounds=rounds)
    copt, sopt = _opts()
    kw = dict(params_like=params, num_rounds=100, num_registered=m,
              flat="xla", seed=7)
    loop_c = make_fleet_loop(loss, copt, sopt, eta_carry=True, **kw)
    loop_n = make_fleet_loop(loss, copt, sopt, eta_carry=False, **kw)
    f0 = flatten_fl_state(init_fl_state(params, sopt), loop_c.layout)
    car = arena_init(m, eta0=loop_c.eta0)
    (fc, ac), mc = jax.jit(loop_c)((f0, car), batches)
    (fn, _), _ = jax.jit(loop_n)((f0, car), batches)
    assert float(jnp.max(jnp.abs(fc.P - fn.P))) > 0.0
    sampled = np.asarray(ac.rounds_seen) > 0
    assert np.any(np.asarray(ac.eta)[sampled] != np.float32(loop_c.eta0))
    assert np.all(np.isfinite(np.asarray(mc["eta_carry_mean"])))


@pytest.mark.slow
def test_fleet_ef_lives_in_arena(rng):
    """EF21 state persists per REGISTERED client: sampled rows' EF
    slabs change, never-sampled rows stay exactly zero, and the carried
    FlatFLState keeps ef=None between rounds."""
    from repro.compression import CompressionSpec
    m = 64
    scn = get_scenario("bandwidth_tiered")
    comp = CompressionSpec(kind="int8", error_feedback=True)
    _, _, batches, _, _, loop, f0 = _fleet_setup(
        rng, m, rounds=2, scenario=scn, compression=comp)
    car = arena_init(m, eta0=loop.eta0,
                     ef_width=loop.layout.padded_size)
    (ff, ar), mets = jax.jit(loop)((f0, car), batches)
    assert ff.ef is None
    ef = np.asarray(ar.ef)
    sampled = np.asarray(ar.rounds_seen) > 0
    assert np.abs(ef[sampled]).max() > 0.0
    np.testing.assert_array_equal(ef[~sampled], 0.0)
    # missing EF slab is a loud error, not a silent reset
    with pytest.raises(ValueError, match="EF slab"):
        loop((f0, arena_init(m, eta0=loop.eta0)), batches)


def test_fleet_memory_ceiling_cohort_only(rng):
    """Compiled HLO check: nothing wider than O(C_registered) scalars
    along the registered dim (the ISSUE's 10^5-client enabler). With
    EF21 the one (C_reg, N) slab the algorithm requires appears — and
    the detector must SEE it (negative control)."""
    m = 5000
    _, _, batches, _, _, loop, f0 = _fleet_setup(rng, m)
    car = arena_init(m, eta0=loop.eta0)
    compiled = jax.jit(loop).lower((f0, car), batches).compile()
    rep = assert_cohort_only_materialization(compiled, m)
    assert rep["vectors"] > 0          # the arena rows themselves
    # negative control: the EF fleet program DOES carry a (m, N) slab
    from repro.compression import CompressionSpec
    scn = get_scenario("bandwidth_tiered")
    rng2 = np.random.default_rng(0)
    _, _, b2, _, _, loop_ef, f2 = _fleet_setup(
        rng2, m, rounds=2, scenario=scn,
        compression=CompressionSpec(kind="int8", error_feedback=True))
    car_ef = arena_init(m, eta0=loop_ef.eta0,
                        ef_width=loop_ef.layout.padded_size)
    c2 = jax.jit(loop_ef).lower((f2, car_ef), b2).compile()
    assert cohort_materialization_report(c2.as_text(), m)["wide"] > 0
    with pytest.raises(AssertionError):
        assert_cohort_only_materialization(c2, m)
    # ... and max_cols=N readmits exactly that slab
    assert_cohort_only_materialization(
        c2, m, max_cols=loop_ef.layout.padded_size)


# ---------------------------------------------------------------------------
# block-level shard_map: the whole R-round scan inside ONE shard_map
# ---------------------------------------------------------------------------

def _block_loops(loss, params, scenario=None, num_clients=None):
    from repro.sharding.spec import FederationSpec
    copt, sopt = _opts()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fed = FederationSpec(client_axes=("data",), fsdp_axes=(), tp_axes=())
    kw = dict(params_like=params, num_rounds=100, flat="xla",
              scenario=scenario)
    if num_clients is not None:
        kw["num_clients"] = num_clients
    rep = make_fl_loop(loss, copt, sopt, **kw)
    blk = make_fl_loop(loss, copt, sopt, mesh=mesh, federation=fed,
                       block_sharded=True, **kw)
    return rep, blk, mesh, fed, sopt


@needs8
@pytest.mark.slow
def test_block_sharded_matches_replicated(rng):
    loss, params, batches = _problem(rng)
    rep, blk, _, _, sopt = _block_loops(loss, params)
    f0 = flatten_fl_state(init_fl_state(params, sopt), rep.layout)
    fr, mr = jax.jit(rep)(f0, batches)
    fb, mb = jax.jit(blk)(f0, batches)
    assert float(jnp.max(jnp.abs(fr.P - fb.P))) <= 1e-5
    for k in ("loss", "eta_mean", "eta_min", "eta_max",
              "eta_clip_rate", "nan_guard_rate"):
        np.testing.assert_allclose(np.asarray(mr[k]), np.asarray(mb[k]),
                                   atol=1e-2)


@needs8
@pytest.mark.slow
def test_block_fused_bit_exact_and_hlo(rng):
    """R=1 blocks host-looped == one R-round block (bit-exact: the
    scan body IS the round), and both sharding assertions hold on the
    SCANNED block program."""
    from repro.sharding.hlo import (assert_flat_buffer_sharded,
                                    assert_no_fullprec_delta_collective)
    loss, params, batches = _problem(rng)
    _, blk, mesh, fed, sopt = _block_loops(loss, params)
    f0 = flatten_fl_state(init_fl_state(params, sopt), blk.layout)
    fb, _ = jax.jit(blk)(f0, batches)
    fh = f0
    for r in range(R):
        fh, _ = jax.jit(blk)(fh, jax.tree.map(lambda x, r=r: x[r:r + 1],
                                              batches))
    assert float(jnp.max(jnp.abs(fh.P - fb.P))) == 0.0
    N = blk.layout.padded_size
    compiled = jax.jit(blk).lower(f0, batches).compile()
    assert_flat_buffer_sharded(compiled, C, N)
    assert_no_fullprec_delta_collective(compiled, C, N, mesh=mesh,
                                        federation=fed)


@needs8
@pytest.mark.slow
@pytest.mark.parametrize("scenario,rounds", [
    ("dirichlet_stragglers", R), ("zipf_async", R),
    # int8 rounding tie-flips amplify through the eta min-branch over
    # long blocks (same bound as the sharded compression parity test)
    ("bandwidth_tiered", 2)])
def test_block_sharded_scenario_parity(scenario, rounds, rng):
    loss, params, batches = _problem(rng)
    batches = jax.tree.map(lambda x: x[:rounds], batches)
    scn = get_scenario(scenario)
    rep, blk, _, _, sopt = _block_loops(loss, params, scenario=scn,
                                        num_clients=64)
    s0 = flatten_fl_state(init_fl_state(params, sopt, scn), rep.layout)
    fr, mr = jax.jit(rep)(s0, batches)
    fb, mb = jax.jit(blk)(s0, batches)
    assert float(jnp.max(jnp.abs(fr.P - fb.P))) <= 1e-5
    np.testing.assert_array_equal(np.asarray(mr["cohort_ids"]),
                                  np.asarray(mb["cohort_ids"]))
    for k in mr:
        if k != "cohort_ids":
            np.testing.assert_allclose(np.asarray(mr[k]),
                                       np.asarray(mb[k]), atol=1e-3)
