import os
import sys

# Smoke tests and benches run on the CPU backend. Only launch/dryrun.py
# installs the 512 placeholder devices (its own first line).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# 8 virtual CPU devices so the sharded flat-engine tests exercise a real
# (data, model) mesh (tests/test_flat.py `needs8` cases); CI pins the
# same flag. A user-provided XLA_FLAGS wins — the sharded tests then
# skip if fewer than 8 devices come up.
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Property tests degrade to deterministic seeded sampling so the suite
    # collects and passes without the optional dependency.
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import numpy as np
import pytest


def _order_seed():
    raw = os.environ.get("PYTEST_ORDER_SEED", "")
    if not raw:
        return None
    try:
        return int(raw)
    except ValueError:
        import zlib
        return zlib.crc32(raw.encode())


def pytest_report_header(config):
    seed = _order_seed()
    if seed is not None:
        return f"randomized test order: PYTEST_ORDER_SEED={seed}"
    return None


def pytest_collection_modifyitems(config, items):
    # Deflake audit: PYTEST_ORDER_SEED=<n> deterministically shuffles
    # the execution order — modules are permuted and items permuted
    # within each module (grouping preserved so module-scoped fixtures
    # set up once). Any pass/fail difference vs the default order is an
    # inter-test dependency, i.e. a flake. CI's conformance job runs
    # the fast tier this way with the run id as seed.
    seed = _order_seed()
    if seed is None:
        return
    shuffle_rng = np.random.default_rng(np.uint64(seed))
    groups = {}
    for item in items:
        groups.setdefault(str(item.fspath), []).append(item)
    keys = list(groups)
    key_order = [keys[i] for i in shuffle_rng.permutation(len(keys))]
    items[:] = [
        groups[k][i]
        for k in key_order
        for i in shuffle_rng.permutation(len(groups[k]))
    ]


@pytest.fixture
def rng():
    return np.random.default_rng(0)
