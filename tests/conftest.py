import os
import sys

# Smoke tests and benches run on the CPU backend. Only launch/dryrun.py
# installs the 512 placeholder devices (its own first line).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# 8 virtual CPU devices so the sharded flat-engine tests exercise a real
# (data, model) mesh (tests/test_flat.py `needs8` cases); CI pins the
# same flag. A user-provided XLA_FLAGS wins — the sharded tests then
# skip if fewer than 8 devices come up.
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Property tests degrade to deterministic seeded sampling so the suite
    # collects and passes without the optional dependency.
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
