import os

# Smoke tests and benches run on the real single CPU device. Only
# launch/dryrun.py installs the 512 placeholder devices (its own first line).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
