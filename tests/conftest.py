import os
import sys

# Smoke tests and benches run on the real single CPU device. Only
# launch/dryrun.py installs the 512 placeholder devices (its own first line).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Property tests degrade to deterministic seeded sampling so the suite
    # collects and passes without the optional dependency.
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
