"""Per-kernel allclose sweeps against the pure-jnp ref oracles
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.delta_sgd import delta_sgd as dk
from repro.kernels.delta_sgd import ref as dref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_scan.ops import ssd_scan
from repro.kernels.mamba2_scan.ref import ssd_ref


# ---------------------------------------------------------------- delta_sgd
@pytest.mark.parametrize("shape", [(7,), (128,), (1000,), (257, 33),
                                   (8, 16, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_sgd_norms_sweep(shape, dtype, rng):
    g = jnp.asarray(rng.normal(size=shape), dtype)
    gp = jnp.asarray(rng.normal(size=shape), dtype)
    dg, gg = dk.norms(g, gp, interpret=True)
    dg_r, gg_r = dref.norms_ref(g, gp)
    np.testing.assert_allclose(dg, dg_r, rtol=3e-3)
    np.testing.assert_allclose(gg, gg_r, rtol=3e-3)


@pytest.mark.parametrize("shape", [(5,), (1024,), (130, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_sgd_apply_sweep(shape, dtype, rng):
    p = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape), dtype)
    out = dk.apply_update(p, g, 0.37, interpret=True)
    ref = dref.apply_ref(p, g, 0.37)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 600), seed=st.integers(0, 2**31 - 1))
def test_delta_sgd_norms_property(n, seed):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(size=n), jnp.float32)
    gp = jnp.asarray(r.normal(size=n), jnp.float32)
    dg, gg = dk.norms(g, gp, interpret=True)
    np.testing.assert_allclose(dg, float(jnp.sum((g - gp) ** 2)), rtol=1e-4)
    np.testing.assert_allclose(gg, float(jnp.sum(g ** 2)), rtol=1e-4)


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,S,H,KV,hd,causal,window", [
    (2, 256, 8, 4, 64, True, None),
    (1, 128, 4, 1, 64, True, None),       # MQA
    (2, 300, 4, 4, 32, True, None),       # non-multiple padding
    (1, 512, 8, 2, 128, True, 128),       # sliding window
    (2, 256, 4, 4, 64, False, None),      # bidirectional
    (1, 64, 2, 2, 16, True, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ----------------------------------------------------------------- mamba2
@pytest.mark.parametrize("B,S,H,P,G,N", [
    (2, 128, 4, 32, 1, 16),
    (1, 64, 8, 64, 2, 64),
    (2, 192, 4, 64, 1, 64),
    (1, 256, 2, 16, 1, 8),
])
def test_mamba2_ssd_sweep(B, S, H, P, G, N, rng):
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A_log = jnp.asarray(np.log(rng.uniform(1, 16, (H,))), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    y, h = ssd_scan(x, dt, A_log, Bm, Cm)
    yr, hr = ssd_ref(x, dt, A_log, Bm, Cm)
    np.testing.assert_allclose(y, yr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-3, atol=1e-4)


def test_mamba2_kernel_inside_model(rng):
    """use_pallas path of the mamba2 block == jnp path."""
    from repro.configs import get_config
    from repro.models.ssm import init_mamba2, mamba2_full
    cfg = get_config("zamba2-7b").reduced()
    p = init_mamba2(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    y0, _ = mamba2_full(p, x, cfg, use_pallas=False)
    y1, _ = mamba2_full(p, x, cfg, use_pallas=True)
    np.testing.assert_allclose(y0, y1, rtol=2e-3, atol=2e-3)


def test_flash_kernel_inside_model(rng):
    from repro.configs import get_config
    from repro.models.attention import init_attention, gqa_full
    cfg = get_config("tinyllama-1.1b").reduced()
    p = init_attention(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 128, cfg.d_model)), jnp.float32)
    pos = jnp.arange(128)[None]
    y0, _ = gqa_full(p, x, cfg, positions=pos, use_pallas=False)
    y1, _ = gqa_full(p, x, cfg, positions=pos, use_pallas=True)
    np.testing.assert_allclose(y0, y1, rtol=2e-3, atol=2e-3)
