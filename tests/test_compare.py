"""benchmarks/compare.py guard semantics: missing baseline rows are
advisory (satellite: new baseline rows must not brick older result
files), ``level: soft`` entries never hard-fail, one malformed csv row
or baseline entry degrades to an advisory instead of killing the guard,
and the run emits a machine-readable hard/soft/advisory summary."""
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.compare import check, read_results  # noqa: E402


def test_missing_row_is_advisory_not_violation():
    hard, soft, advisories, report = check(
        {}, {"new/row": {"us_per_call": 10.0}})
    assert hard == [] and soft == []
    assert len(advisories) == 1 and "missing" in advisories[0]
    assert report == []


def test_missing_normalize_by_row_is_advisory():
    hard, soft, advisories, _ = check(
        {"a": (10.0, 0.0)},
        {"a": {"normalize_by": "gone", "ratio": 1.0}})
    assert hard == [] and soft == []
    assert any("normalize_by" in a for a in advisories)


def test_soft_level_breach_routes_to_soft_bucket():
    results = {"a": (30.0, 0.5), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25, "max_err": 0.1,
                      "level": "soft"}}
    hard, soft, advisories, report = check(results, baseline)
    assert hard == [] and advisories == []
    # both the regression (ratio 3 > 1.25) and max_err breach are soft
    assert len(soft) == 2
    assert any("soft" in line for line in report)


def test_hard_violations_still_fire():
    results = {"a": (30.0, 0.5), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25, "max_err": 0.1}}
    hard, soft, advisories, _ = check(results, baseline)
    assert len(hard) == 2 and soft == [] and advisories == []


def test_within_limit_passes_and_reports():
    results = {"a": (11.0, 0.0), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25}}
    hard, soft, advisories, report = check(results, baseline)
    assert hard == [] and soft == [] and advisories == []
    assert len(report) == 1 and "ratio vs base" in report[0]


def test_broken_baseline_entry_is_advisory_per_row():
    # entry missing both normalize_by and us_per_call raises KeyError
    # inside the per-entry check — it must degrade to an advisory and
    # the healthy sibling entry must still be checked
    results = {"a": (10.0, 0.0), "b": (10.0, 0.0)}
    baseline = {"a": {}, "b": {"us_per_call": 10.0}}
    hard, soft, advisories, report = check(results, baseline)
    assert hard == [] and soft == []
    assert len(advisories) == 1 and "errored" in advisories[0]
    assert len(report) == 1 and report[0].startswith("b:")


def test_read_results_skips_malformed_rows(tmp_path):
    p = tmp_path / "bench_results.csv"
    p.write_text("name,us_per_call,derived\n"
                 "good,10.0,0.5\n"
                 "bad,not_a_number,0.5\n"
                 "too,many,fields,here\n")
    rows, bad = read_results(str(p))
    assert rows == {"good": (10.0, 0.5)}
    assert len(bad) == 2


def _run_compare(tmp_path, csv_text, baseline, mode="hard"):
    csv = tmp_path / "bench_results.csv"
    csv.write_text(csv_text)
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(baseline))
    out = tmp_path / "summary.json"
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.compare", str(csv), str(base),
         "--mode", mode, "--summary-out", str(out)],
        capture_output=True, text=True, env=env)
    return proc, (json.loads(out.read_text()) if out.exists() else None)


def test_cli_summary_and_exit_codes(tmp_path):
    csv = ("name,us_per_call,derived\n"
           "a,30.0,0.0\nbase,10.0,0.0\nsoft_row,30.0,0.0\n")
    baseline = {
        "a": {"normalize_by": "base", "ratio": 1.0,
              "max_regression": 1.25},
        "soft_row": {"normalize_by": "base", "ratio": 1.0,
                     "max_regression": 1.25, "level": "soft"},
        "gone": {"us_per_call": 5.0},
    }
    proc, summary = _run_compare(tmp_path, csv, baseline, mode="hard")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "bench guard summary:" in proc.stdout
    assert summary == {"mode": "hard", "rows_checked": 2, "hard": 1,
                       "soft": 1, "advisory": 1, "ok": False}
    # soft mode: same breaches, exit 0
    proc, summary = _run_compare(tmp_path, csv, baseline, mode="soft")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert summary["ok"] is True and summary["hard"] == 1


def test_cli_soft_only_breaches_exit_zero_in_hard_mode(tmp_path):
    csv = "name,us_per_call,derived\nsoft_row,30.0,0.0\nbase,10.0,0.0\n"
    baseline = {"soft_row": {"normalize_by": "base", "ratio": 1.0,
                             "max_regression": 1.25, "level": "soft"}}
    proc, summary = _run_compare(tmp_path, csv, baseline, mode="hard")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert summary["soft"] == 1 and summary["hard"] == 0
    assert summary["ok"] is True
