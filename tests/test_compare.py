"""benchmarks/compare.py guard semantics: missing baseline rows are
advisory (satellite: new baseline rows must not brick older result
files), and ``level: soft`` entries never hard-fail."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.compare import check  # noqa: E402


def test_missing_row_is_advisory_not_violation():
    violations, advisories, report = check(
        {}, {"new/row": {"us_per_call": 10.0}})
    assert violations == []
    assert len(advisories) == 1 and "missing" in advisories[0]
    assert report == []


def test_missing_normalize_by_row_is_advisory():
    violations, advisories, _ = check(
        {"a": (10.0, 0.0)},
        {"a": {"normalize_by": "gone", "ratio": 1.0}})
    assert violations == []
    assert any("normalize_by" in a for a in advisories)


def test_soft_level_breach_is_advisory():
    results = {"a": (30.0, 0.5), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25, "max_err": 0.1,
                      "level": "soft"}}
    violations, advisories, report = check(results, baseline)
    assert violations == []
    # both the regression (ratio 3 > 1.25) and max_err breach are soft
    assert len(advisories) == 2
    assert any("soft" in line for line in report)


def test_hard_violations_still_fire():
    results = {"a": (30.0, 0.5), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25, "max_err": 0.1}}
    violations, advisories, _ = check(results, baseline)
    assert len(violations) == 2 and advisories == []


def test_within_limit_passes_and_reports():
    results = {"a": (11.0, 0.0), "base": (10.0, 0.0)}
    baseline = {"a": {"normalize_by": "base", "ratio": 1.0,
                      "max_regression": 1.25}}
    violations, advisories, report = check(results, baseline)
    assert violations == [] and advisories == []
    assert len(report) == 1 and "ratio vs base" in report[0]
