"""Federation scenario engine: schedulers, heterogeneous-K lane masking
(parity against a reference that literally runs K_c steps per client),
async buffered aggregation, and the sync-degenerate equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.core import flat as fp
from repro.core.delta_sgd import (delta_sgd_init, delta_sgd_update,
                                  flat_delta_sgd_init, flat_delta_sgd_step)
from repro.federation import (SCENARIOS, Scenario, buffer_init,
                              buffer_merge, buffer_step, cohort_size,
                              get_scenario, make_scheduler,
                              staleness_weights)
from repro.kernels.delta_sgd import delta_sgd as dk

GAMMA, DELTA, ETA0, THETA0 = 2.0, 0.1, 0.2, 1.0
D = 5


def _quad(params, batch):
    r = batch["A"] @ params["x"] - batch["b"]
    return 0.5 * jnp.mean(r * r), {}


def _mk_batches(rng, C, K, n=8):
    return {"A": jnp.asarray(rng.normal(size=(C, K, n, D)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(C, K, n)), jnp.float32)}


# ------------------------------------------------------------- schedulers
@pytest.mark.parametrize("kind", ["uniform", "size_weighted", "zipf",
                                  "cyclic"])
def test_scheduler_shape_determinism_uniqueness(kind):
    m, C = 40, 10
    sizes = np.arange(1, m + 1, dtype=np.float32) * 10
    sch = make_scheduler(kind, num_clients=m, cohort=C, sizes=sizes)
    key = jax.random.key(0)
    ids1 = np.asarray(sch.sample(key, 3))
    ids2 = np.asarray(sch.sample(key, 3))
    assert ids1.shape == (C,) and ids1.dtype == np.int32
    np.testing.assert_array_equal(ids1, ids2)          # deterministic
    assert len(set(ids1.tolist())) == C                # w/o replacement
    assert ids1.min() >= 0 and ids1.max() < m
    ids3 = np.asarray(sch.sample(key, 4))
    assert not np.array_equal(np.sort(ids1), np.sort(ids3))


def test_zipf_scheduler_prefers_low_ranks():
    m, C = 50, 5
    sch = make_scheduler("zipf", num_clients=m, cohort=C, zipf_s=1.5)
    key = jax.random.key(1)
    h = np.zeros(m)
    for t in range(200):
        np.add.at(h, np.asarray(sch.sample(key, t)), 1)
    assert h[:10].sum() > h[10:].sum()     # head dominates the tail


def test_size_weighted_scheduler_prefers_big_clients():
    m, C = 30, 4
    sizes = np.ones(m, np.float32)
    sizes[:5] = 100.0
    sch = make_scheduler("size_weighted", num_clients=m, cohort=C,
                         sizes=sizes)
    key = jax.random.key(2)
    h = np.zeros(m)
    for t in range(100):
        np.add.at(h, np.asarray(sch.sample(key, t)), 1)
    assert h[:5].sum() > h[5:].sum()


def test_cyclic_scheduler_respects_window():
    m, C = 40, 4
    sch = make_scheduler("cyclic", num_clients=m, cohort=C,
                         window_frac=0.25)
    key = jax.random.key(3)
    win, stride = sch.window, sch.stride
    for t in (0, 1, 7):
        ids = np.asarray(sch.sample(key, t))
        start = (t * stride) % m
        assert np.all(((ids - start) % m) < win), (t, ids)
    # rotation: the reachable set changes across rounds
    all_ids = {int(i) for t in range(20)
               for i in np.asarray(sch.sample(key, t))}
    assert len(all_ids) > win


def test_cohort_size_shared_helper():
    """Satellite: FLConfig.clients_per_round and the pipeline draw use
    the SAME rounding (the seed repo truncated in one and rounded in the
    other — p=0.15, m=10 disagreed)."""
    from repro.configs.base import FLConfig
    from repro.data.pipeline import FederatedDataset
    from repro.data.synthetic import get_task
    assert cohort_size(0.15, 10) == 2          # round, not truncate
    fl = FLConfig(num_clients=10, participation=0.15)
    assert fl.clients_per_round == 2
    fed = FederatedDataset.build(get_task("easy"), num_clients=10,
                                 alpha=1.0, seed=0)
    batches, w, ids = fed.sample_round(0.15, 2, 4)
    assert batches["x"].shape[0] == fl.clients_per_round == len(ids)


def test_pipeline_cohort_matches_scenario_scheduler():
    """The ids the host pipeline gathers data for == the scenario's
    in-round scheduler draw (same key discipline)."""
    from repro.data.pipeline import FederatedDataset
    from repro.data.synthetic import get_task
    scn = get_scenario("zipf_async")
    fed = FederatedDataset.build(get_task("easy"), num_clients=30,
                                 alpha=1.0, seed=0, scenario=scn)
    _, _, ids = fed.sample_round(0.2, 2, 4, round_idx=7)
    sch = scn.make_scheduler(30, cohort_size(0.2, 30),
                             sizes=fed.client_sizes())
    expect = np.asarray(sch.sample(jax.random.key(scn.seed), 7))
    np.testing.assert_array_equal(ids, expect)


# -------------------------------------------------- speed models / masks
def test_speed_model_draws_in_range():
    from repro.federation import SpeedModel
    for kind in ("fixed", "uniform", "stragglers"):
        sm = SpeedModel(kind)
        ks = np.asarray(sm.draw(jax.random.key(0), 64, 8))
        assert ks.shape == (64,) and ks.min() >= 1 and ks.max() <= 8
    assert np.all(np.asarray(
        SpeedModel("fixed").draw(jax.random.key(0), 4, 6)) == 6)
    slow = np.asarray(SpeedModel("stragglers", straggler_frac=1.0)
                      .draw(jax.random.key(0), 16, 8))
    assert np.all(slow == 2)               # k_min = round(0.25·8)


def test_scenario_registry_and_overrides():
    assert {"sync_iid", "dirichlet_stragglers", "zipf_async"} \
        <= set(SCENARIOS)
    scn = get_scenario("zipf_async", buffer_size=16)
    assert scn.buffer_size == 16 and scn.is_async
    assert get_scenario(scn) is scn
    with pytest.raises(KeyError):
        get_scenario("nope")
    with pytest.raises(KeyError):
        Scenario("bad", aggregation="maybe")


# -------------------------------------- hetero-K parity (flat vs literal)
def _literal_reference(tree, grad_seq, step_counts):
    """Runs EXACTLY K_c oracle steps per client — no masking anywhere."""
    finals, etas = [], []
    for c, k_c in enumerate(step_counts):
        p = tree
        s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
        for k in range(int(k_c)):
            p, s = delta_sgd_update(p, grad_seq[c][k], s, gamma=GAMMA,
                                    delta=DELTA, eta0=ETA0)
        finals.append(p)
        etas.append(float(s.eta))
    return finals, etas


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_flat_step_hetero_matches_literal_kc_reference(backend, rng):
    """Acceptance: the masked flat engine == a reference that literally
    runs K_c steps per client (≤1e-5), mixed bf16/f32 tree included."""
    C, K = 4, 5
    step_counts = np.array([1, 3, 5, 2], np.int64)
    tree = {"emb": jnp.asarray(rng.normal(size=(33, 7)), jnp.bfloat16),
            "w": jnp.asarray(rng.normal(size=(129,)), jnp.float32)}
    layout = fp.layout_of(tree)
    mask = fp.round_mask(layout)
    grad_seq = [[jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), p.dtype), tree)
        for _ in range(K)] for _ in range(C)]
    ref_params, ref_etas = _literal_reference(tree, grad_seq, step_counts)

    P = jnp.stack([fp.pack(tree, layout)] * C)
    S = flat_delta_sgd_init(C, layout, eta0=ETA0, theta0=THETA0)
    sc = jnp.asarray(step_counts, jnp.int32)
    for k in range(K):
        G = jnp.stack([fp.pack(grad_seq[c][k], layout) for c in range(C)])
        P, S = flat_delta_sgd_step(
            P, G, S, gamma=GAMMA, delta=DELTA, eta0=ETA0, mask=mask,
            active=(k < sc), backend=backend,
            interpret=True if backend == "pallas" else None)
    got = fp.unpack_batched(P, layout)
    for c in range(C):
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(got[key][c], np.float32),
                np.asarray(ref_params[c][key], np.float32),
                rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(S.eta[c]), ref_etas[c], rtol=1e-5)


@pytest.mark.parametrize("flat", [False, "xla", "pallas"])
def test_hetero_round_matches_literal_reference(flat, rng):
    """Round-level acceptance: make_fl_round under a straggler scenario
    == mean of per-client literal K_c-step oracles."""
    C, K = 4, 4
    scn = get_scenario("dirichlet_stragglers", straggler_frac=0.5, seed=3)
    step_counts = np.asarray(scn.draw_step_counts(0, C, K))
    # mixed draw: at least one masked lane AND one full-K lane, so the
    # parity test really exercises frozen clients next to running ones
    assert step_counts.min() < K and step_counts.max() == K, step_counts
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)

    # literal reference: grads recomputed exactly as the engine does
    tree = {"x": x0}
    grad_fn = jax.value_and_grad(
        lambda p, b: make_loss(_quad)(p, b, None, None), has_aux=True)
    finals = []
    for c in range(C):
        p = tree
        s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
        for k in range(int(step_counts[c])):
            b = {"A": batches["A"][c, k], "b": batches["b"][c, k]}
            (_, _), g = grad_fn(p, b)
            p, s = delta_sgd_update(p, g, s, gamma=GAMMA, delta=DELTA,
                                    eta0=ETA0)
        finals.append(np.asarray(p["x"], np.float64))
    ref = np.mean(finals, axis=0)

    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(make_loss(_quad), copt, sopt,
                                num_rounds=10, flat=flat, scenario=scn))
    st = init_fl_state(tree, sopt, scn)
    st, m, loc = rnd(st, batches)
    np.testing.assert_allclose(np.asarray(st.params["x"]), ref,
                               rtol=1e-5, atol=1e-5)
    for c in range(C):
        np.testing.assert_allclose(np.asarray(loc["x"][c]), finals[c],
                                   rtol=1e-5, atol=1e-5)
    assert float(m["k_eff_mean"]) == pytest.approx(step_counts.mean())


def test_sync_scenario_reproduces_seed_engines(rng):
    """Acceptance: a sync full-participation scenario reproduces the
    existing engines bit-for-bit (sync_iid takes the identical code
    path; a stragglers scenario with frac=0 exercises the masked path
    with an all-ones mask, ≤1e-5)."""
    C, K = 3, 4
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(_quad)
    for flat in (False, "xla", "pallas"):
        base = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                     flat=flat))
        st0 = init_fl_state({"x": x0}, sopt)
        st0, m0, _ = base(st0, batches)
        # identical code path: exact equality
        scn = get_scenario("sync_iid")
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat=flat, scenario=scn))
        st1 = init_fl_state({"x": x0}, sopt, scn)
        st1, m1, _ = rnd(st1, batches)
        np.testing.assert_array_equal(np.asarray(st1.params["x"]),
                                      np.asarray(st0.params["x"]))
        # masked path with every client at K_max: ≤1e-5
        scn0 = get_scenario("dirichlet_stragglers", straggler_frac=0.0)
        rnd0 = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                     flat=flat, scenario=scn0))
        st2 = init_fl_state({"x": x0}, sopt, scn0)
        st2, m2, _ = rnd0(st2, batches)
        np.testing.assert_allclose(np.asarray(st2.params["x"]),
                                   np.asarray(st0.params["x"]),
                                   rtol=1e-5, atol=1e-6)
        assert float(m2["loss"]) == pytest.approx(float(m0["loss"]),
                                                  rel=1e-6)


def test_hetero_flat_round_two_launches_per_local_step(rng):
    """Fused-launch invariant (acceptance): heterogeneous-K rounds still
    trace exactly 2 pallas launches per local step — the lane mask rides
    the per-client η vector, not an extra kernel."""
    scn = get_scenario("dirichlet_stragglers")
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(_quad)
    for C, K in ((2, 3), (5, 2)):
        batches = _mk_batches(rng, C, K)
        rnd = make_fl_round(loss, copt, sopt, num_rounds=10,
                            flat="pallas", scenario=scn)
        st = init_fl_state({"x": jnp.zeros((D,), jnp.float32)}, sopt, scn)
        dk.reset_launch_count()
        jax.eval_shape(lambda s, b: rnd(s, b), st, batches)
        assert dk.launch_count() == 2, (C, K, dict(dk.LAUNCHES))


# ----------------------------------------------------------- async buffer
def test_staleness_weights_polynomial():
    w = np.asarray(staleness_weights(jnp.asarray([0, 1, 3]), 0.5))
    np.testing.assert_allclose(w, [1.0, 2 ** -0.5, 0.5], rtol=1e-6)


def test_buffer_merge_and_flush_counting():
    params = {"x": jnp.ones((4,), jnp.float32)}
    sopt = get_server_opt("fedavg")
    buf = buffer_init(params)
    stale = jnp.asarray([0, 0], jnp.int32)
    delta = {"x": jnp.full((4,), 2.0, jnp.float32)}  # pre-weighted sum
    buf = buffer_merge(buf, delta, jnp.float32(2.0), 2, stale)
    assert int(buf.count) == 2
    # below M: hold — params unchanged, buffer kept
    p, s, buf2, flushed = buffer_step(params, {}, buf, sopt, 4)
    assert float(flushed) == 0.0 and int(buf2.count) == 2
    np.testing.assert_array_equal(np.asarray(p["x"]),
                                  np.asarray(params["x"]))
    # reach M: flush applies params + delta/weight and resets
    buf3 = buffer_merge(buf2, delta, jnp.float32(2.0), 2, stale)
    p, s, buf4, flushed = buffer_step(params, {}, buf3, sopt, 4)
    assert float(flushed) == 1.0 and int(buf4.count) == 0
    np.testing.assert_allclose(np.asarray(p["x"]), 1.0 + 4.0 / 4.0)
    assert float(buf4.weight) == 0.0


def test_async_round_requires_flat_engine():
    scn = get_scenario("zipf_async")
    with pytest.raises(ValueError, match="flat engine"):
        make_fl_round(make_loss(_quad), get_client_opt("delta_sgd"),
                      get_server_opt("fedavg"), num_rounds=1,
                      scenario=scn)


def test_async_degenerate_equals_sync_fedavg(rng):
    """staleness ≡ 0 + M = C → flush every round with unit weights: the
    async path reproduces synchronous FedAvg."""
    C, K = 4, 3
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(_quad)
    sync = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                 flat="xla"))
    scn = get_scenario("zipf_async", staleness_max=0, buffer_size=C,
                       speed="fixed")
    asy = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                flat="xla", scenario=scn))
    st_s = init_fl_state({"x": x0}, sopt)
    st_a = init_fl_state({"x": x0}, sopt, scn)
    for _ in range(3):
        st_s, _, _ = sync(st_s, batches)
        st_a, ma, _ = asy(st_a, batches)
        assert float(ma["flushed"]) == 1.0
    np.testing.assert_allclose(np.asarray(st_a.params["x"]),
                               np.asarray(st_s.params["x"]),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("server", ["fedavg", "fedadam"])
def test_async_round_buffers_and_flushes(server, rng):
    """M > C: the server holds for ⌈M/C⌉ rounds, then steps — with any
    ServerOpt — and the staleness metrics are populated."""
    C, K = 3, 2
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt(server)
    scn = get_scenario("zipf_async", buffer_size=6)
    rnd = jax.jit(make_fl_round(make_loss(_quad), copt, sopt,
                                num_rounds=10, flat="xla", scenario=scn,
                                num_clients=12))
    st = init_fl_state({"x": x0}, sopt, scn)
    flushes = []
    for _ in range(4):
        st, m, _ = rnd(st, batches)
        flushes.append(float(m["flushed"]))
        assert 0.0 <= float(m["stale_mean"]) <= scn.staleness_max
        assert m["cohort_ids"].shape == (C,)
    assert flushes == [0.0, 1.0, 0.0, 1.0]
    # held rounds leave params untouched only for fedavg-like flushes;
    # in all cases the state stays finite
    assert np.all(np.isfinite(np.asarray(st.params["x"])))


def test_async_held_round_keeps_params(rng):
    C, K = 2, 2
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    sopt = get_server_opt("fedavg")
    scn = get_scenario("zipf_async", buffer_size=8)
    rnd = jax.jit(make_fl_round(make_loss(_quad),
                                get_client_opt("delta_sgd"), sopt,
                                num_rounds=10, flat="xla", scenario=scn))
    st = init_fl_state({"x": x0}, sopt, scn)
    st, m, _ = rnd(st, batches)
    assert float(m["flushed"]) == 0.0
    np.testing.assert_array_equal(np.asarray(st.params["x"]),
                                  np.asarray(x0))
    assert float(m["buffer_fill"]) == C


# ------------------------------------------------------------- sharded
needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")


def _fl_problem(rng, C=8, K=3, Dm=300, E=40):
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, Dm)),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=Dm), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    return quad, params, batches


@needs8
@pytest.mark.parametrize("scn_name", ["dirichlet_stragglers",
                                      "zipf_async"])
@pytest.mark.slow
def test_sharded_scenario_round_matches_replicated(scn_name, rng):
    """Acceptance: scenario rounds on the sharded flat engine == the
    replicated flat engine (≤1e-5) AND the packed (C, N) buffer never
    rematerializes in the compiled HLO (assert_flat_buffer_sharded)."""
    from repro.sharding.hlo import assert_flat_buffer_sharded
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    scn = get_scenario(scn_name)
    quad, params, batches = _fl_problem(rng)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    out = {}
    for name, kw in (("repl", {}),
                     ("shard", dict(mesh=mesh, federation=spec))):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat="xla", scenario=scn,
                                    num_clients=20, **kw))
        st = init_fl_state(params, sopt, scn)
        if name == "shard":
            lay = fp.layout_of(params, shards=spec.flat_shards(mesh))
            compiled = rnd.lower(st, batches).compile()
            assert_flat_buffer_sharded(compiled, 8, lay.padded_size)
        for _ in range(3):
            st, m, _ = rnd(st, batches)
        out[name] = (np.asarray(st.params["x"]),
                     np.asarray(st.params["e"], np.float32),
                     np.asarray(m["cohort_ids"]),
                     float(m["eta_mean"]), float(m["loss"]))
    for a, b in zip(out["repl"], out["shard"]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
