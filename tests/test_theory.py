"""Theory checks: the convex Lyapunov decrease (paper Eq. 5 / Thm 5) and
convergence of the distributed scheme on least squares."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

GAMMA, DELTA = 1.0, 1.0   # Thm 5 setting uses the undamped rule (γ=1, δ=1)


def _make_problem(m=4, d=6, seed=0, interpolation=True):
    """m clients, each f_i(x) = 0.5‖A_i x − b_i‖²; convex, different local
    smoothness per client. Thm 5's monotone Lyapunov needs a COMMON
    minimizer (x* minimises every f_i — the paper's 'x* is any minimum of
    f_i for all i' condition), so by default b_i = A_i x*."""
    rng = np.random.default_rng(seed)
    x_star = rng.normal(size=d).astype(np.float32)
    As, bs = [], []
    for i in range(m):
        scale = 0.5 + 2.0 * i           # heterogeneous L_i
        Ai = scale * rng.normal(size=(12, d)).astype(np.float32)
        As.append(Ai)
        if interpolation:
            bs.append(Ai @ x_star)
        else:
            bs.append(rng.normal(size=(12,)).astype(np.float32))
    if not interpolation:
        A = np.concatenate(As)
        b = np.concatenate(bs)
        x_star = np.linalg.lstsq(A, b, rcond=None)[0].astype(np.float32)
    return As, bs, x_star


def _fi(Ai, bi, x):
    r = Ai @ x - bi
    return 0.5 * float(r @ r)


def _gi(Ai, bi, x):
    return Ai.T @ (Ai @ x - bi)


def test_lyapunov_decrease_convex():
    """Run Alg. 1 with K=1, p=1, full batch (the Thm 5 setting) and check
    the Lyapunov function of Eq. (5) is non-increasing after the first
    couple of iterations (the bound needs one step of warm-up for θ)."""
    m, d = 4, 6
    As, bs, x_star = _make_problem(m, d)
    x = np.zeros(d, np.float32)
    xs_prev = [x.copy() for _ in range(m)]       # x_{t-1}^i
    etas = [0.05] * m
    thetas = [0.0] * m
    gs_prev = [_gi(As[i], bs[i], x) for i in range(m)]

    def lyapunov(x, xs_i, xs_prev_i, etas, thetas):
        v = float(np.sum((x - x_star) ** 2))
        v += sum(np.sum((xs_i[i] - xs_prev_i[i]) ** 2)
                 for i in range(m)) / (2 * m)
        v += 2 / m * sum(etas[i] * thetas[i]
                         * (_fi(As[i], bs[i], xs_prev_i[i])
                            - _fi(As[i], bs[i], x_star))
                         for i in range(m))
        return v

    vals = []
    xs_i = [x.copy() for _ in range(m)]
    for t in range(40):
        new_xs, new_etas, new_thetas = [], [], []
        for i in range(m):
            g = _gi(As[i], bs[i], xs_i[i])
            dg = np.linalg.norm(g - gs_prev[i])
            dx = np.linalg.norm(xs_i[i] - xs_prev[i])
            cand1 = GAMMA * dx / (2 * dg) if dg > 0 else np.inf
            cand2 = np.sqrt(1 + DELTA * thetas[i]) * etas[i]
            eta = min(cand1, cand2)
            new_xs.append(xs_i[i] - eta * g)
            new_thetas.append(eta / etas[i])
            new_etas.append(eta)
            gs_prev[i] = g
        xs_prev = [a.copy() for a in xs_i]
        xs_i = new_xs
        x = np.mean(xs_i, axis=0)
        etas, thetas = new_etas, new_thetas
        vals.append(lyapunov(x, xs_i, xs_prev, etas, thetas))
    vals = np.asarray(vals[2:])
    diffs = np.diff(vals)
    # Eq. (5): non-increasing, up to fp noise near the fixed point
    assert np.all(diffs <= 1e-3 + 1e-2 * vals[:-1]), (vals, diffs)
    assert vals[-1] < 1e-2 * vals[0]  # and it actually converges


def test_fl_round_converges_on_least_squares():
    """Full pipeline (make_fl_round + delta_sgd) drives the global least
    squares objective near optimum without any tuning."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    m, d = 4, 6
    As, bs, x_star = _make_problem(m, d)
    A = jnp.asarray(np.stack(As))       # (m, n, d)
    B = jnp.asarray(np.stack(bs))

    def base_loss(params, batch):
        # mean (not sum): η0 = 0.2 must not blow up the first local step
        # (paper §3: "η0 should be sufficiently small")
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    loss_fn = make_loss(base_loss)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(loss_fn, copt, sopt, num_rounds=100))
    state = init_fl_state({"x": jnp.zeros((d,), jnp.float32)}, sopt)
    K = 3
    batches = {"A": jnp.broadcast_to(A[:, None], (m, K) + A.shape[1:]),
               "b": jnp.broadcast_to(B[:, None], (m, K) + B.shape[1:])}
    for _ in range(100):
        state, metrics, _ = rnd(state, batches)
    err = float(jnp.linalg.norm(state.params["x"] - jnp.asarray(x_star)))
    assert err < 0.15, err


def test_rate_beats_lmax_baseline():
    """Thm/preliminaries claim: per-client adaptive steps beat the crude
    1/L_max global step when smoothness is heterogeneous."""
    m, d = 4, 6
    As, bs, x_star = _make_problem(m, d)
    Ls = [np.linalg.norm(Ai.T @ Ai, 2) for Ai in As]
    eta_crude = 1.0 / max(Ls)

    def run(adaptive, T=60):
        xs = [np.zeros(d, np.float32) for _ in range(m)]
        etas, thetas = [1e-3] * m, [1.0] * m
        xp = [x.copy() for x in xs]
        gp = [_gi(As[i], bs[i], xs[i]) for i in range(m)]
        for t in range(T):
            nxt = []
            for i in range(m):
                g = _gi(As[i], bs[i], xs[i])
                if adaptive:
                    dg = np.linalg.norm(g - gp[i])
                    dx = np.linalg.norm(xs[i] - xp[i])
                    cand1 = dx / (2 * dg) if dg > 0 else np.inf
                    eta = min(cand1, np.sqrt(1 + thetas[i]) * etas[i])
                    thetas[i], etas[i] = eta / etas[i], eta
                else:
                    eta = eta_crude
                xp[i], gp[i] = xs[i].copy(), g
                nxt.append(xs[i] - eta * g)
            mean = np.mean(nxt, axis=0)
            xs = [mean.copy() for _ in range(m)]   # aggregate each round
        f = sum(_fi(As[i], bs[i], mean) for i in range(m)) / m
        fstar = sum(_fi(As[i], bs[i], x_star) for i in range(m)) / m
        return f - fstar

    assert run(True) < run(False), (run(True), run(False))
