"""Serving plane (repro.serving): fused-scan decode parity, continuous
batching exactness, checkpoint hot-swap atomicity + KV reuse,
personalized decode, load-generator metrics, train->serve round trip,
and warm fleet-arena resume.

The parity tests all reduce to the same contract: the engine is an
OPTIMIZATION of one-request-at-a-time greedy decode, never a different
decoder. Greedy argmax over f32 logits is deterministic, so every
comparison here is exact token equality, not a tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save
from repro.configs import get_config
from repro.models import build_model
from repro.serving import (DecodeEngine, ModelRegistry,
                           PersonalizationStore, Workload, greedy_decode,
                           make_requests, run_load)

ARCH = "tinyllama-1.1b"


def _setup(arch=ARCH, seed=0):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, jnp.float32)
    return cfg, model, model.init(jax.random.key(seed))


def _prefill(model, params, prompt, cache_len):
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])})
    return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache


def _isolated_decode(model, params, prompt, gen, cache_len):
    """Reference: the request decoded alone, fused lockstep."""
    tok0, cache = _prefill(model, params, prompt, cache_len)
    toks, _, _ = greedy_decode(model, params, cache, tok0, gen - 1)
    return np.concatenate([np.asarray(tok0)[0], np.asarray(toks)[0]])


# ---------------------------------------------------------------- fused
def test_fused_decode_token_exact_vs_host_loop():
    """Satellite 1: the lax.scan decode emits token-identical output to
    the legacy per-token host loop it replaced."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    B, S, G = 2, 16, 8
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    logits, cache = jax.jit(lambda p, b: model.prefill(
        p, b, cache_len=S + G))(params, batch)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    c, t, out = cache, tok, [tok]
    for _ in range(G - 1):
        lg, c = step(params, c, t)
        t = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(t)
    host = np.asarray(jnp.concatenate(out, 1))

    toks, _, _ = greedy_decode(model, params, cache, tok, G - 1)
    fused = np.concatenate([np.asarray(tok), np.asarray(toks)], axis=1)
    np.testing.assert_array_equal(host, fused)


# ------------------------------------------------- continuous batching
def test_continuous_batching_token_exact_vs_isolated():
    """A request admitted into a busy pool — different prompt lengths,
    staggered admission, slots freed and reused — decodes exactly the
    tokens it gets alone (per-slot positions/ring slots really are
    independent)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    eng = DecodeEngine(model, params, slots=3, cache_len=64,
                       flush_tokens=4)
    prompts = [rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (16, 9, 23, 5)]
    gens = [8, 11, 5, 9]
    rids = [eng.submit(prompts[0], gens[0]),
            eng.submit(prompts[1], gens[1])]
    done = eng.step()                       # staggered: 2 running...
    rids.append(eng.submit(prompts[2], gens[2]))   # ...then a 3rd
    done += eng.step()
    rids.append(eng.submit(prompts[3], gens[3]))   # reuses a freed slot
    done += eng.run_until_idle()
    got = {c.request_id: c.tokens for c in done}
    assert sorted(got) == sorted(rids)
    for rid, p, g in zip(rids, prompts, gens):
        np.testing.assert_array_equal(
            got[rid], _isolated_decode(model, params, p, g, 64),
            err_msg=f"request {rid} diverged in the shared pool")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-1.3b",
                                  "deepseek-v3-671b"])
def test_continuous_batching_other_archs(arch):
    """Same exactness through SSM (mamba2/mlstm/slstm) state pools and
    the MLA latent cache. (deepseek's MoE routing is batch-global —
    exact here at reduced scale because capacity is not contended.)"""
    cfg, model, params = _setup(arch)
    rng = np.random.default_rng(2)
    eng = DecodeEngine(model, params, slots=2, cache_len=48,
                       flush_tokens=4)
    prompts = [rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (12, 7)]
    gens = [6, 9]
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    got = {c.request_id: c.tokens for c in eng.run_until_idle()}
    for rid, p, g in zip(rids, prompts, gens):
        np.testing.assert_array_equal(
            got[rid], _isolated_decode(model, params, p, g, 48))


def test_submit_rejects_oversized_request():
    cfg, model, params = _setup()
    eng = DecodeEngine(model, params, slots=2, cache_len=16)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(np.zeros(12, np.int32), 8)   # 12 + 8 > 16, no window


# ------------------------------------------------------------ hot swap
def test_hot_swap_atomic_per_flush(tmp_path):
    """A checkpoint published mid-request swaps in at exactly ONE flush
    boundary: the request's token stream is prefix-exact under the old
    params and suffix-exact under the new params WITH THE OLD KV CACHE
    (shape-compatible swap reuses the pool), and the engine records one
    swap with a positive stall."""
    cfg, model, params = _setup(seed=0)
    params2 = model.init(jax.random.key(1))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    G, F = 9, 4                      # 1 prefill token + 2 flushes of 4

    save(str(tmp_path), params, step=1)
    reg = ModelRegistry(str(tmp_path), params)
    eng = DecodeEngine(model, params, slots=2, cache_len=32,
                       flush_tokens=F, registry=reg)
    assert eng.version == 1          # initial version staged at build
    eng.submit(prompt, G)
    done = eng.step()                # flush 0: tokens 2..5 under v1
    assert not done
    save(str(tmp_path), params2, step=2)
    done = eng.run_until_idle()      # flush 1 swaps, tokens 6..9 v2
    assert len(done) == 1

    m = eng.metrics()
    assert m["serve_swaps_total"] == 1
    assert m["serve_swap_stall_max"] > 0.0
    assert m["kv_reuse_swaps"] == 1          # slot was live at swap
    assert [h["version"] for h in eng.history] == [1, 2]
    assert done[0].versions == (1, 2)

    # replay: v1 prefill + v1 flush, then v2 continues on the SAME cache
    tok0, cache = _prefill(model, params, prompt, 32)
    t1, cache, last = greedy_decode(model, params, cache, tok0, F)
    t2, _, _ = greedy_decode(model, params2, cache, last, F)
    ref = np.concatenate([np.asarray(tok0)[0], np.asarray(t1)[0],
                          np.asarray(t2)[0]])
    np.testing.assert_array_equal(done[0].tokens, ref)


def test_swap_shape_gate():
    """Same-shape params swap in; a different architecture is refused
    (the KV pool cannot be reused across an architecture change)."""
    cfg, model, params = _setup(seed=0)
    eng = DecodeEngine(model, params, slots=2, cache_len=32)
    eng.swap(model.init(jax.random.key(1)), 5)
    assert eng.version == 5
    other = build_model(get_config(ARCH).reduced(num_layers=1,
                                                 d_model=128),
                        jnp.float32).init(jax.random.key(0))
    with pytest.raises(ValueError, match="hot-swap refused"):
        eng.swap(other, 6)
    assert eng.version == 5          # refused swap left version alone


# ----------------------------------------------------- personalization
def test_personalized_decode_parity():
    """Engine decode under a registered client's delta == decoding
    under the manually overlaid params, and != the global decode when
    the delta is non-trivial (acceptance: personalized differs from
    global exactly by the client's arena delta)."""
    from repro.core.flat import pack, unpack
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    store = PersonalizationStore(params, scale=1.0)
    delta = jnp.asarray(rng.normal(scale=5e-2,
                                   size=(store.layout.padded_size,)),
                        jnp.float32)
    store.set_delta(7, delta)
    params_c = unpack(pack(params, store.layout) + delta, store.layout)

    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    eng = DecodeEngine(model, params, slots=3, cache_len=32,
                       flush_tokens=4, personalization=store)
    r_pers = eng.submit(prompt, 8, client_id=7)
    r_glob = eng.submit(prompt, 8)
    r_unkn = eng.submit(prompt, 8, client_id=99)  # no delta -> global
    got = {c.request_id: c.tokens for c in eng.run_until_idle()}

    ref_pers = _isolated_decode(model, params_c, prompt, 8, 32)
    ref_glob = _isolated_decode(model, params, prompt, 8, 32)
    np.testing.assert_array_equal(got[r_pers], ref_pers)
    np.testing.assert_array_equal(got[r_glob], ref_glob)
    np.testing.assert_array_equal(got[r_unkn], ref_glob)
    assert not np.array_equal(got[r_pers], got[r_glob])


def test_personalization_store_from_arena():
    """from_arena lifts the fleet arena's EF21 slab into per-client
    deltas (row i -> client i) and rejects arenas without one or with
    a mismatched layout width."""
    from repro.federation import arena_init
    _, model, params = _setup()
    store0 = PersonalizationStore(params)
    N = store0.layout.padded_size
    arena = arena_init(4, eta0=0.1, ef_width=N)
    ef = np.zeros((4, N), np.float32)
    ef[2, :5] = 1.5
    arena = arena._replace(ef=jnp.asarray(ef))
    store = PersonalizationStore.from_arena(arena, params)
    assert store.client_ids() == [0, 1, 2, 3]
    np.testing.assert_array_equal(
        np.asarray(store._deltas[2]), ef[2])
    with pytest.raises(ValueError, match="no EF21 slab"):
        PersonalizationStore.from_arena(arena_init(4, eta0=0.1), params)
    bad = arena._replace(ef=jnp.zeros((4, N + 128)))
    with pytest.raises(ValueError, match="EF width"):
        PersonalizationStore.from_arena(bad, params)


# ------------------------------------------------------ load generator
def test_loadgen_metrics_sane():
    cfg, model, params = _setup()
    eng = DecodeEngine(model, params, slots=3, cache_len=32,
                       flush_tokens=4)
    wl = Workload(num_requests=6, arrival="closed", concurrency=3,
                  prompt_lens=(8, 12), gen_lens=(4, 6), seed=0)
    rep = run_load(eng, wl, cfg.vocab_size)
    assert rep["requests"] == 6
    assert rep["tok_per_s"] > 0
    assert rep["p99_s"] >= rep["p50_s"] > 0
    assert 0 < rep["occupancy"] <= 1
    assert rep["swaps"] == 0


def test_loadgen_request_stream_deterministic():
    wl = Workload(num_requests=10, arrival="poisson", rate=50.0,
                  prompt_lens=(8, 16), gen_lens=(4, 8),
                  personalized_frac=0.5, client_ids=(0, 1), seed=3)
    a, b = make_requests(wl, 512), make_requests(wl, 512)
    assert len(a) == 10
    for (pa, ga, ca, ta), (pb, gb, cb, tb) in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
        assert (ga, ca, ta) == (gb, cb, tb)
    arrivals = [t for *_, t in a]
    assert arrivals == sorted(arrivals)


def test_engine_emits_flush_events(tmp_path):
    """Per-flush JSONL telemetry: one serve_flush row per flush with
    the schema-registered fields, flushed at the flush boundary."""
    from repro.telemetry import EventLog, load_events
    from repro.telemetry.schema import REGISTRY
    for name in ("serve_tokens", "serve_occupancy", "serve_version",
                 "serve_swapped", "serve_swap_stall_s",
                 "serve_tok_per_s", "serve_latency_p50_s",
                 "serve_latency_p99_s"):
        assert name in REGISTRY, f"{name} missing from telemetry schema"
    cfg, model, params = _setup()
    path = str(tmp_path / "events.jsonl")
    events = EventLog(path, config={"mode": "serve"})
    eng = DecodeEngine(model, params, slots=2, cache_len=32,
                       flush_tokens=4, events=events)
    eng.submit(np.zeros(8, np.int32), 6)
    eng.run_until_idle()
    events.close()
    _, evs = load_events(path)
    rows = [e for e in evs if e["kind"] == "serve_flush"]
    assert len(rows) == eng.stats["flushes"] > 0
    assert rows[0]["serve_tokens"] > 0
    assert rows[0]["serve_version"] == 0


# ------------------------------------------- train -> serve round trip
@pytest.mark.slow
def test_train_serve_round_trip(tmp_path):
    """Two fused training blocks checkpoint rounds 2 and 4; the
    registry serves the LATEST round, and a newer checkpoint published
    mid-serve triggers exactly one hot swap."""
    from repro.launch.train import build_parser, train_lm
    ckpt = str(tmp_path / "ckpt")
    args = build_parser().parse_args(
        ["--arch", ARCH, "--reduced", "--layers", "2",
         "--d-model", "256", "--rounds", "4", "--rounds-per-call", "2",
         "--clients-per-round", "2", "--local-steps", "1",
         "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
         "--ckpt-every", "2"])
    final = train_lm(args)

    cfg, model, params = _setup()
    reg = ModelRegistry(ckpt, params)
    eng = DecodeEngine(model, params, slots=2, cache_len=32,
                       flush_tokens=4, registry=reg)
    assert eng.version == 4          # latest round staged at build
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    rid = eng.submit(prompt, 9)
    eng.step()
    # a newer round lands mid-request -> exactly one swap next flush
    save(ckpt, final.params, step=6)
    done = {c.request_id: c for c in eng.run_until_idle()}
    assert eng.metrics()["serve_swaps_total"] == 1
    assert done[rid].versions == (4, 6)
    # and the trained params really drive decode: fresh-init differs
    fresh = _isolated_decode(model, params, prompt, 9, 32)
    assert not np.array_equal(done[rid].tokens, fresh)


# -------------------------------------------------- warm fleet resume
def _fleet_args(ckpt, rounds, resume=False):
    from repro.launch.train import build_parser
    argv = ["--task", "easy", "--rounds", str(rounds),
            "--rounds-per-call", "2", "--clients-per-round", "4",
            "--num-clients", "8", "--num-registered", "32",
            "--participation", "0.25", "--eta-carry",
            "--local-steps", "1", "--batch", "16", "--ckpt-dir", ckpt,
            "--ckpt-every", "2", "--seed", "0"]
    if resume:
        argv.append("--resume")
    return build_parser().parse_args(argv)


@pytest.mark.slow
def test_fleet_arena_resume_bit_exact(tmp_path):
    """Satellite 2 acceptance: a fleet run (--num-registered, η carry)
    interrupted at round 2 and --resume'd matches the uninterrupted
    run bit for bit — params AND the restored client arena (η carry,
    participation counters). Requires (a) the arena riding the
    checkpoint under <ckpt_dir>/arena and (b) the data pipeline's
    within-client draws being (seed, round)-keyed, not stream-stateful."""
    from repro.checkpoint import restore
    from repro.federation import arena_init
    from repro.launch.train import train_paper_task
    ref, cut = str(tmp_path / "ref"), str(tmp_path / "cut")
    straight = train_paper_task(_fleet_args(ref, 4))
    train_paper_task(_fleet_args(cut, 2))
    resumed = train_paper_task(_fleet_args(cut, 2, resume=True))
    assert int(straight.round) == int(resumed.round) == 4
    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    like = arena_init(32, eta0=0.05)
    ar, _ = restore(os.path.join(ref, "arena"), like=like, step=4)
    ac, _ = restore(os.path.join(cut, "arena"), like=like, step=4)
    for a, b in zip(jax.tree_util.tree_leaves(ar),
                    jax.tree_util.tree_leaves(ac)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(ac.rounds_seen).sum()) > 0   # warm, not cold