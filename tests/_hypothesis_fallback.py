"""Minimal stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests with a tiny slice of the hypothesis API:
``@settings(max_examples=N, deadline=None)``, ``@given(...)`` and the
``floats`` / ``integers`` / ``lists`` strategies. This module implements
exactly that slice with deterministic seeded sampling so the suite
collects and runs without the extra dependency; when the real package is
available, ``conftest.py`` never installs this fallback.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__version__ = "0.0-fallback"
DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def floats(min_value, max_value, *, allow_nan=False, allow_infinity=False):
    del allow_nan, allow_infinity  # bounded draws are always finite
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        # hit the boundaries occasionally, like hypothesis does
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.1:
            return hi
        return float(rng.uniform(lo, hi))

    return _Strategy(draw)


def integers(min_value, max_value):
    lo, hi = int(min_value), int(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.1:
            return hi
        return int(rng.integers(lo, hi + 1))

    return _Strategy(draw)


def lists(elements, *, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return _Strategy(draw)


class strategies:
    floats = staticmethod(floats)
    integers = staticmethod(integers)
    lists = staticmethod(lists)


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        sig = inspect.signature(fn)
        # hypothesis binds positional strategies to the RIGHTMOST
        # parameters (leading ones may be pytest fixtures)
        names = [p for p in sig.parameters if p not in kw_strategies]
        names = names[len(names) - len(arg_strategies):] \
            if arg_strategies else []
        pos = dict(zip(names, arg_strategies))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            # crc32, not hash(): str hashing is salted per process and
            # would make failures unreproducible across runs
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in pos.items()}
                drawn.update({k: s.example(rng)
                              for k, s in kw_strategies.items()})
                fn(*args, **drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution
        drawn_names = set(pos) | set(kw_strategies)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values()
                        if p.name not in drawn_names])
        del wrapper.__wrapped__
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


def settings(*, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    del deadline

    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return decorate
