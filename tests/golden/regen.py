"""Regenerate the golden-trajectory fixtures (tests/test_golden.py).

    PYTHONPATH=src python tests/golden/regen.py

Run this ONLY when a numeric change to the round engines is intended —
the fixture diff is the review artifact that makes the change visible.
Fixtures record the jax version they were generated under; the test
asserts bit-exact on the same version and <= 1e-6 across versions.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    import jax
    from _golden_common import CASES, fixture_path, run_case
    for name in CASES:
        trace = run_case(name)
        trace["jax"] = jax.__version__
        path = fixture_path(name)
        with open(path, "w") as f:
            json.dump(trace, f, indent=2)
            f.write("\n")
        print(f"wrote {path}: loss[0]={trace['loss'][0]:.6f} "
              f"loss[-1]={trace['loss'][-1]:.6f}")


if __name__ == "__main__":
    main()
