"""Integration tests for the jitted federated round vs a hand-written
python reference of Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)

D = 5


def _quad_loss(params, batch):
    r = batch["A"] @ params["x"] - batch["b"]
    return 0.5 * jnp.mean(r * r), {}


def _mk_batches(rng, C, K, n=8):
    return {"A": jnp.asarray(rng.normal(size=(C, K, n, D)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(C, K, n)), jnp.float32)}


def _reference_round(x0, batches, *, gamma=2.0, delta=0.1, eta0=0.2,
                     theta0=1.0):
    """Plain-python Algorithm 1 (FedAvg + Δ-SGD), one round."""
    C, K = batches["A"].shape[:2]
    finals = []
    for i in range(C):
        x = np.asarray(x0, np.float64).copy()
        eta, theta = eta0, theta0
        g_prev, gn_prev = None, None
        for k in range(K):
            A = np.asarray(batches["A"][i, k], np.float64)
            b = np.asarray(batches["b"][i, k], np.float64)
            g = A.T @ (A @ x - b) / A.shape[0]
            if k == 0:
                eta_k = eta0
            else:
                dg = np.linalg.norm(g - g_prev)
                dx = eta * gn_prev
                cand1 = gamma * dx / (2 * dg) if dg > 0 else np.inf
                cand2 = np.sqrt(1 + delta * theta) * eta
                eta_k = min(cand1, cand2)
                theta = eta_k / eta
            x = x - eta_k * g
            g_prev, gn_prev, eta = g, np.linalg.norm(g), eta_k
        finals.append(x)
    return np.mean(finals, axis=0)


def test_round_matches_reference(rng):
    C, K = 3, 4
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)

    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(make_loss(_quad_loss), copt, sopt,
                                num_rounds=10))
    state = init_fl_state({"x": x0}, sopt)
    state, metrics, locals_ = rnd(state, batches)
    ref = _reference_round(x0, batches)
    np.testing.assert_allclose(np.asarray(state.params["x"]), ref,
                               rtol=2e-4, atol=2e-5)
    assert locals_["x"].shape == (C, D)
    assert np.isfinite(float(metrics["loss"]))


def test_weighted_aggregation(rng):
    C, K = 3, 2
    batches = _mk_batches(rng, C, K)
    x0 = jnp.zeros((D,), jnp.float32)
    copt = get_client_opt("sgd", lr=0.05)
    sopt = get_server_opt("fedavg")
    rnd_w = jax.jit(make_fl_round(make_loss(_quad_loss), copt, sopt,
                                  num_rounds=10, weighted=True))
    state = init_fl_state({"x": x0}, sopt)
    w = jnp.asarray([1.0, 0.0, 0.0])
    state_w, _, locals_ = rnd_w(state, batches, client_weights=w)
    # weight (1,0,0) -> global == client 0's local result
    np.testing.assert_allclose(np.asarray(state_w.params["x"]),
                               np.asarray(locals_["x"][0]), rtol=1e-5)


def test_fedprox_changes_trajectory(rng):
    C, K = 2, 3
    batches = _mk_batches(rng, C, K)
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    copt = get_client_opt("sgd", lr=0.1)
    sopt = get_server_opt("fedavg")
    out = {}
    for mu in (0.0, 10.0):
        rnd = jax.jit(make_fl_round(make_loss(_quad_loss, fedprox_mu=mu),
                                    copt, sopt, num_rounds=10))
        state = init_fl_state({"x": x0}, sopt)
        state, _, _ = rnd(state, batches)
        out[mu] = np.asarray(state.params["x"])
    # strong prox keeps locals near the global start
    assert np.linalg.norm(out[10.0] - np.asarray(x0)) \
        < np.linalg.norm(out[0.0] - np.asarray(x0))


@pytest.mark.parametrize("server", ["fedavg", "fedavgm", "fedadam",
                                    "fedyogi"])
def test_server_optimizers_run(rng, server):
    C, K = 2, 2
    batches = _mk_batches(rng, C, K)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt(server)
    rnd = jax.jit(make_fl_round(make_loss(_quad_loss), copt, sopt,
                                num_rounds=10))
    state = init_fl_state({"x": jnp.zeros((D,), jnp.float32)}, sopt)
    for _ in range(3):
        state, metrics, _ = rnd(state, batches)
    assert np.all(np.isfinite(np.asarray(state.params["x"])))


@pytest.mark.parametrize("copt_name", ["sgd", "sgd_decay", "sgdm",
                                       "sgdm_decay", "adam", "adagrad",
                                       "sps", "delta_sgd"])
def test_all_client_opts_reduce_loss(rng, copt_name):
    C, K = 4, 6
    batches = _mk_batches(rng, C, K, n=16)
    copt = get_client_opt(copt_name, lr=0.05)
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(make_loss(_quad_loss), copt, sopt,
                                num_rounds=30))
    state = init_fl_state({"x": jnp.zeros((D,), jnp.float32) + 2.0}, sopt)
    first = None
    for t in range(30):
        state, metrics, _ = rnd(state, batches)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first
