"""repro.launch.report — formatting helpers, cohort/scenario report
aggregation (registry-driven via repro.telemetry.schema), the η-hist
ASCII renderer, and the markdown tables, all on synthetic artifacts
(no training runs)."""
import json

import numpy as np

from repro.launch.report import (cohort_histogram, dryrun_table,
                                 eta_hist_render, fmt_b, fmt_t, load,
                                 roofline_table, scenario_summary,
                                 scenario_table)


def test_fmt_t_units():
    assert fmt_t(0) == "0"
    assert fmt_t(5e-6) == "5µs"
    assert fmt_t(0.0123) == "12.3ms"
    assert fmt_t(2.5) == "2.50s"


def test_fmt_b_units():
    assert fmt_b(512) == "512B"
    assert fmt_b(2_000) == "2.0KB"
    assert fmt_b(3_500_000) == "3.5MB"
    assert fmt_b(7e9) == "7.0GB"
    assert fmt_b(1.2e12) == "1.2TB"


def test_cohort_histogram_counts_repeats():
    h = cohort_histogram([[0, 1], [1, 3], [1, 1]], num_clients=5)
    assert h.tolist() == [1, 4, 0, 1, 0]


def test_scenario_summary_participation_and_metrics():
    ids = [[0, 1], [0, 2], [0, 1]]
    mets = [{"k_eff_mean": 1.0, "loss": 0.5},
            {"k_eff_mean": 3.0, "loss": 0.4}]
    out = scenario_summary("sync_iid", ids, num_clients=4,
                           metrics_per_round=mets)
    assert out["scenario"] == "sync_iid" and out["rounds"] == 2
    assert out["clients_seen"] == 3
    assert out["cohort_top1_share"] == 0.5          # client 0: 3 of 6
    assert out["cohort_histogram"] == [3, 2, 1, 0]
    # registry-driven: k_eff_mean declares a mean summary in the schema
    assert out["k_eff_mean"] == 2.0


def test_scenario_summary_vector_metric_and_edges():
    hist = [1.0] * 16
    out = scenario_summary(
        "zipf_async", [], num_clients=2,
        metrics_per_round=[{"eta_hist": hist}, {"eta_hist": hist}])
    assert out["eta_hist"] == [2.0] * 16             # summed elementwise
    assert len(out["eta_hist_edges"]) == 17          # B bins -> B+1 edges
    # fleet regime: raw per-client histogram suppressed above 10k
    big = scenario_summary("fleet", [[0]], num_clients=20_000,
                           metrics_per_round=[])
    assert "cohort_histogram" not in big
    assert big["clients_seen"] == 1


def test_eta_hist_render():
    edges = [0.0, 1e-3, 1e-2, 1e-1, float("inf")]
    text = eta_hist_render([2, 8, 4, 1], edges, width=8)
    lines = text.splitlines()
    assert "15 client-rounds" in lines[0]
    assert len(lines) == 5
    assert lines[1].startswith("  <") and lines[-1].lstrip().startswith(">")
    assert lines[2].count("#") == 8                  # peak bin fills width
    assert eta_hist_render([0, 0], edges) == "(empty η histogram)"


def test_scenario_table_degrades_over_missing_keys():
    rows = [{"scenario": "sync_iid", "rounds": 2, "clients_seen": 3,
             "cohort_top1_share": 0.5, "cohort_top5_share": 1.0,
             "stale_mean": 0.25, "stale_max": 2.0, "flush_rate": 0.75},
            {"scenario": "bare"}]                    # everything missing
    t = scenario_table(rows)
    assert "sync_iid" in t and "0.50/1.00" in t and "0.25/2" in t
    assert "| bare |" in t and " - " in t
    assert scenario_table([{}]) == "(no scenario artifacts)"


def test_dryrun_and_roofline_tables():
    rows = [{"arch": "tinyllama-1.1b", "shape": "b1s128", "mesh": "16x16",
             "federation": "dp", "clients": 8, "compile_s": 3.2,
             "memory": {"temp_size_in_bytes": 2_000_000},
             "analytic_memory": {"total": 4e9},
             "useful_flops_ratio": 0.61,
             "roofline": {"t_compute_s": 1e-3, "t_memory_s": 2e-3,
                          "t_collective_s": 5e-4, "bottleneck": "memory",
                          "coll_by_kind": {"all-reduce": 1e6}}},
            {"arch": "partial"}]                     # degraded artifact
    d = dryrun_table(rows)
    assert "tinyllama-1.1b" in d and "2.0MB" in d and "4.0GB" in d
    assert "| partial |" in d
    r = roofline_table(rows)
    assert "**memory**" in r and "all-reduce (1.0MB)" in r
    assert "0.61" in r
    # non-16x16 and note-only artifacts are filtered out
    assert "partial" not in r


def test_load_reads_sorted_json(tmp_path):
    (tmp_path / "b.json").write_text(json.dumps({"n": 2}))
    (tmp_path / "a.json").write_text(json.dumps({"n": 1}))
    assert [r["n"] for r in load(str(tmp_path))] == [1, 2]
