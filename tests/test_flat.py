"""Flat-parameter Δ-SGD engine: packer round-trips, batched kernel
parity, and full multi-round equivalence against the per-leaf pytree
oracle (core.delta_sgd.delta_sgd_update) in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flat as fp
from repro.core.delta_sgd import (delta_sgd_init, delta_sgd_reset,
                                  delta_sgd_update, flat_delta_sgd_init,
                                  flat_delta_sgd_step)
from repro.kernels.delta_sgd import delta_sgd as dk
from repro.kernels.delta_sgd import ref as dref

GAMMA, DELTA, ETA0, THETA0 = 2.0, 0.1, 0.2, 1.0


def _mixed_tree(rng, scale=1.0):
    """bf16 params / f32 params mixed in one tree (odd, non-lane shapes)."""
    return {"emb": jnp.asarray(rng.normal(size=(33, 7)) * scale,
                               jnp.bfloat16),
            "w": jnp.asarray(rng.normal(size=(129,)) * scale, jnp.float32),
            "b": jnp.asarray(rng.normal(size=(5, 3, 2)) * scale,
                             jnp.float32)}


# ------------------------------------------------------------------ packer
def test_pack_unpack_roundtrip_mixed_dtypes(rng):
    tree = _mixed_tree(rng)
    layout = fp.layout_of(tree)
    buf = fp.pack(tree, layout)
    assert buf.shape == (layout.padded_size,)
    assert layout.padded_size % fp.LANES == 0
    # tail padding is zero (exact global reductions over the buffer)
    assert float(jnp.sum(jnp.abs(buf[layout.size:]))) == 0.0
    back = fp.unpack(buf, layout)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))


def test_pack_unpack_batched_roundtrip(rng):
    C = 4
    tree = {"a": jnp.asarray(rng.normal(size=(C, 17, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(C, 40)), jnp.bfloat16)}
    layout = fp.layout_of(tree, batched=True)
    buf = fp.pack_batched(tree, layout)
    assert buf.shape == (C, layout.padded_size)
    back = fp.unpack_batched(buf, layout)
    for k in tree:
        assert back[k].shape == tree[k].shape
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))


def test_layout_cached_per_treedef(rng):
    t1 = _mixed_tree(rng)
    t2 = _mixed_tree(rng, scale=3.0)
    assert fp.layout_of(t1) is fp.layout_of(t2)


def test_round_mask_marks_bf16_segments(rng):
    tree = _mixed_tree(rng)
    layout = fp.layout_of(tree)
    mask = fp.round_mask(layout)
    assert mask is not None
    n_bf16 = sum(s.size for s in layout.leaves
                 if s.dtype == jnp.dtype(jnp.bfloat16))
    assert float(jnp.sum(mask)) == n_bf16
    f32_tree = {"x": jnp.zeros((7,), jnp.float32)}
    assert fp.round_mask(fp.layout_of(f32_tree)) is None


# ---------------------------------------------------------- batched kernels
@pytest.mark.parametrize("C,n_leaves", [(1, 1), (3, 5), (8, 2)])
def test_batched_norms_matches_ref(C, n_leaves, rng):
    tree = {f"w{i}": jnp.asarray(rng.normal(size=(C, 50 + 13 * i)),
                                 jnp.float32) for i in range(n_leaves)}
    layout = fp.layout_of(tree, batched=True)
    g = fp.pack_batched(tree, layout)
    gp = g * -0.3 + 0.1
    dg, gg = dk.batched_norms(g, gp, interpret=True)
    dg_r, gg_r = dref.batched_norms_ref(g, gp)
    np.testing.assert_allclose(dg, dg_r, rtol=1e-5)
    np.testing.assert_allclose(gg, gg_r, rtol=1e-5)


def test_batched_apply_per_client_eta_and_mask(rng):
    C = 3
    tree = {"a": jnp.asarray(rng.normal(size=(C, 200)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(size=(C, 77)), jnp.float32)}
    layout = fp.layout_of(tree, batched=True)
    p = fp.pack_batched(tree, layout)
    g = p * 0.2 + 0.05
    eta = jnp.asarray([0.1, 0.5, 1.3], jnp.float32)
    mask = fp.round_mask(layout)
    out = dk.batched_apply(p, g, eta, mask=mask, interpret=True)
    ref = dref.batched_apply_ref(p, g, eta, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # masked segments are exactly bf16-representable
    seg = fp.unpack_batched(out, layout)["a"]
    np.testing.assert_array_equal(
        np.asarray(out[:, :200].astype(jnp.bfloat16), np.float32),
        np.asarray(seg, np.float32))


# -------------------------------------------------- full-round parity oracle
def test_flat_step_matches_oracle_multi_round_mixed_dtype(rng):
    """Satellite acceptance: fused flat path == delta_sgd_update oracle
    (interpret mode) over TWO full K=3 rounds — covers the k=0 reset
    branch — on a mixed bf16/f32 tree, tolerance ≤ 1e-5."""
    C, K, R = 3, 3, 2
    tree = _mixed_tree(rng)
    layout = fp.layout_of(tree)
    mask = fp.round_mask(layout)
    N = layout.padded_size

    # per-step per-client synthetic grads in the leaf dtypes
    grad_seq = [[jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), p.dtype), tree)
        for _ in range(K)] for _ in range(C)]

    # oracle: per-client pytree loop with round-start resets
    ref_params, ref_etas = [], []
    for c in range(C):
        p = tree
        s = delta_sgd_init(p, eta0=ETA0, theta0=THETA0)
        for r in range(R):
            s = delta_sgd_reset(s, eta0=ETA0, theta0=THETA0)
            for k in range(K):
                p, s = delta_sgd_update(p, grad_seq[c][k], s, gamma=GAMMA,
                                        delta=DELTA, eta0=ETA0)
        ref_params.append(p)
        ref_etas.append(float(s.eta))

    # flat engine: one (C, N) buffer, two launches per step
    P = jnp.stack([fp.pack(tree, layout)] * C)
    for r in range(R):
        S = flat_delta_sgd_init(C, layout, eta0=ETA0, theta0=THETA0)
        for k in range(K):
            G = jnp.stack([fp.pack(grad_seq[c][k], layout)
                           for c in range(C)])
            P, S = flat_delta_sgd_step(P, G, S, gamma=GAMMA, delta=DELTA,
                                       eta0=ETA0, mask=mask,
                                       backend="pallas", interpret=True)

    got = fp.unpack_batched(P, layout)
    for c in range(C):
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(got[key][c], np.float32),
                np.asarray(ref_params[c][key], np.float32),
                rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(S.eta[c]), ref_etas[c], rtol=1e-5)


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_flat_round_engine_matches_vmap_engine(backend, rng):
    """make_fl_round(flat=...) == the vmapped per-client engine."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    D, C, K = 5, 3, 4

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    x0 = jnp.asarray(rng.normal(size=D), jnp.float32)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    results = {}
    for eng in (False, backend):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat=eng))
        st = init_fl_state({"x": x0}, sopt)
        for _ in range(2):
            st, m, loc = rnd(st, batches)
        results[eng] = (np.asarray(st.params["x"]), float(m["eta_mean"]),
                        float(m["loss"]), np.asarray(loc["x"]))
    for a, b in zip(results[False], results[backend]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_flat_round_weighted_matches_vmap(rng):
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    D, C, K = 4, 3, 2

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    w = jnp.asarray([0.7, 0.2, 0.1], jnp.float32)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    out = {}
    for eng in (False, "xla"):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    weighted=True, flat=eng))
        st = init_fl_state({"x": jnp.zeros((D,), jnp.float32)}, sopt)
        st, _, _ = rnd(st, batches, client_weights=w)
        out[eng] = np.asarray(st.params["x"])
    np.testing.assert_allclose(out["xla"], out[False], rtol=1e-5)


def test_flat_round_two_launches_per_local_step(rng):
    """Launch-count acceptance: the scan body is traced once, so tracing
    one flat round builds exactly 2 pallas calls — i.e. every local step
    executes 2 launches — independent of leaf count, client count, and
    K."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)

    def quad(params, batch):
        r = batch["A"] @ params["x"] + batch["A"] @ params["y"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    for C, K, D in ((2, 3, 4), (5, 2, 6)):
        batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(C, K, 8)),
                                    jnp.float32)}
        rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="pallas")
        st = init_fl_state({"x": jnp.zeros((D,), jnp.float32),
                            "y": jnp.zeros((D,), jnp.float32)}, sopt)
        dk.reset_launch_count()
        jax.eval_shape(lambda s, b: rnd(s, b), st, batches)
        assert dk.launch_count() == 2, (C, K, dict(dk.LAUNCHES))


def test_flat_engine_rejects_non_delta_sgd():
    from repro.core import get_client_opt, get_server_opt, make_fl_round
    with pytest.raises(ValueError):
        make_fl_round(lambda *a: (0.0, {}), get_client_opt("sgd"),
                      get_server_opt("fedavg"), num_rounds=1, flat=True)


# ------------------------------------------------------------- sharded
# 8 virtual CPU devices come from conftest's XLA_FLAGS default; a
# user-provided XLA_FLAGS may override it, so the mesh tests skip when
# fewer devices are available.
needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")


def _mesh8():
    return jax.make_mesh((4, 2), ("data", "model"))


def _fl_problem(rng, C=8, K=3, D=300, E=40):
    """Quadratic FL problem with a mixed f32/bf16 param tree."""
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    return quad, params, batches


def test_layout_cache_key_includes_shard_count(rng):
    """Bugfix: switching meshes (shard counts) in one process must never
    reuse a stale padded layout."""
    tree = _mixed_tree(rng)
    l1 = fp.layout_of(tree)
    l2 = fp.layout_of(tree, shards=2)
    l8 = fp.layout_of(tree, shards=8)
    assert l1 is not l2 and l2 is not l8
    assert l1.shards == 1 and l2.shards == 2 and l8.shards == 8
    for l in (l2, l8):
        per = l.padded_size // l.shards
        assert l.padded_size % l.shards == 0
        assert per % fp.LANES == 0          # every slab lane-aligned
        m = per // fp.LANES
        rows = min(fp.BLOCK_ROWS, m)
        assert m % rows == 0                # ... and row-block aligned
        assert l.padded_size >= l.size
    # same shard count again -> cache hit, not a new object
    assert fp.layout_of(tree, shards=2) is l2
    # back to the unsharded layout: still the original, not the stale one
    assert fp.layout_of(tree) is l1


@needs8
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.slow
def test_sharded_step_matches_replicated_flat(backend, rng):
    """flat_delta_sgd_step_sharded == flat_delta_sgd_step over a K-step
    run on an 8-device mesh, incl. the bf16 round-mask path."""
    from repro.core.delta_sgd import flat_delta_sgd_step_sharded
    from repro.sharding.spec import cross_device
    mesh = _mesh8()
    spec = cross_device(mesh)
    pspec = spec.flat_spec(mesh)
    C = 8
    tree = _mixed_tree(rng)
    lay_s = fp.layout_of(tree, shards=spec.flat_shards(mesh))
    lay_r = fp.layout_of(tree)
    Ps = jnp.stack([fp.pack(tree, lay_s)] * C)
    Pr = jnp.stack([fp.pack(tree, lay_r)] * C)
    Ss = flat_delta_sgd_init(C, lay_s, eta0=ETA0, theta0=THETA0)
    Sr = flat_delta_sgd_init(C, lay_r, eta0=ETA0, theta0=THETA0)
    kw = dict(gamma=GAMMA, delta=DELTA, eta0=ETA0)
    interp = backend == "pallas" or None
    for _ in range(3):
        gt = jax.tree.map(
            lambda l: jnp.asarray(rng.normal(size=(C,) + l.shape), l.dtype),
            tree)
        Gs = fp.pack_batched(gt, fp.layout_of(gt, batched=True,
                                              shards=lay_s.shards))
        Gr = fp.pack_batched(gt, fp.layout_of(gt, batched=True))
        Ps, Ss = flat_delta_sgd_step_sharded(
            Ps, Gs, Ss, mask=fp.round_mask(lay_s), mesh=mesh, pspec=pspec,
            backend=backend, interpret=interp, **kw)
        Pr, Sr = flat_delta_sgd_step(Pr, Gr, Sr, mask=fp.round_mask(lay_r),
                                     backend=backend, interpret=interp,
                                     **kw)
    got, ref = fp.unpack_batched(Ps, lay_s), fp.unpack_batched(Pr, lay_r)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(Ss.eta), np.asarray(Sr.eta),
                               rtol=1e-5)


@needs8
@pytest.mark.parametrize("fed", ["cross_device", "cross_silo"])
@pytest.mark.slow
def test_sharded_round_matches_replicated_flat(fed, rng):
    """Tentpole acceptance: sharded pack -> K-step scan -> unpack matches
    the replicated flat engine to <= 1e-5 on an 8-device host mesh, for
    both stock federation specs, incl. the bf16 round-mask path."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.sharding.spec import get_federation_spec
    mesh = _mesh8()
    spec = get_federation_spec(fed, mesh)
    quad, params, batches = _fl_problem(rng)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    out = {}
    for name, kw in (("repl", {}),
                     ("shard", dict(mesh=mesh, federation=spec))):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat="xla", **kw))
        st = init_fl_state(params, sopt)
        for _ in range(2):
            st, m, loc = rnd(st, batches)
        out[name] = (np.asarray(st.params["x"]),
                     np.asarray(st.params["e"], dtype=np.float32),
                     np.asarray([m["eta_mean"], m["eta_min"], m["eta_max"],
                                 m["loss"]], dtype=np.float32),
                     np.asarray(loc["x"]))
    for a, b in zip(out["repl"], out["shard"]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@needs8
@pytest.mark.slow
def test_sharded_round_hlo_never_materializes_full_buffer(rng):
    """Acceptance: the compiled sharded round contains NO involuntary
    resharding copies (or any other rematerialization) of the full
    (C, N) buffer — every instruction that touches it is on local
    slabs. The replicated engine (sanity) does materialize it."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.sharding.hlo import assert_flat_buffer_sharded, \
        flat_buffer_report
    from repro.sharding.spec import cross_device
    mesh = _mesh8()
    spec = cross_device(mesh)
    quad, params, batches = _fl_problem(rng)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    C = 8
    st = init_fl_state(params, sopt)

    rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="xla",
                        mesh=mesh, federation=spec)
    lay = fp.layout_of(params, shards=spec.flat_shards(mesh))
    compiled = jax.jit(rnd).lower(st, batches).compile()
    rep = assert_flat_buffer_sharded(compiled, C, lay.padded_size)
    assert rep["gather_or_copy"] == 0

    # sanity: the check has teeth — the replicated engine's HLO is full
    # of (C, N)-shaped instructions
    rnd0 = make_fl_round(loss, copt, sopt, num_rounds=10, flat="xla")
    lay0 = fp.layout_of(params)
    txt0 = jax.jit(rnd0).lower(st, batches).compile().as_text()
    assert flat_buffer_report(txt0, C, lay0.padded_size)["full_shape"] > 0


@needs8
def test_sharded_round_two_launches_per_local_step(rng):
    """The shard_map step keeps the 2-launches-per-local-step property:
    tracing one sharded flat round builds exactly 2 pallas calls."""
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    from repro.sharding.spec import cross_device
    mesh = _mesh8()
    spec = cross_device(mesh)
    quad, params, batches = _fl_problem(rng)
    copt = get_client_opt("delta_sgd")
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="pallas",
                        mesh=mesh, federation=spec)
    st = init_fl_state(params, sopt)
    dk.reset_launch_count()
    jax.eval_shape(lambda s, b: rnd(s, b), st, batches)
    assert dk.launch_count() == 2, dict(dk.LAUNCHES)


def test_eta_metrics_nan_for_non_delta_and_finite_for_delta(rng):
    from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                            make_fl_round, make_loss)
    D, C, K = 4, 2, 2

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    sopt = get_server_opt("fedavg")
    loss = make_loss(quad)
    for opt, finite in (("sgd", False), ("delta_sgd", True)):
        rnd = jax.jit(make_fl_round(loss, get_client_opt(opt, lr=0.05),
                                    sopt, num_rounds=10))
        st = init_fl_state({"x": jnp.zeros((D,), jnp.float32)}, sopt)
        _, m, _ = rnd(st, batches)
        for key in ("eta_mean", "eta_min", "eta_max"):
            assert key in m
            assert np.isfinite(float(m[key])) == finite, (opt, key)
        if finite:
            assert float(m["eta_min"]) <= float(m["eta_mean"]) \
                <= float(m["eta_max"])


# ----------------------------------------------------- property testing
# pack/unpack roundtrip identity across random pytree shapes, bf16/f32
# mixes, and shard counts. Runs under real hypothesis when installed and
# under the vendored deterministic fallback otherwise (conftest).
from hypothesis import given, settings, strategies as st  # noqa: E402


def _prop_tree(sizes, bf16_mask, cdim=None):
    """Deterministic tree from drawn leaf sizes: mixed ranks (0-D/1-D/
    2-D), mixed f32/bf16 per the mask bits, values seeded by the draw."""
    rng = np.random.default_rng(sum(sizes) * 31 + bf16_mask + 7)
    tree = {}
    for i, size in enumerate(sizes):
        if size == 1 and i % 2:
            shape = ()                      # scalar leaf
        elif size > 12 and size % 3 == 0:
            shape = (3, size // 3)
        else:
            shape = (size,)
        if cdim is not None:
            shape = (cdim,) + shape
        dtype = jnp.bfloat16 if (bf16_mask >> i) & 1 else jnp.float32
        tree[f"l{i}"] = jnp.asarray(rng.normal(size=shape) * 3.0, dtype)
    return tree


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(1, 400), min_size=1, max_size=6),
       bf16_mask=st.integers(0, 63), shards=st.integers(1, 4))
@pytest.mark.slow
def test_pack_unpack_roundtrip_property(sizes, bf16_mask, shards):
    tree = _prop_tree(sizes, bf16_mask)
    layout = fp.layout_of(tree, shards=shards)
    # shard alignment: each of the `shards` contiguous slabs is itself
    # lane-aligned, and all padding lives in the zero-filled global tail
    assert layout.padded_size % (shards * fp.LANES) == 0
    assert layout.size == sum(
        int(np.prod(l.shape, dtype=np.int64)) if l.shape else 1
        for l in jax.tree_util.tree_leaves(tree))
    buf = fp.pack(tree, layout)
    assert buf.shape == (layout.padded_size,)
    assert float(jnp.sum(jnp.abs(buf[layout.size:]))) == 0.0
    back = fp.unpack(buf, layout)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        assert back[k].shape == tree[k].shape
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))
    # round_mask marks exactly the sub-f32 lanes
    mask = fp.round_mask(layout)
    n_bf16 = sum(s.size for s in layout.leaves
                 if s.dtype == jnp.dtype(jnp.bfloat16))
    assert (mask is None and n_bf16 == 0) or \
        float(jnp.sum(mask)) == n_bf16


@settings(max_examples=15, deadline=None)
@given(sizes=st.lists(st.integers(1, 300), min_size=1, max_size=5),
       bf16_mask=st.integers(0, 31), shards=st.integers(1, 4),
       cdim=st.integers(1, 5))
@pytest.mark.slow
def test_pack_unpack_batched_roundtrip_property(sizes, bf16_mask, shards,
                                                cdim):
    tree = _prop_tree(sizes, bf16_mask, cdim=cdim)
    layout = fp.layout_of(tree, batched=True, shards=shards)
    buf = fp.pack_batched(tree, layout)
    assert buf.shape == (cdim, layout.padded_size)
    assert float(jnp.sum(jnp.abs(buf[:, layout.size:]))) == 0.0
    back = fp.unpack_batched(buf, layout)
    raw = fp.unpack_batched(buf, layout, cast=False)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        assert raw[k].dtype == jnp.float32      # cast=False keeps f32
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))
    # the (treedef, shapes, dtypes, shards) cache key: same draw hits
    # the cached layout object
    assert fp.layout_of(tree, batched=True, shards=shards) is layout
