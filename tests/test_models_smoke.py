"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture (2 layers — or one pattern cycle — d_model ≤ 512,
≤ 4 experts) runs one forward and one federated train step on CPU with
correct output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, FLConfig, get_config
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.models import build_model

# heavyweight tier: CI runs -m 'not slow' first (scripts/ci.sh)
pytestmark = pytest.mark.slow

B, S = 2, 32


def _batch(cfg, rng, lead=()):
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           lead + (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           lead + (B, S)), jnp.int32),
    }
    if cfg.encoder_layers:
        out["frames"] = jnp.asarray(
            rng.normal(size=lead + (B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.num_image_tokens:
        out["image_embeds"] = jnp.asarray(
            rng.normal(size=lead + (B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and (not cfg.num_experts
                                   or cfg.num_experts <= 4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    logits, aux = jax.jit(model.apply)(params, _batch(cfg, rng))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = model.loss(params, _batch(cfg, rng))
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_federated_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    fl = FLConfig(local_steps=2)
    copt = get_client_opt("delta_sgd", fl)
    sopt = get_server_opt("fedavg")
    loss_fn = make_loss(lambda p, b: model.loss(p, b))
    rnd = jax.jit(make_fl_round(loss_fn, copt, sopt, num_rounds=10))
    params = model.init(jax.random.key(0))
    state = init_fl_state(params, sopt)
    C = 2
    batches = _batch(cfg, rng, lead=(C, fl.local_steps))
    state, metrics, _ = rnd(state, batches)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert not bool(jnp.isnan(leaf).any())
    # params actually moved
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(state.params),
        jax.tree_util.tree_leaves(params)))
    assert moved > 0


def test_param_counts_match_configs():
    """Analytic counts ballpark the advertised sizes (vocab padding and
    simplifications shift them slightly)."""
    expect = {"tinyllama-1.1b": (0.9e9, 1.4e9),
              "qwen2.5-14b": (12e9, 17e9),
              "granite-20b": (18e9, 24e9),
              "deepseek-v3-671b": (600e9, 760e9),
              "olmoe-1b-7b": (5e9, 8.5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.12 * cfg.param_count()
