"""repro.roofline — HLO collective parsing, ring-transfer wire-byte
model, the three roofline terms, and affine-in-depth extrapolation.
These run on synthetic HLO text and hand-built Roofline objects, so
they cover the module without compiling a model."""
import numpy as np
import pytest

from repro.roofline import (CollectiveOp, HBM_BW, ICI_BW, PEAK_FLOPS,
                            Roofline, analyze, extrapolate, model_flops,
                            memory_analysis_summary, parse_collectives)

HLO = """\
ENTRY main {
  %ar = f32[1024,8]{1,0} all-reduce(%p0), replica_groups=[4,8]
  %ag = bf16[256]{0} all-gather(%p1), replica_groups={{0,1,2,3}}
  %rs = f32[64,2]{1,0} reduce-scatter(%p2), replica_groups=[2,16]
  %aa = f32[128]{0} all-to-all(%p3), replica_groups={{0,1}}
  %cp = f32[32]{0} collective-permute(%p4)
  %tup = (f32[16]{0}, bf16[8]{0}) all-reduce-start(%p5), replica_groups=[1,2]
  %mm = f32[4,4]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_kinds_and_groups():
    ops = parse_collectives(HLO)
    assert [o.kind for o in ops] == [
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute", "all-reduce"]
    ar, ag, rs, aa, cp, tup = ops
    assert ar.bytes == 1024 * 8 * 4 and ar.group_size == 8
    assert ag.bytes == 256 * 2 and ag.group_size == 4   # explicit list
    assert rs.group_size == 16
    assert aa.bytes == 128 * 4 and aa.group_size == 2
    assert cp.group_size == 2                            # 0 -> floor 2
    # tuple-shaped result: bytes summed across the tuple elements
    assert tup.bytes == 16 * 4 + 8 * 2 and tup.group_size == 2


def test_parse_collectives_ignores_non_collectives():
    assert parse_collectives("  %x = f32[8]{0} add(%a, %b)\n") == []


def test_wire_bytes_ring_model():
    assert CollectiveOp("all-reduce", 1000, 4).wire_bytes \
        == pytest.approx(2 * 3 / 4 * 1000)
    assert CollectiveOp("all-gather", 1000, 4).wire_bytes \
        == pytest.approx(3 / 4 * 1000)
    assert CollectiveOp("reduce-scatter", 1000, 8).wire_bytes \
        == pytest.approx(7 / 8 * 1000)
    assert CollectiveOp("collective-permute", 1000, 4).wire_bytes == 1000
    # degenerate group clamps to 2, never divides by zero
    assert CollectiveOp("all-reduce", 1000, 0).wire_bytes \
        == pytest.approx(1000.0)


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2,
                 coll_bytes=ICI_BW / 4, chips=4)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    # whole-program (not per-partition) numbers divide by chips
    r2 = Roofline(flops=PEAK_FLOPS, hbm_bytes=0, coll_bytes=0, chips=4,
                  per_device=False)
    assert r2.t_compute == pytest.approx(0.25)
    s = r.summary()
    assert s["bottleneck"] == "compute"
    assert s["t_compute_s"] == pytest.approx(1.0)


def test_analyze_from_fake_compiled():
    class FakeCompiled:
        def cost_analysis(self):
            return [{"flops": 10.0, "bytes accessed": 20.0}]

        def as_text(self):
            return HLO

    r = analyze(FakeCompiled(), chips=4)
    assert r.flops == 10.0 and r.hbm_bytes == 20.0
    assert set(r.coll_by_kind) == {"all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"}
    assert r.coll_bytes == pytest.approx(
        sum(r.coll_by_kind.values()))


def test_extrapolate_affine_in_depth():
    r1 = Roofline(flops=10.0, hbm_bytes=100.0, coll_bytes=4.0, chips=2,
                  coll_by_kind={"all-reduce": 4.0})
    r2 = Roofline(flops=16.0, hbm_bytes=140.0, coll_bytes=6.0, chips=2,
                  coll_by_kind={"all-reduce": 4.0, "all-gather": 2.0})
    r = extrapolate(r1, r2, l1=1, l2=2, L=10)
    # fixed + L*layer: layer = r2 - r1, fixed = r1 - layer
    assert r.flops == pytest.approx(10 + 6 * 9)
    assert r.hbm_bytes == pytest.approx(100 + 40 * 9)
    assert r.coll_by_kind["all-gather"] == pytest.approx(2 * 9)
    # negative extrapolations clamp at 0
    r3 = Roofline(flops=10.0, hbm_bytes=0, coll_bytes=0, chips=2)
    r4 = Roofline(flops=5.0, hbm_bytes=0, coll_bytes=0, chips=2)
    assert extrapolate(r3, r4, 1, 2, 10).flops == 0.0


def test_model_flops_dense_rule():
    from repro.configs import get_config
    cfg = get_config("tinyllama-1.1b")
    n = cfg.active_param_count()
    assert model_flops(cfg, tokens=1000) == pytest.approx(6.0 * n * 1000)


def test_memory_analysis_summary_partial_attrs():
    class FakeMA:
        argument_size_in_bytes = 128
        temp_size_in_bytes = 64
        # output/generated_code absent on purpose

    class FakeCompiled:
        def memory_analysis(self):
            return FakeMA()

    out = memory_analysis_summary(FakeCompiled())
    assert out == {"argument_size_in_bytes": 128,
                   "temp_size_in_bytes": 64}
