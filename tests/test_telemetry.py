"""Telemetry plane (repro.telemetry + kernels/telemetry): the
non-perturbing contract — `--telemetry` must change NO trained bit on
the host round, the fused loop, or the 8-device block-sharded engine —
plus kernel-vs-reference parity, launch-counter namespacing (the Δ-SGD
2-launch/step budget is counted separately from telemetry launches),
the zero-host-transfer guarantee inside a fused block, the typed
schema registry, the JSONL event log, and the report-layer guards."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (flatten_fl_state, get_client_opt, get_server_opt,
                        init_fl_state, make_fl_loop, make_fl_round,
                        make_loss, unflatten_fl_state)
from repro.telemetry import (EventLog, SpanTimer, TelemetrySpec,
                             config_hash, kernel_launch_snapshot,
                             load_events, reset_kernel_launches,
                             resolve_telemetry, round_telemetry, schema)

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")

R, C, K, D, E = 4, 8, 3, 96, 18


def _problem(rng):
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(R, C, K, 4, D)),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(R, C, K, 4)),
                                jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    return make_loss(quad), params, batches


def _opts():
    return get_client_opt("delta_sgd"), get_server_opt("fedavg")


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


# ------------------------------------------------------------ kernels
def test_lane_histogram_kernel_matches_ref(rng):
    """Pallas histogram == jnp reference EXACTLY (counts are small
    integers in f32), including underflow/overflow bins and NaN lanes
    (NaN counts in no bin)."""
    from repro.kernels.telemetry import lane_histogram, lane_histogram_ref
    edges = jnp.asarray(TelemetrySpec(eta_bins=16).eta_edges())
    x = np.asarray(10.0 ** rng.uniform(-6.0, 3.0, size=257), np.float32)
    x[:3] = [0.0, np.nan, np.inf]
    x = jnp.asarray(x)
    h = lane_histogram(x, edges)
    ref = lane_histogram_ref(x, edges)
    assert h.shape == (16,)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(ref))
    # NaN and +inf lanes count in no bin (bins are [lo, hi) half-open,
    # so the overflow bin [e_-2, inf) excludes inf itself); 0.0 lands
    # in the underflow bin
    assert float(jnp.sum(h)) == x.shape[0] - 2


def test_lane_quantiles_kernel_matches_ref(rng):
    from repro.kernels.telemetry import lane_quantiles, lane_quantiles_ref
    x = jnp.asarray(rng.normal(size=77), jnp.float32)
    q = lane_quantiles(x, Q=11)
    ref = lane_quantiles_ref(x, Q=11)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(ref))
    srt = np.sort(np.asarray(x))
    assert float(q[0]) == srt[0] and float(q[-1]) == srt[-1]


def test_launch_counter_namespaces(rng):
    """Telemetry kernels count in their OWN namespace: running them
    does not move the Δ-SGD counter, and the Δ-SGD 2-launch/step
    invariant is unchanged with telemetry enabled."""
    from repro.kernels.delta_sgd import delta_sgd as dk
    from repro.kernels.telemetry import lane_histogram, lane_quantiles
    reset_kernel_launches()
    edges = jnp.asarray(TelemetrySpec().eta_edges())
    x = jnp.asarray(rng.normal(size=64), jnp.float32)
    lane_histogram(jnp.abs(x), edges)
    lane_quantiles(x)
    snap = kernel_launch_snapshot()
    assert snap.get("telemetry/lane_histogram") == 1
    assert snap.get("telemetry/lane_quantiles") == 1
    assert not any(k.startswith("delta_sgd/") for k in snap)

    # a telemetry-on pallas flat round still traces the Δ-SGD fused
    # pair exactly once (the local-step scan body: 2 trace-time
    # launches, an executed schedule of 2·K) — telemetry adds only its
    # own namespace
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="pallas",
                        telemetry=True)
    st = init_fl_state(params, sopt)
    reset_kernel_launches()
    jax.jit(rnd).lower(st, jax.tree.map(lambda x: x[0], batches))
    assert dk.launch_count() == 2
    snap = kernel_launch_snapshot()
    assert snap.get("telemetry/lane_histogram", 0) >= 1


# ------------------------------------------- non-perturbing trajectory
@pytest.mark.parametrize("backend", ["xla", "pallas", None])
def test_host_round_bit_exact_on_off(backend, rng):
    """R host rounds with telemetry on == off, bit for bit (flat xla,
    flat pallas, and the vmap tree engine), and the on-run's metrics
    are a strict superset."""
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    states, mets = [], []
    for tele in (False, True):
        rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                    flat=backend or False,
                                    telemetry=tele))
        st = init_fl_state(params, sopt)
        for r in range(R):
            st, m, _ = rnd(st, jax.tree.map(lambda x, r=r: x[r], batches))
        states.append(st)
        mets.append(m)
    _assert_trees_equal(states[0].params, states[1].params)
    for k in mets[0]:
        np.testing.assert_array_equal(np.asarray(mets[0][k]),
                                      np.asarray(mets[1][k]),
                                      err_msg=f"metric {k}")
    extra = set(mets[1]) - set(mets[0])
    assert "eta_hist" in extra and "loss_deciles" in extra
    B = TelemetrySpec().eta_bins
    assert mets[1]["eta_hist"].shape == (B,)
    # every finite η lane lands in a bin on the flat engines
    if backend is not None:
        assert float(jnp.sum(mets[1]["eta_hist"])) == C


def test_fused_loop_bit_exact_on_off(rng):
    """One R-round fused block with telemetry on == off bit-exact;
    distributions gain the leading R axis from the scan."""
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    outs = []
    for tele in (False, True):
        loop = make_fl_loop(loss, copt, sopt, params_like=params,
                            num_rounds=10, rounds_per_call=R, flat="xla",
                            telemetry=tele)
        fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        fst, mets = jax.jit(loop, donate_argnums=0)(fst, batches)
        outs.append((unflatten_fl_state(fst, loop.layout), mets))
    _assert_trees_equal(outs[0][0].params, outs[1][0].params)
    for k in outs[0][1]:
        np.testing.assert_array_equal(np.asarray(outs[0][1][k]),
                                      np.asarray(outs[1][1][k]),
                                      err_msg=f"metric {k}")
    B = TelemetrySpec().eta_bins
    assert outs[1][1]["eta_hist"].shape == (R, B)
    assert outs[1][1]["loss_deciles"].shape == (R, 11)


@needs8
@pytest.mark.slow
def test_block_sharded_bit_exact_and_hist_parity(rng):
    """8-device block engine: telemetry on == off bit-exact, AND the
    psum-assembled η histogram equals the replicated engine's
    bit-for-bit (counts are exact integers in f32, so the widened
    (N+5+B,) packed psum reproduces them exactly)."""
    from repro.sharding.spec import FederationSpec
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fed = FederationSpec(client_axes=("data",), fsdp_axes=(), tp_axes=())

    def run(block, tele):
        kw = dict(params_like=params, num_rounds=10, rounds_per_call=R,
                  flat="xla", telemetry=tele)
        if block:
            kw.update(mesh=mesh, federation=fed, block_sharded=True)
        loop = make_fl_loop(loss, copt, sopt, **kw)
        fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        fst, mets = jax.jit(loop)(fst, batches)
        return fst, mets

    f_off, m_off = run(True, False)
    f_on, m_on = run(True, True)
    assert float(jnp.max(jnp.abs(f_off.P - f_on.P))) == 0.0
    for k in m_off:
        np.testing.assert_array_equal(np.asarray(m_off[k]),
                                      np.asarray(m_on[k]),
                                      err_msg=f"metric {k}")
    _, m_rep = run(False, True)
    np.testing.assert_array_equal(np.asarray(m_on["eta_hist"]),
                                  np.asarray(m_rep["eta_hist"]))
    assert np.all(np.asarray(m_on["eta_hist"]).sum(axis=1) == C)


def test_fused_block_no_host_transfer(rng):
    """No implicit device->host transfer occurs while a telemetry-on
    fused block executes: the whole R-round call runs under
    jax.transfer_guard("disallow") (explicit staging outside it)."""
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="xla",
                        telemetry=True)
    jloop = jax.jit(loop)
    fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
    batches = jax.tree.map(jnp.asarray, batches)
    jax.block_until_ready(jloop(fst, batches))          # compile outside
    with jax.transfer_guard("disallow"):
        fst2, mets = jloop(fst, batches)
        jax.block_until_ready((fst2.P, mets))
    assert mets["eta_hist"].shape[0] == R


# ----------------------------------------------------- spec + registry
def test_resolve_telemetry_forms():
    assert not resolve_telemetry(None).enabled
    assert not resolve_telemetry(False).enabled
    assert resolve_telemetry(True).enabled
    spec = TelemetrySpec(enabled=True, eta_bins=8)
    assert resolve_telemetry(spec) is spec
    with pytest.raises(ValueError):
        resolve_telemetry("yes")
    edges = TelemetrySpec(eta_bins=8).eta_edges()
    assert len(edges) == 9
    assert edges[0] == 0.0 and np.isinf(edges[-1])


def test_round_telemetry_disabled_is_empty(rng):
    assert round_telemetry(TelemetrySpec(), jnp.ones(4),
                           jnp.ones((4, 2))) == {}


def test_schema_registry_roundtrip():
    """Every registered summary reduction is valid; the generated
    markdown table carries every metric; report names the launch
    drivers rely on stay registered."""
    specs = schema.specs()
    assert len(specs) >= 25
    table = schema.markdown_table()
    for s in specs:
        assert f"`{s.name}`" in table
        for _, red in s.summaries:
            assert red in ("mean", "sum", "min", "max")
    for name in ("loss", "eta_mean", "cohort_ids", "eta_hist",
                 "loss_deciles", "wire_bytes", "eta_clip_rate"):
        assert schema.get(name) is not None
    assert schema.is_scalar("loss")
    assert not schema.is_scalar("eta_hist")


def test_warn_unregistered_warns_once():
    schema._warned.discard("zz_bogus_metric")
    with pytest.warns(UserWarning, match="zz_bogus_metric"):
        schema.warn_unregistered("zz_bogus_metric", producer="test")
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")         # second call must NOT warn
        schema.warn_unregistered("zz_bogus_metric", producer="test")


def test_scenario_stats_routes_unregistered(rng):
    """launch/train._ScenarioStats stores EVERY metric (the old KEYS
    whitelist silently dropped unknown names), warning once."""
    from repro.launch.train import _ScenarioStats
    schema._warned.discard("zz_new_metric")
    stats = _ScenarioStats(None, num_clients=4)
    with pytest.warns(UserWarning, match="zz_new_metric"):
        stats.update(np.asarray([0, 1]),
                     {"stale_mean": 1.5, "zz_new_metric": 2.0,
                      "eta_hist": np.asarray([1.0, 2.0])})
    assert stats.metrics[0]["zz_new_metric"] == 2.0
    assert stats.metrics[0]["stale_mean"] == 1.5
    np.testing.assert_array_equal(stats.metrics[0]["eta_hist"],
                                  [1.0, 2.0])
    rep = stats.report()
    assert rep["stale_mean"] == 1.5
    assert rep["eta_hist"] == [1.0, 2.0]


# ------------------------------------------------------------ artifacts
def test_event_log_header_and_flush(tmp_path):
    path = tmp_path / "events.jsonl"
    cfg = {"task": "easy", "rounds": 4}
    with EventLog(str(path), config=cfg) as ev:
        # header is on disk BEFORE any flush (crash-visible metadata)
        header, events = load_events(str(path))
        assert header["kind"] == "header" and events == []
        assert header["config_hash"] == config_hash(cfg)
        ev.emit("round", t=0, loss=jnp.float32(1.5),
                eta_hist=np.arange(3, dtype=np.float32))
        assert ev.flush() == 1
        ev.emit("round", t=1, loss=0.5)
    header, events = load_events(str(path))
    assert [e["kind"] for e in events] == ["round", "round"]
    assert events[0]["loss"] == 1.5            # np scalars -> json floats
    assert events[0]["eta_hist"] == [0.0, 1.0, 2.0]
    assert ev.events_written == 2
    for line in path.read_text().splitlines():
        json.loads(line)                       # every line valid JSON


def test_event_log_rejects_headerless(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "round", "t": 0}\n')
    with pytest.raises(ValueError):
        load_events(str(p))


def test_span_timer():
    st = SpanTimer()
    with st.span("pack"):
        pass
    with st.span("pack"):
        pass
    st.add("stage", 0.5)
    s = st.summary()
    assert s["pack"]["n"] == 2 and s["pack"]["s"] >= 0.0
    assert s["stage"]["s"] == 0.5
    assert "pack" in str(st) and "stage" in str(st)


def test_static_telemetry_counts_collectives(rng):
    from repro.telemetry import static_telemetry
    loss, params, batches = _problem(rng)
    copt, sopt = _opts()
    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="pallas",
                        telemetry=True)
    fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
    reset_kernel_launches()
    lowered = jax.jit(loop).lower(fst, batches)
    snap = kernel_launch_snapshot()
    row = static_telemetry(lowered.compile(), rounds=R, launches=snap)
    assert row["rounds"] == R
    assert row["hlo_instructions"] > 0
    # the round scan body traces the Δ-SGD pair once for the whole block
    assert row["pallas_launches"]["delta_sgd/batched_norms"] == 1
    assert row["pallas_launches_per_round"]["delta_sgd/batched_norms"] \
        == 1 / R
    assert any(k.startswith("telemetry/") for k in row["pallas_launches"])


# ------------------------------------------------------- report layer
def test_report_tables_guard_missing_columns():
    from repro.launch.report import (dryrun_table, roofline_table,
                                     scenario_table)
    assert "| mlp | - |" in dryrun_table([{"arch": "mlp"}])
    assert roofline_table([{"mesh": "16x16"}]).count("\n") == 1
    out = scenario_table([{"scenario": "x"}])
    assert "| x | - |" in out


def test_scenario_summary_registry_driven():
    from repro.launch.report import scenario_summary
    mets = [{"stale_mean": 1.0, "wire_bytes": 100.0,
             "eta_hist": [0.0, 2.0, 1.0], "loss_deciles": [1.0, 2.0]},
            {"stale_mean": 3.0, "wire_bytes": 300.0,
             "eta_hist": [1.0, 0.0, 1.0], "loss_deciles": [3.0, 4.0]}]
    s = scenario_summary("sync_iid", [[0, 1], [1, 2]], 4, mets)
    assert s["stale_mean"] == 2.0
    assert s["wire_bytes_round"] == 200.0 and s["wire_bytes_total"] == 400.0
    assert s["eta_hist"] == [1.0, 2.0, 2.0]          # summed over rounds
    assert s["loss_deciles"] == [2.0, 3.0]           # averaged
    assert len(s["eta_hist_edges"]) == 4
    assert s["eta_hist_edges"][0] == 0.0


def test_eta_hist_render():
    from repro.launch.report import eta_hist_render
    edges = TelemetrySpec(eta_bins=4).eta_edges()
    out = eta_hist_render([1, 0, 2, 5], edges)
    assert "8 client-rounds" in out and "#####" in out
    assert eta_hist_render([0, 0], [0.0, 1.0, np.inf]).startswith("(empty")
