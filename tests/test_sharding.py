"""Sharding rules: PartitionSpecs are valid (divisible, deduped) for every
architecture's param tree on the production mesh *shape* (validated
structurally — the real 512-device lowering is the dry-run's job)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.sharding.spec import (FederationSpec, _dedupe, param_pspec,
                                 _resolve_conditional, _path_str)


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) is needed by the rules."""
    def __init__(self, shape):
        self.shape = shape


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_id", ["single", "multi"])
def test_param_specs_divide(arch, mesh_id):
    import jax.numpy as jnp
    cfg = get_config(arch)
    mesh = MESHES[mesh_id]
    spec = FederationSpec(client_axes=("data",), fsdp_axes=(),
                          tp_axes=("model",))
    model = build_model(cfg, jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    def check(path, leaf):
        ps = param_pspec(spec, _path_str(path), leaf)
        ps = _resolve_conditional(ps, leaf.shape, mesh, "model")
        ps = _dedupe(ps)
        assert len(ps) == leaf.ndim
        seen = set()
        for dim, name in zip(leaf.shape, ps):
            if name is None:
                continue
            axes = name if isinstance(name, tuple) else (name,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, _path_str(path), leaf.shape, ps)
            for a in axes:
                assert a not in seen
                seen.add(a)

    jax.tree_util.tree_map_with_path(check, shapes)


@pytest.mark.parametrize("mesh_id,kind,want_c,want_n,want_shards", [
    ("single", "cross_device", ("data",), ("model",), 16),
    ("multi", "cross_device", ("pod", "data"), ("model",), 16),
    ("single", "cross_silo", None, ("data", "model"), 256),
    ("multi", "cross_silo", ("pod",), ("data", "model"), 256),
])
def test_flat_spec_maps_clients_and_param_shards(mesh_id, kind, want_c,
                                                 want_n, want_shards):
    """flat_spec: C over the client axes, N over the remaining fsdp/tp
    axes; flat_shards is the N-dim shard count the packer pads to."""
    from repro.sharding.spec import get_federation_spec
    mesh = MESHES[mesh_id]
    spec = get_federation_spec(kind, mesh)
    ps = spec.flat_spec(mesh)
    assert len(ps) == 2
    assert ps[0] == want_c and ps[1] == want_n
    assert spec.flat_shards(mesh) == want_shards
    # client and param-shard axes never overlap
    ca, na = spec.flat_axes(mesh)
    assert not set(ca) & set(na)
    cs = spec.flat_client_spec(mesh)
    assert len(cs) <= 1 and (len(cs) == 0 or cs[0] == want_c)


def test_dedupe():
    assert tuple(_dedupe(P("model", "model"))) == ("model", None)
    assert tuple(_dedupe(P(("pod", "data"), "data"))) == (("pod", "data"),
                                                          None)


def test_big_weights_are_sharded():
    """No single >100M-element tensor may end up fully replicated."""
    import jax.numpy as jnp
    cfg = get_config("deepseek-v3-671b")
    mesh = MESHES["multi"]
    spec = FederationSpec(client_axes=("pod",), fsdp_axes=("data",),
                          tp_axes=("model",))
    model = build_model(cfg, jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    def check(path, leaf):
        n = int(np.prod(leaf.shape))
        if n < 100_000_000:
            return
        ps = _dedupe(_resolve_conditional(
            param_pspec(spec, _path_str(path), leaf), leaf.shape, mesh,
            "model"))
        assert any(a is not None for a in ps), (_path_str(path), leaf.shape)

    jax.tree_util.tree_map_with_path(check, shapes)
