"""Sharding rules: PartitionSpecs are valid (divisible, deduped) for every
architecture's param tree on the production mesh *shape* (validated
structurally — the real 512-device lowering is the dry-run's job)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.sharding.spec import (FederationSpec, _dedupe, param_pspec,
                                 _resolve_conditional, _path_str)


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) is needed by the rules."""
    def __init__(self, shape):
        self.shape = shape


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_id", ["single", "multi"])
def test_param_specs_divide(arch, mesh_id):
    import jax.numpy as jnp
    cfg = get_config(arch)
    mesh = MESHES[mesh_id]
    spec = FederationSpec(client_axes=("data",), fsdp_axes=(),
                          tp_axes=("model",))
    model = build_model(cfg, jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    def check(path, leaf):
        ps = param_pspec(spec, _path_str(path), leaf)
        ps = _resolve_conditional(ps, leaf.shape, mesh, "model")
        ps = _dedupe(ps)
        assert len(ps) == leaf.ndim
        seen = set()
        for dim, name in zip(leaf.shape, ps):
            if name is None:
                continue
            axes = name if isinstance(name, tuple) else (name,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, _path_str(path), leaf.shape, ps)
            for a in axes:
                assert a not in seen
                seen.add(a)

    jax.tree_util.tree_map_with_path(check, shapes)


def test_dedupe():
    assert tuple(_dedupe(P("model", "model"))) == ("model", None)
    assert tuple(_dedupe(P(("pod", "data"), "data"))) == (("pod", "data"),
                                                          None)


def test_big_weights_are_sharded():
    """No single >100M-element tensor may end up fully replicated."""
    import jax.numpy as jnp
    cfg = get_config("deepseek-v3-671b")
    mesh = MESHES["multi"]
    spec = FederationSpec(client_axes=("pod",), fsdp_axes=("data",),
                          tp_axes=("model",))
    model = build_model(cfg, jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.key(0))

    def check(path, leaf):
        n = int(np.prod(leaf.shape))
        if n < 100_000_000:
            return
        ps = _dedupe(_resolve_conditional(
            param_pspec(spec, _path_str(path), leaf), leaf.shape, mesh,
            "model"))
        assert any(a is not None for a in ps), (_path_str(path), leaf.shape)

    jax.tree_util.tree_map_with_path(check, shapes)
