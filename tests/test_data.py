"""Data pipeline: Dirichlet partitioner + federated batching."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.dirichlet import client_label_histogram, dirichlet_partition
from repro.data.pipeline import FederatedDataset
from repro.data.synthetic import get_task


def _skew(y, clients):
    """Mean per-client label-distribution TV distance from uniform."""
    h = client_label_histogram(y, clients).astype(np.float64)
    p = h / np.maximum(h.sum(1, keepdims=True), 1)
    u = 1.0 / p.shape[1]
    return float(np.mean(np.abs(p - u).sum(1) / 2))


def test_partition_sizes_and_determinism():
    task = get_task("easy")
    c1 = dirichlet_partition(task.y, 50, 0.1, 500, seed=3)
    c2 = dirichlet_partition(task.y, 50, 0.1, 500, seed=3)
    assert all(len(c) == 500 for c in c1)
    for a, b in zip(c1, c2):
        np.testing.assert_array_equal(a, b)


def test_skew_monotone_in_alpha():
    """Paper Fig. 8: smaller α -> more heterogeneity."""
    task = get_task("easy")
    skews = [_skew(task.y, dirichlet_partition(task.y, 100, a, 500, seed=0))
             for a in (1.0, 0.1, 0.01)]
    assert skews[0] < skews[1] < skews[2], skews


def test_variable_sizes():
    task = get_task("easy")
    rng = np.random.default_rng(0)
    sizes = rng.integers(100, 501, 30)
    clients = dirichlet_partition(task.y, 30, 0.1, seed=1,
                                  variable_sizes=sizes)
    assert [len(c) for c in clients] == list(sizes)


def test_round_batch_shapes():
    task = get_task("easy")
    fed = FederatedDataset.build(task, num_clients=40, alpha=0.1, seed=0)
    batches, w, ids = fed.sample_round(0.25, local_steps=3, batch_size=16)
    assert batches["x"].shape == (10, 3, 16, task.x.shape[1])
    assert batches["y"].shape == (10, 3, 16)
    assert w.shape == (10,)
    assert len(set(ids)) == 10  # without replacement


@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.01, 2.0), m=st.integers(2, 30))
def test_partition_property_all_indices_valid(alpha, m):
    task = get_task("easy")
    clients = dirichlet_partition(task.y, m, alpha, 100, seed=7)
    for idx in clients:
        assert idx.min() >= 0 and idx.max() < len(task.y)
        assert len(idx) == 100


def test_task_difficulty_ordering():
    """Linear probes separate 'easy' better than 'hard' — the ladder the
    transfer protocol relies on."""
    from numpy.linalg import lstsq
    accs = {}
    for tid in ("easy", "hard"):
        t = get_task(tid)
        X = t.x[:5000].reshape(5000, -1)
        Y = np.eye(t.num_classes)[t.y[:5000]]
        W = lstsq(X, Y, rcond=None)[0]
        Xt = t.x_test.reshape(len(t.y_test), -1)
        accs[tid] = float((Xt @ W).argmax(1).__eq__(t.y_test).mean())
    assert accs["easy"] > accs["hard"] + 0.15, accs
