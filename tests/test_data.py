"""Data pipeline: Dirichlet partitioner + federated batching."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.dirichlet import client_label_histogram, dirichlet_partition
from repro.data.pipeline import FederatedDataset
from repro.data.synthetic import get_task


def _skew(y, clients):
    """Mean per-client label-distribution TV distance from uniform."""
    h = client_label_histogram(y, clients).astype(np.float64)
    p = h / np.maximum(h.sum(1, keepdims=True), 1)
    u = 1.0 / p.shape[1]
    return float(np.mean(np.abs(p - u).sum(1) / 2))


def test_partition_sizes_and_determinism():
    task = get_task("easy")
    c1 = dirichlet_partition(task.y, 50, 0.1, 500, seed=3)
    c2 = dirichlet_partition(task.y, 50, 0.1, 500, seed=3)
    assert all(len(c) == 500 for c in c1)
    for a, b in zip(c1, c2):
        np.testing.assert_array_equal(a, b)


def test_skew_monotone_in_alpha():
    """Paper Fig. 8: smaller α -> more heterogeneity."""
    task = get_task("easy")
    skews = [_skew(task.y, dirichlet_partition(task.y, 100, a, 500, seed=0))
             for a in (1.0, 0.1, 0.01)]
    assert skews[0] < skews[1] < skews[2], skews


def test_variable_sizes():
    task = get_task("easy")
    rng = np.random.default_rng(0)
    sizes = rng.integers(100, 501, 30)
    clients = dirichlet_partition(task.y, 30, 0.1, seed=1,
                                  variable_sizes=sizes)
    assert [len(c) for c in clients] == list(sizes)


def test_round_batch_shapes():
    task = get_task("easy")
    fed = FederatedDataset.build(task, num_clients=40, alpha=0.1, seed=0)
    batches, w, ids = fed.sample_round(0.25, local_steps=3, batch_size=16)
    assert batches["x"].shape == (10, 3, 16, task.x.shape[1])
    assert batches["y"].shape == (10, 3, 16)
    assert w.shape == (10,)
    assert len(set(ids)) == 10  # without replacement


@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.01, 2.0), m=st.integers(2, 30))
def test_partition_property_all_indices_valid(alpha, m):
    task = get_task("easy")
    clients = dirichlet_partition(task.y, m, alpha, 100, seed=7)
    for idx in clients:
        assert idx.min() >= 0 and idx.max() < len(task.y)
        assert len(idx) == 100


def test_task_difficulty_ordering():
    """Linear probes separate 'easy' better than 'hard' — the ladder the
    transfer protocol relies on."""
    from numpy.linalg import lstsq
    accs = {}
    for tid in ("easy", "hard"):
        t = get_task(tid)
        X = t.x[:5000].reshape(5000, -1)
        Y = np.eye(t.num_classes)[t.y[:5000]]
        W = lstsq(X, Y, rcond=None)[0]
        Xt = t.x_test.reshape(len(t.y_test), -1)
        accs[tid] = float((Xt @ W).argmax(1).__eq__(t.y_test).mean())
    assert accs["easy"] > accs["hard"] + 0.15, accs


# --------------------------------------------------------------------------
# satellite coverage: assignment uniqueness, alpha limits, sample_round
# determinism (see ISSUE 3)
# --------------------------------------------------------------------------
def test_partition_assigns_each_index_at_most_once():
    """Every sample index is assigned exactly once across clients while
    classes last (the partitioner only resamples with replacement once a
    class pool is exhausted — not the case at this scale)."""
    task = get_task("easy")
    clients = dirichlet_partition(task.y, 20, 10.0, 200, seed=11)
    allidx = np.concatenate(clients)
    assert len(allidx) == 20 * 200
    assert len(np.unique(allidx)) == len(allidx)


def test_alpha_limits_uniform_vs_concentrated():
    """alpha→∞: per-client label distribution ≈ the uniform prior;
    alpha→0: mass concentrates on one or two classes per client."""
    task = get_task("easy")
    h_inf = client_label_histogram(
        task.y, dirichlet_partition(task.y, 30, 1000.0, 500, seed=0))
    p_inf = h_inf / h_inf.sum(1, keepdims=True)
    tv_inf = np.abs(p_inf - 1.0 / p_inf.shape[1]).sum(1).mean() / 2
    assert tv_inf < 0.1, tv_inf

    h0 = client_label_histogram(
        task.y, dirichlet_partition(task.y, 30, 0.001, 500, seed=0))
    top_share = (h0.max(1) / h0.sum(1)).mean()
    assert top_share > 0.9, top_share


def test_sample_round_shape_and_determinism():
    """Two pipelines built from the same seed draw identical cohorts and
    batches; explicit round_idx pins the cohort draw."""
    task = get_task("easy")
    feds = [FederatedDataset.build(task, num_clients=25, alpha=0.5, seed=9)
            for _ in range(2)]
    outs = [f.sample_round(0.2, 3, 8) for f in feds]
    for (b1, w1, i1), (b2, w2, i2) in [(outs[0], outs[1])]:
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(w1, w2)
        np.testing.assert_array_equal(b1["x"], b2["x"])
        np.testing.assert_array_equal(b1["y"], b2["y"])
    assert outs[0][0]["x"].shape == (5, 3, 8, task.x.shape[1])
    # explicit round_idx: same round -> same cohort, later round -> new
    fed = FederatedDataset.build(task, num_clients=25, alpha=0.5, seed=9)
    _, _, ids_a = fed.sample_round(0.2, 3, 8, round_idx=4)
    _, _, ids_b = fed.sample_round(0.2, 3, 8, round_idx=4)
    np.testing.assert_array_equal(ids_a, ids_b)
    _, _, ids_c = fed.sample_round(0.2, 3, 8, round_idx=5)
    assert not np.array_equal(np.sort(ids_a), np.sort(ids_c))
