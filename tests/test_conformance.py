"""Conformance plane (repro.conformance): the checked-in regression
corpus runs green through every applicable oracle (tier-1), the sampler
is deterministic and valid, the greedy shrinker minimizes toward the
default point, violation artifacts round-trip through JSON, and — the
teeth — a deliberately planted engine mutation is detected by the
fuzzer, shrunk to the minimal config, and reproduced from the emitted
artifact by ``python -m repro.conformance.replay`` in a fresh process
(then vanishes under ``--ignore-mutation``)."""
import dataclasses
import json
import os
import subprocess
import sys
import types

import jax
import pytest

from repro.conformance import (DEFAULT, ConfPoint, Harness, ORACLES,
                               ServePoint, Violation, active_mutation,
                               applicable, check_config, invalid_reason,
                               read_artifact, sample, shrink,
                               write_artifact)
from repro.conformance.corpus import generate, load

_CORPUS = load()


def _corpus_params():
    out = []
    for cfg in _CORPUS:
        marks = []
        if cfg.mesh or cfg.serve is not None:
            marks.append(pytest.mark.slow)
        if cfg.mesh:
            marks.append(pytest.mark.skipif(
                jax.device_count() < 8, reason="needs >= 8 devices"))
        out.append(pytest.param(cfg, id=cfg.label(), marks=marks))
    return out


# ----------------------------------------------------------------- corpus
def test_corpus_is_generator_output():
    """corpus.json == the generator: regeneration is a reviewed change,
    never silent drift."""
    assert [c.to_dict() for c in _CORPUS] \
        == [c.to_dict() for c in generate()]


def test_corpus_size_and_validity():
    assert len(_CORPUS) >= 25
    for cfg in _CORPUS:
        assert invalid_reason(cfg) is None, cfg.label()
        # every corpus entry must exercise at least the universal
        # train oracles
        assert len(applicable(cfg)) >= (1 if cfg.serve is not None
                                        else 8), cfg.label()


@pytest.mark.parametrize("cfg", _corpus_params())
def test_corpus_config_green(cfg):
    """Tier-1 regression gate: every corpus config satisfies every
    applicable oracle on the pristine engines."""
    violations = check_config(cfg, do_shrink=False)
    assert not violations, "\n".join(
        m for v in violations for m in v.messages)


# ---------------------------------------------------------------- sampler
def test_sampler_deterministic_and_valid():
    for seed in range(120):
        a = sample(seed)
        assert a == sample(seed)
        assert invalid_reason(a) is None, (seed, a.label())
    # the space actually varies across its axes
    drawn = [sample(s) for s in range(120)]
    assert {c.compression for c in drawn} == {"none", "int8", "topk"}
    assert len({c.scenario for c in drawn}) >= 6
    assert {c.server_opt for c in drawn} >= {"fedavg", "fedadam"}
    assert any(c.mesh for c in drawn)
    assert any(c.serve is not None for c in drawn)


def test_confpoint_json_roundtrip():
    # force a serve section so the tuple fields go through JSON too
    cfg = dataclasses.replace(sample(7), serve=ServePoint())
    assert ConfPoint.from_dict(cfg.to_dict()) == cfg
    # through actual JSON text, tuples and all
    assert ConfPoint.from_dict(json.loads(json.dumps(cfg.to_dict()))) \
        == cfg


def test_invalid_reasons():
    assert invalid_reason(DEFAULT) is None
    bad = [
        dataclasses.replace(DEFAULT, clients=1),
        dataclasses.replace(DEFAULT, compression="fp4"),
        dataclasses.replace(DEFAULT, scenario="no_such_preset"),
        dataclasses.replace(DEFAULT, scenario="fleet_uniform"),
        dataclasses.replace(DEFAULT, robust_agg="clip"),  # no scenario
        dataclasses.replace(DEFAULT, mesh=True, clients=3),
        dataclasses.replace(DEFAULT, serve=ServePoint(cache_len=4)),
    ]
    for cfg in bad:
        assert invalid_reason(cfg) is not None, cfg


# ----------------------------------------------------------------- shrink
def test_shrink_greedy_toward_default():
    """Synthetic oracle (no engine runs): violation iff dim >= 8 and
    rounds >= 2. The shrinker must land exactly on the smallest
    violating point with every other axis at its default."""
    start = dataclasses.replace(
        DEFAULT, seed=3, rounds=4, clients=8, local_steps=3, batch=4,
        dim=33, bf16_dim=18, server_opt="fedyogi", weighted=True,
        scenario="zipf_async", compression="int8", error_feedback=True)
    oracle = types.SimpleNamespace(
        applies=lambda c: None,
        check=lambda h: (["bad"] if h.cfg.dim >= 8 and h.cfg.rounds >= 2
                         else []))
    minimal, evals = shrink(start, oracle, budget=100)
    assert minimal == dataclasses.replace(DEFAULT, seed=3, rounds=2,
                                          dim=8)
    assert 0 < evals <= 100


def test_shrink_respects_oracle_domain():
    """A shrink candidate the oracle does not apply to is never
    accepted (dropping the axis would 'fix' the violation vacuously)."""
    start = dataclasses.replace(DEFAULT, seed=1, rounds=3, clients=8)
    oracle = types.SimpleNamespace(
        applies=lambda c: None if c.rounds >= 2 else "needs rounds>=2",
        check=lambda h: ["bad"])
    minimal, _ = shrink(start, oracle, budget=50)
    assert minimal.rounds == 2        # not 1: the oracle's floor
    assert minimal.clients == DEFAULT.clients


# -------------------------------------------------------------- artifacts
def test_artifact_roundtrip(tmp_path):
    v = Violation(oracle="pallas_vs_xla", messages=["m1", "m2"],
                  config=dataclasses.replace(DEFAULT, seed=9),
                  shrunk_from=sample(9), shrink_evals=5,
                  mutation="delta_sgd.pallas_apply:1e-3")
    path = write_artifact(str(tmp_path), v)
    back = read_artifact(path)
    assert back == v
    data = json.loads(open(path).read())
    assert data["relation"] == "allclose" and data["tol"] == 1e-5


def test_replay_nonviolating_artifact_exits_zero(tmp_path):
    """An artifact whose config satisfies the oracle replays to exit
    0 — the green path the corpus-replay CI leg relies on."""
    from repro.conformance import replay as replay_mod
    v = Violation(oracle="fused_vs_host", messages=["stale"],
                  config=dataclasses.replace(DEFAULT, seed=2),
                  shrunk_from=dataclasses.replace(DEFAULT, seed=2))
    path = write_artifact(str(tmp_path), v)
    assert replay_mod.run([path]) == 0


def test_replay_rejects_inapplicable_oracle(tmp_path):
    from repro.conformance import replay as replay_mod
    v = Violation(oracle="serve_pool_vs_isolated", messages=["x"],
                  config=DEFAULT, shrunk_from=DEFAULT)
    path = write_artifact(str(tmp_path), v)
    assert replay_mod.run([path]) == 2


# ---------------------------------------------------------------- oracles
def test_oracle_applicability_partitions():
    cfg = DEFAULT
    names = {o.name for o in applicable(cfg)}
    assert "fused_vs_host" in names and "pallas_vs_xla" in names
    assert "serve_pool_vs_isolated" not in names     # no serve section
    assert "block_vs_replicated" not in names        # no mesh
    assert "resume_vs_uninterrupted" not in names    # rounds < 2
    cfg2 = dataclasses.replace(cfg, rounds=2, mesh=True, clients=4)
    names2 = {o.name for o in applicable(cfg2)}
    assert "resume_vs_uninterrupted" in names2
    if jax.device_count() >= 8:
        assert "block_vs_replicated" in names2


def test_every_registered_oracle_has_direction():
    for o in ORACLES.values():
        assert o.relation in ("bitexact", "allclose", "per-cell")
        assert o.description


# --------------------------------------------------------- mutation teeth
def test_mutation_context_installs_and_restores():
    from repro.kernels.delta_sgd import delta_sgd as dk
    orig = dk.batched_apply
    with active_mutation("delta_sgd.pallas_apply:1e-3"):
        assert dk.batched_apply is not orig
    assert dk.batched_apply is orig
    with pytest.raises(KeyError, match="unknown mutation"):
        with active_mutation("no_such_mutation"):
            pass


def test_kernel_oracle_catches_telemetry_mutation():
    """The off-by-one histogram mutation is invisible to trajectories
    but must trip the kernel:telemetry parity cells."""
    cfg = ConfPoint(seed=0)      # seed 0 selects a hist cell
    oracle = ORACLES["kernel:telemetry"]
    assert oracle.check(Harness(cfg)) == []
    with active_mutation("telemetry.hist_offbyone"):
        assert oracle.check(Harness(cfg))


@pytest.mark.slow
def test_fuzzer_teeth_detect_shrink_replay(tmp_path):
    """Acceptance: a planted epsilon perturbation in the pallas engine
    is (1) detected by the differential fuzzer within the CI seed
    budget, (2) shrunk to the minimal config — every structural axis
    stripped — and (3) reproduced from the emitted JSON artifact by
    ``python -m repro.conformance.replay`` in a fresh process, which
    then exits 0 under --ignore-mutation (the defect lives in the
    mutation, not the plane)."""
    mutation = "delta_sgd.pallas_apply:1e-3"
    start = sample(4, allow_mesh=False, allow_serve=False)
    assert start != dataclasses.replace(DEFAULT, seed=4)  # shrink work
    with active_mutation(mutation):
        violations = check_config(start,
                                  oracle_names=["pallas_vs_xla"],
                                  do_shrink=True, shrink_budget=40,
                                  mutation=mutation)
    assert len(violations) == 1
    v = violations[0]
    assert v.oracle == "pallas_vs_xla"
    # minimal: greedy shrink stripped every axis back to the default
    assert v.config == dataclasses.replace(DEFAULT, seed=4)
    assert v.shrunk_from == start and v.shrink_evals > 0

    path = write_artifact(str(tmp_path), v)
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r1 = subprocess.run(
        [sys.executable, "-m", "repro.conformance.replay", path],
        cwd=repo, env=env, capture_output=True, text=True, timeout=300)
    assert r1.returncode == 1, r1.stdout + r1.stderr
    assert "REPRODUCES" in r1.stdout
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.conformance.replay", path,
         "--ignore-mutation"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stdout + r2.stderr


# --------------------------------------------------- found-by-fuzzing lock
def test_adaptive_server_async_bf16_runs():
    """Regression for a bug THIS plane found on first contact: adaptive
    server opts initialized moments with zeros_like(params), so a bf16
    leaf flipped the moment dtype after the first update — a trace-time
    lax.cond type mismatch in the async buffer flush (and a scan-carry
    mismatch in the fused loop). Locked by corpus entry s105 and here
    by the smallest failing shape."""
    cfg = dataclasses.replace(DEFAULT, rounds=2, bf16_dim=6,
                              server_opt="fedyogi",
                              scenario="zipf_async")
    assert invalid_reason(cfg) is None
    h = Harness(cfg)
    h.host("xla")      # crashed at trace time before the fix
    h.fused("xla")
    violations = check_config(cfg, oracle_names=["resume_vs_uninterrupted"],
                              do_shrink=False)
    assert all(v.error is None for v in violations), violations
