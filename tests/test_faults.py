"""Fault injection + robust aggregation (repro.federation.faults).

PR-6 acceptance tier: deterministic fault draws off axis 4 of the round
key, the always-on numerical guards (NaN lane latching + ETA_CLAMP), the
RobustAgg ladder (mean/clip/trimmed/median, replicated + Pallas +
bucketed sharded), byzantine-defense behavior (plain mean measurably
diverges under 10% corruption while clip/trimmed stay within 10% of the
clean final loss), quorum degradation (a skipped round leaves params
bit-identical and increments the skipped counter in the host AND fused
engines), the 2-launches-per-local-step invariant with guards + faults
active, and fused-vs-host bit-exactness under active faults."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (flatten_fl_state, get_client_opt, get_server_opt,
                        init_fl_state, make_fl_loop, make_fl_round,
                        make_loss, unflatten_fl_state)
from repro.core import flat as fp
from repro.core.delta_sgd import (ETA_CLAMP, FlatDeltaSGDState,
                                  flat_delta_sgd_init, flat_delta_sgd_step)
from repro.federation import get_scenario
from repro.federation.faults import (FaultModel, RobustAgg,
                                     robust_aggregate,
                                     robust_aggregate_sharded)

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")


def _lanes_equal(a, b):
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


# ------------------------------------------------------------ fault draws
def test_fault_draw_deterministic():
    """Same key -> identical lanes (reproducible from (seed, round));
    a different round key perturbs them."""
    fm = FaultModel(drop_rate=0.4, nan_rate=0.2, byzantine_rate=0.3,
                    overstale_rate=0.3)
    key = jax.random.key(7)
    a, b = fm.draw(key, 64, 8), fm.draw(key, 64, 8)
    _lanes_equal(a, b)
    c = fm.draw(jax.random.fold_in(key, 1), 64, 8)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c))
    # dropped clients die strictly mid-round: 1 <= drop_step < K
    ds = np.asarray(a.drop_step)
    assert ds.shape == (64,) and ds.dtype == np.int32
    assert np.all((ds == 8) | ((ds >= 1) & (ds < 8)))
    assert np.any(ds < 8)


def test_fault_draw_rate_extremes():
    key = jax.random.key(0)
    clean = FaultModel()
    assert not clean.active
    lanes = clean.draw(key, 16, 4)
    assert np.all(np.asarray(lanes.drop_step) == 4)
    assert np.all(np.asarray(lanes.nan_step) == 4)
    assert not np.any(np.asarray(lanes.byzantine))
    assert not np.any(np.asarray(lanes.overstale))
    allbad = FaultModel(drop_rate=1.0, nan_rate=1.0, byzantine_rate=1.0,
                        overstale_rate=1.0)
    lanes = allbad.draw(key, 16, 4)
    assert np.all(np.asarray(lanes.drop_step) < 4)
    assert np.all(np.asarray(lanes.nan_step) < 4)
    assert np.all(np.asarray(lanes.byzantine))
    assert np.all(np.asarray(lanes.overstale))


def test_fault_and_robust_specs_validated():
    with pytest.raises(ValueError):
        FaultModel(drop_rate=1.5)
    with pytest.raises(ValueError):
        FaultModel(nan_rate=-0.1)
    with pytest.raises(KeyError):
        RobustAgg(kind="bogus")
    with pytest.raises(ValueError):
        RobustAgg(trim_frac=0.5)
    with pytest.raises(ValueError):
        RobustAgg(clip_norm=0.0)
    with pytest.raises(ValueError):
        get_scenario("sync_iid", quorum=-1)


# ------------------------------------------------- in-step numerical guards
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_nan_guard_latches_and_freezes_lane(backend, rng):
    """A non-finite gradient drops the lane (η=0, params untouched),
    latches ``valid`` off for the rest of the round, and never leaks NaN
    into the packed buffer or the rolled prev_grads."""
    params = {"x": jnp.asarray(rng.normal(size=40), jnp.float32)}
    layout = fp.layout_of(params)
    C = 4
    P = jnp.broadcast_to(fp.pack(params, layout)[None],
                         (C, layout.padded_size))
    S = flat_delta_sgd_init(C, layout, eta0=0.1, theta0=1e8)
    G = jnp.asarray(rng.normal(size=(C, layout.padded_size)), jnp.float32)
    G_bad = G.at[2].set(jnp.nan)
    kw = dict(gamma=2.0, delta=0.1, eta0=0.1, backend=backend)
    P1, S1 = flat_delta_sgd_step(P, G_bad, S, **kw)
    assert np.all(np.isfinite(np.asarray(P1)))
    np.testing.assert_array_equal(np.asarray(P1[2]), np.asarray(P[2]))
    assert np.asarray(S1.valid).tolist() == [True, True, False, True]
    # prev_grads carry the SANITIZED gradient — lane 2 is all zeros
    np.testing.assert_array_equal(np.asarray(S1.prev_grads[2]), 0.0)
    # a clean step afterwards must NOT resurrect the lane (latching)
    P2, S2 = flat_delta_sgd_step(P1, G, S1, **kw)
    assert np.asarray(S2.valid).tolist() == [True, True, False, True]
    np.testing.assert_array_equal(np.asarray(P2[2]), np.asarray(P[2]))
    # healthy lanes moved
    assert float(jnp.max(jnp.abs(P2[0] - P[0]))) > 0.0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_eta_clamp_counts_clips(backend, rng):
    """A runaway η (near-zero gradient difference on a non-first step)
    is clamped to ETA_CLAMP and counted per client in ``clips``."""
    params = {"x": jnp.asarray(rng.normal(size=32), jnp.float32)}
    layout = fp.layout_of(params)
    C = 3
    P = jnp.broadcast_to(fp.pack(params, layout)[None],
                         (C, layout.padded_size))
    G = jnp.asarray(rng.normal(size=(C, layout.padded_size)), jnp.float32)
    # prev_grads ~ G: dg_norm tiny -> cand1 explodes; η_prev above the
    # ceiling keeps cand2 over it too, so the clamp must fire
    S = FlatDeltaSGDState(
        prev_grads=G + 1e-7, eta=jnp.full((C,), 2.0 * ETA_CLAMP),
        theta=jnp.ones((C,)), prev_grad_norm=jnp.ones((C,)),
        k=jnp.asarray(1, jnp.int32), valid=jnp.ones((C,), bool),
        clips=jnp.zeros((C,), jnp.int32))
    P1, S1 = flat_delta_sgd_step(P, G, S, gamma=2.0, delta=0.1, eta0=0.1,
                                 backend=backend)
    np.testing.assert_allclose(np.asarray(S1.eta), ETA_CLAMP)
    assert np.asarray(S1.clips).tolist() == [1, 1, 1]
    assert np.all(np.asarray(S1.valid))
    assert np.all(np.isfinite(np.asarray(P1)))


# ---------------------------------------------------- robust agg (direct)
def test_robust_aggregate_mean_and_clip_values(rng):
    C, N = 6, 32
    delta = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    valid = jnp.asarray([True, True, False, True, True, True])
    d, v = np.asarray(delta), np.asarray(valid, np.float32)
    agg, info = robust_aggregate(delta, RobustAgg("mean"), valid)
    np.testing.assert_allclose(
        np.asarray(agg), (v[:, None] * d).sum(0) / v.sum(), rtol=1e-6)
    assert info == {}
    spec = RobustAgg("clip", clip_norm=2.0)
    agg, info = robust_aggregate(delta, spec, valid)
    z = d * v[:, None]
    norms = np.sqrt((z * z).sum(1))
    f = np.minimum(1.0, 2.0 / np.maximum(norms, 1e-12))
    np.testing.assert_allclose(
        np.asarray(agg), (z * f[:, None]).sum(0) / v.sum(), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(info["agg_clip_rate"]),
                               ((f < 1.0) * v).sum() / v.sum())


@pytest.mark.parametrize("kind", ["trimmed", "median"])
def test_robust_aggregate_order_statistics(kind, rng):
    C, N = 10, 256        # N lane-aligned: the Pallas kernel requires it
    delta = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    valid = jnp.ones((C,), bool).at[3].set(False)
    spec = RobustAgg(kind, trim_frac=0.2)
    t = spec.trim_count(C)
    assert t == (2 if kind == "trimmed" else 4)
    z = np.asarray(delta) * np.asarray(valid, np.float32)[:, None]
    s = np.sort(z, axis=0)
    expect = s[t:C - t].mean(0)
    agg, _ = robust_aggregate(delta, spec, valid)
    np.testing.assert_allclose(np.asarray(agg), expect, rtol=1e-6,
                               atol=1e-7)
    # Pallas bitonic kernel (interpret off-TPU) agrees with the jnp sort
    agg_k, _ = robust_aggregate(delta, spec, valid, backend="pallas")
    np.testing.assert_allclose(np.asarray(agg_k), expect, rtol=1e-6,
                               atol=1e-7)


def test_robust_aggregate_outlier_resistance(rng):
    """One byzantine row scaled ×(−50) poisons the mean but not the
    clipped/trimmed/median rungs."""
    C, N = 10, 16
    # honest deltas have l2 norm ~0.4 < clip_norm: only the byzantine
    # row (norm ~20) trips the clip
    base = 0.1 * jnp.asarray(rng.normal(size=(1, N)), jnp.float32)
    delta = base + 0.001 * jnp.asarray(rng.normal(size=(C, N)),
                                       jnp.float32)
    delta = delta.at[4].multiply(-50.0)
    truth = np.asarray(base)[0]
    mean, _ = robust_aggregate(delta, RobustAgg("mean"))
    assert np.max(np.abs(np.asarray(mean) - truth)) > 0.2
    for spec in (RobustAgg("clip", clip_norm=0.5),
                 RobustAgg("trimmed", trim_frac=0.2),
                 RobustAgg("median")):
        agg, _ = robust_aggregate(delta, spec)
        assert np.max(np.abs(np.asarray(agg) - truth)) < 0.1, spec.kind


@needs8
def test_robust_aggregate_sharded_bucketed(rng):
    """Mesh-native ladder: clip matches the replicated result exactly in
    math (per-client norms are psum-exact); trimmed is the BUCKETED
    variant — shard-local trimmed means averaged across client shards."""
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    pspec = spec.flat_spec(mesh)
    C, N = 16, 256
    delta = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    valid = jnp.asarray(rng.random(C) > 0.2)
    with mesh:
        clip = RobustAgg("clip", clip_norm=1.0)
        agg_s, info_s = jax.jit(
            lambda d, v: robust_aggregate_sharded(
                d, clip, v, mesh=mesh, pspec=pspec))(delta, valid)
        agg_r, info_r = robust_aggregate(delta, clip, valid)
        np.testing.assert_allclose(np.asarray(agg_s), np.asarray(agg_r),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(info_s["agg_clip_rate"]),
                                   np.asarray(info_r["agg_clip_rate"]))
        trim = RobustAgg("trimmed", trim_frac=0.25)
        agg_s, _ = jax.jit(
            lambda d, v: robust_aggregate_sharded(
                d, trim, v, mesh=mesh, pspec=pspec))(delta, valid)
    # expected: 4 client shards × 4 clients each, trim 1 per end locally
    z = np.asarray(delta) * np.asarray(valid, np.float32)[:, None]
    buckets = [np.sort(z[i:i + 4], axis=0)[1:3].mean(0)
               for i in range(0, C, 4)]
    np.testing.assert_allclose(np.asarray(agg_s),
                               np.mean(buckets, axis=0), rtol=1e-5,
                               atol=1e-6)


# ------------------------------------------------------ round-level tests
R, C, K, D = 4, 10, 3, 48


def _problem(rng, rounds=R, clients=C):
    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(
        rng.normal(size=(rounds, clients, K, 4, D)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(rounds, clients, K, 4)),
                         jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    return quad, params, batches


def _host_rounds(loss, copt, sopt, params, batches, scn, **kw):
    rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                scenario=scn, num_clients=20, **kw))
    st = init_fl_state(params, sopt, scn)
    mets = []
    for r in range(rounds):
        st, m, _ = rnd(st, jax.tree.map(lambda x: x[r], batches))
        mets.append(m)
    return st, mets


def test_fault_free_robust_mean_is_legacy_bit_exact(rng):
    """The sync_iid preset (mean agg, zero fault rates, no quorum) takes
    the exact legacy round tail: bit-identical to scenario=None, with
    the guard telemetry reporting all-clean."""
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    st0, m0 = _host_rounds(loss, copt, sopt, params, batches, None,
                           flat="xla")
    st1, m1 = _host_rounds(loss, copt, sopt, params, batches,
                           get_scenario("sync_iid"), flat="xla")
    _assert_trees_equal(st0.params, st1.params)
    for a, b in zip(m0, m1):
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]), err_msg=k)
        assert float(b["eta_clip_rate"]) == 0.0
        assert float(b["nan_guard_rate"]) == 0.0


def test_guarded_mean_tail_matches_legacy_closely(rng):
    """quorum > 0 with mean agg routes through the delta-space guarded
    tail — same math as the legacy mean up to summation order, so the
    trajectories must agree tightly (and nothing is skipped)."""
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    st0, _ = _host_rounds(loss, copt, sopt, params, batches, None,
                          flat="xla")
    scn = get_scenario("sync_iid", quorum=1)
    st1, m1 = _host_rounds(loss, copt, sopt, params, batches, scn,
                           flat="xla")
    np.testing.assert_allclose(np.asarray(st1.params["x"]),
                               np.asarray(st0.params["x"]), rtol=1e-5,
                               atol=1e-6)
    assert all(float(m["round_skipped"]) == 0.0 for m in m1)
    assert all(float(m["valid_count"]) == C for m in m1)


def test_launch_schedule_two_per_step_with_guards_and_faults(rng):
    """Faults + robust aggregation keep the flat engine's launch
    invariant: one traced round = 2 delta-sgd kernel launches (the fused
    pair), plus exactly ONE robust-agg kernel launch for the trimmed
    tail — fault lanes ride the existing η-mask, costing nothing."""
    from repro.kernels.delta_sgd import delta_sgd as dk
    from repro.kernels.robust_agg import robust_agg as rk
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario("sync_iid", drop_rate=0.2, nan_rate=0.1,
                       byzantine_rate=0.2, robust_agg="trimmed",
                       quorum=2)
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                scenario=scn, flat="pallas"))
    st = init_fl_state(params, sopt, scn)
    dk.reset_launch_count()
    rk.reset_launch_count()
    st, m, _ = rnd(st, jax.tree.map(lambda x: x[0], batches))
    jax.block_until_ready(st.params["x"])
    assert dk.launch_count() == 2, dict(dk.LAUNCHES)
    assert rk.launch_count() == 1, dict(rk.LAUNCHES)


def test_nan_fault_telemetry_all_lanes(rng):
    """nan_rate=1.0: every lane trips the guard — nan_guard_rate hits
    1.0, valid_count 0, and the round's params stay finite."""
    quad, params, batches = _problem(rng, rounds=1)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario("sync_iid", nan_rate=1.0)
    st, mets = _host_rounds(loss, copt, sopt, params, batches, scn,
                            flat="xla")
    m = mets[0]
    assert float(m["nan_guard_rate"]) == 1.0
    assert float(m["valid_count"]) == 0.0
    assert np.all(np.isfinite(np.asarray(st.params["x"])))


# ------------------------------------------------- byzantine acceptance
@pytest.mark.slow
def test_byzantine_defense_acceptance(rng):
    """ISSUE acceptance: at 10% byzantine corruption (−10× deltas),
    plain mean aggregation diverges by orders of magnitude while the
    trimmed mean stays within 10% of the clean final loss and clip
    within 15% (clip bounds the corrupted mass but cannot reject its
    flipped sign, so its plateau sits slightly higher). Same seed
    everywhere — identical batches and identical fault draws, only the
    aggregator changes."""
    rounds = 30
    quad, params, batches = _problem(rng, rounds=rounds, clients=20)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")

    def final_loss(agg):
        over = {} if agg is None else dict(
            byzantine_rate=0.1, byzantine_scale=-10.0, robust_agg=agg,
            clip_norm=1.0, trim_frac=0.2)
        st, _ = _host_rounds(loss, copt, sopt, params, batches,
                             get_scenario("sync_iid", **over), flat="xla")
        # global objective: mean loss over every client's last-round data
        b = jax.tree.map(lambda x: x[-1].reshape((-1,) + x.shape[3:]),
                         batches)
        return float(quad(st.params, b)[0])

    clean = final_loss(None)
    mean_byz = final_loss("mean")
    clip_byz = final_loss("clip")
    trim_byz = final_loss("trimmed")
    print(f"byzantine acceptance: clean={clean:.4f} mean={mean_byz:.4f} "
          f"clip={clip_byz:.4f} trimmed={trim_byz:.4f}")
    assert mean_byz > 100.0 * clean, (mean_byz, clean)
    assert clip_byz <= 1.15 * clean, (clip_byz, clean)
    assert trim_byz <= 1.1 * clean, (trim_byz, clean)


# ------------------------------------------------------ quorum degradation
def test_quorum_skip_host_engine(rng):
    """drop_rate=1.0: zero valid clients — the round is a lax.cond no-op
    leaving params/server state bit-identical while the skipped counter
    and round index advance."""
    quad, params, batches = _problem(rng, rounds=2)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario("sync_iid", drop_rate=1.0, quorum=1)
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                scenario=scn, flat="xla"))
    st0 = init_fl_state(params, sopt, scn)
    st1, m, _ = rnd(st0, jax.tree.map(lambda x: x[0], batches))
    np.testing.assert_array_equal(np.asarray(st1.params["x"]),
                                  np.asarray(st0.params["x"]))
    _assert_trees_equal(st1.server_state, st0.server_state)
    assert int(st1.round) == 1
    assert float(m["round_skipped"]) == 1.0
    assert float(m["valid_count"]) == 0.0
    assert float(m["drop_frac"]) == 1.0


def test_quorum_skip_fused_engine_matches_host(rng):
    """The same quorum-skipped rounds through the round-fused scan:
    params bit-identical to the init, every round's skipped flag set,
    and fused == host bit-exact on state and metrics."""
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario("sync_iid", drop_rate=1.0, quorum=1)
    st_h, mets_h = _host_rounds(loss, copt, sopt, params, batches, scn,
                                flat="xla")
    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="xla",
                        scenario=scn, num_clients=20)
    fst = flatten_fl_state(init_fl_state(params, sopt, scn), loop.layout)
    fst, fmets = jax.jit(loop)(fst, batches)
    st_f = unflatten_fl_state(fst, loop.layout)
    np.testing.assert_array_equal(np.asarray(st_f.params["x"]),
                                  np.asarray(params["x"]))
    _assert_trees_equal(st_h.params, st_f.params)
    assert np.asarray(fmets["round_skipped"]).tolist() == [1.0] * R
    assert sum(float(m["round_skipped"]) for m in mets_h) == R
    assert int(st_f.round) == R


# ------------------------------------------- fused == host under faults
@pytest.mark.parametrize("scenario", ["dirichlet_dropouts",
                                      "byzantine_async"])
def test_fused_matches_host_under_faults(scenario, rng):
    """ISSUE acceptance: the fused multi-round scan equals the host loop
    bit for bit with the fault axis ACTIVE (drops, NaN lanes, byzantine
    scaling, staleness rejection, robust tails, quorum conds) — final
    state and every round's metrics row."""
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario(scenario)
    assert scn.faulty
    st, mets = _host_rounds(loss, copt, sopt, params, batches, scn,
                            flat="xla")
    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="xla",
                        scenario=scn, num_clients=20)
    fst = flatten_fl_state(init_fl_state(params, sopt, scn), loop.layout)
    fst, fmets = jax.jit(loop, donate_argnums=0)(fst, batches)
    st2 = unflatten_fl_state(fst, loop.layout)
    _assert_trees_equal(st, st2)
    assert int(st2.round) == R
    for r in range(R):
        for k in mets[r]:
            np.testing.assert_array_equal(
                np.asarray(mets[r][k], np.float32),
                np.asarray(jax.tree.map(lambda m: m[r], fmets)[k],
                           np.float32), err_msg=f"round {r} metric {k}")
    # faults actually fired somewhere in the window
    assert any(float(m["nan_guard_rate"]) > 0 or
               float(m.get("drop_frac", 0.0)) > 0 or
               float(m.get("byz_frac", 0.0)) > 0 for m in mets)


@needs8
@pytest.mark.slow
def test_sharded_faulty_round_matches_metrics_shape(rng):
    """8-device mesh smoke for the faulty sync tail: the sharded robust
    round runs under jit with the (C, N) buffer mesh-sharded, reports
    the same telemetry keys as the replicated engine, and the quorum
    cond keeps params finite."""
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    quad, params, batches = _problem(rng, rounds=1, clients=8)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = get_scenario("sync_iid", drop_rate=0.3, nan_rate=0.1,
                       byzantine_rate=0.2, robust_agg="trimmed",
                       trim_frac=0.3, quorum=2)
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                scenario=scn, flat="xla", mesh=mesh,
                                federation=spec))
    with mesh:
        st = init_fl_state(params, sopt, scn)
        st, m, _ = rnd(st, jax.tree.map(lambda x: x[0], batches))
    for k in ("eta_clip_rate", "nan_guard_rate", "valid_count",
              "round_skipped", "drop_frac", "byz_frac"):
        assert k in m, k
    assert np.all(np.isfinite(np.asarray(st.params["x"])))
    assert int(st.round) == 1
