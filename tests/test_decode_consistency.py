"""Serving-path correctness: prefill + decode_step must reproduce the full
forward's last-position logits for every architecture, including the
sliding-window ring-buffer path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moe
from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

# heavyweight tier: CI runs -m 'not slow' first (scripts/ci.sh)
pytestmark = pytest.mark.slow

B, S = 2, 33


def _mk(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    extra = {}
    if cfg.encoder_layers:
        extra["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return toks, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full(arch, rng, monkeypatch):
    # ample expert capacity: token dropping is order-dependent and would
    # make the comparison ill-defined (documented Switch-style behaviour)
    monkeypatch.setattr(moe, "CAPACITY_FACTOR", 8.0)
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    toks, extra = _mk(cfg, rng)
    full, _ = model.apply(params, {"tokens": toks, **extra})
    cache_len = S + (cfg.num_image_tokens or 0)
    _, cache = model.prefill(params, {"tokens": toks[:, :-1], **extra},
                             cache_len=cache_len)
    dec, _ = model.decode_step(params, cache, toks[:, -1:])
    a, b = np.asarray(full[:, -1]), np.asarray(dec[:, 0])
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3, err


def test_sliding_window_ring_buffer(rng):
    """Decode with a ring buffer of W slots == full attention restricted to
    the last W positions."""
    cfg = get_config("tinyllama-1.1b").reduced()
    import dataclasses
    W = 16
    cfg = dataclasses.replace(cfg, sliding_window=W)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    T = 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)

    # reference: full forward with window masking
    full, _ = model.apply(params, {"tokens": toks})  # apply has no window
    # decode from scratch through the ring buffer
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, window=W))
    cache = model.init_cache(B, W)
    for j in range(T):
        logits, cache = step(params, cache, toks[:, j:j + 1])
    # windowed reference via prefill(window=W) of first T-1 then one step
    _, cache2 = model.prefill(params, {"tokens": toks[:, :-1]}, window=W)
    logits2, _ = model.decode_step(params, cache2, toks[:, -1:], window=W)
    a = np.asarray(logits[:, 0])
    b = np.asarray(logits2[:, 0])
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3, err


def test_multistep_decode_matches_full(rng):
    """Greedy-decode 8 steps vs teacher-forced full forwards."""
    cfg = get_config("zamba2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    T0, Tn = 16, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T0 + Tn)),
                       jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks[:, :T0]},
                             cache_len=T0 + Tn)
    step = jax.jit(model.decode_step)
    for j in range(Tn):
        dec, cache = step(params, cache, toks[:, T0 + j:T0 + j + 1])
        full, _ = model.apply(params, {"tokens": toks[:, :T0 + j + 1]})
        a = np.asarray(full[:, -1])
        b = np.asarray(dec[:, 0])
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 2e-3, (j, err)
