"""Table-driven kernel parity matrix: every cell of
``repro.conformance.kernels.KERNEL_MATRIX`` — all six Pallas kernel
namespaces, across dtype × shape — checked pallas-interpret against its
pure-jnp reference in one parametrized test. The same table backs the
``kernel:<ns>`` conformance oracles, which run one seed-selected cell
per fuzzed config; this test is the exhaustive sweep."""
import pytest

from repro.conformance import KERNEL_MATRIX, cells_for, check_cell
from repro.conformance.kernels import NAMESPACES


def test_matrix_covers_every_namespace():
    assert {c.ns for c in KERNEL_MATRIX} == set(NAMESPACES)
    for ns in NAMESPACES:
        assert len(cells_for(ns)) >= 2, ns
    keys = [c.key for c in KERNEL_MATRIX]
    assert len(keys) == len(set(keys))      # cell ids are unique


@pytest.mark.parametrize(
    "cell", KERNEL_MATRIX, ids=[c.key for c in KERNEL_MATRIX])
def test_kernel_cell_parity(cell):
    violations = check_cell(cell, seed=0)
    assert not violations, "\n".join(violations)


@pytest.mark.parametrize("seed", [1, 2])
def test_kernel_cells_parity_other_seeds(seed):
    """The matrix holds on fresh data too — one cell per namespace so
    the sweep stays cheap."""
    for ns in NAMESPACES:
        cells = cells_for(ns)
        cell = cells[seed % len(cells)]
        assert check_cell(cell, seed=seed) == [], cell.key
