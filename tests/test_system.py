"""End-to-end system behaviour: the full FL pipeline (data partitioning →
client sampling → K local Δ-SGD steps → aggregation) learns a non-iid
synthetic task without tuning, and the paper's headline transfer claim
holds in miniature."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tasks import MLP_SMALL
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)
from repro.data.pipeline import FederatedDataset
from repro.data.synthetic import get_task
from repro.models.small import accuracy, make_small_model, softmax_ce


def _train(task_id, opt_name, rounds=60, alpha=0.1, lr=0.05, seed=0):
    task = get_task(task_id, seed=seed)
    fed = FederatedDataset.build(task, num_clients=60, alpha=alpha,
                                 seed=seed)
    init_fn, logits_fn = make_small_model(MLP_SMALL)
    loss_fn = make_loss(
        lambda p, b: (softmax_ce(logits_fn(p, b["x"]), b["y"]), {}))
    copt = get_client_opt(opt_name, lr=lr)
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(loss_fn, copt, sopt, num_rounds=rounds))
    state = init_fl_state(init_fn(jax.random.key(seed)), sopt)
    for _ in range(rounds):
        batches, w, _ = fed.sample_round(0.1, local_steps=7, batch_size=64)
        state, metrics, _ = rnd(state, {"x": jnp.asarray(batches["x"]),
                                        "y": jnp.asarray(batches["y"])})
    xt, yt = fed.test_batch(2000)
    return float(accuracy(logits_fn(state.params, jnp.asarray(xt)),
                          jnp.asarray(yt))), metrics


def test_delta_sgd_learns_easy_task():
    acc, metrics = _train("easy", "delta_sgd", rounds=40)
    assert acc > 0.9, acc
    assert 0 < float(metrics["eta_mean"]) < 10


def test_delta_sgd_non_iid_robustness():
    """α = 0.01 (pathological skew) still learns."""
    acc, _ = _train("easy", "delta_sgd", rounds=60, alpha=0.01)
    assert acc > 0.75, acc


def test_transfer_claim_miniature():
    """The paper's core claim: with a step size tuned elsewhere (lr=3.0 —
    badly mis-tuned for this task), Δ-SGD (which ignores lr entirely)
    clearly beats mis-tuned SGDM on 'medium' (the task with stable
    signal at this round budget)."""
    acc_delta, _ = _train("medium", "delta_sgd", rounds=50)
    acc_mistuned, _ = _train("medium", "sgdm", rounds=50, lr=3.0)
    assert acc_delta > acc_mistuned + 0.05, (acc_delta, acc_mistuned)


def test_eta_adapts_per_round():
    """Step sizes settle away from η0 — the rule is actually engaging."""
    _, metrics = _train("hard", "delta_sgd", rounds=25)
    eta = float(metrics["eta_mean"])
    assert eta > 0 and abs(eta - 0.2) > 1e-3
